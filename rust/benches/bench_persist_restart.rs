//! Warm restart vs cold rebuild (ISSUE 6): what durability buys at
//! startup.
//!
//! Arms, on the same corpus:
//!
//! 1. **Cold rebuild** — what a restart costs *without* durability: re-
//!    encode every cached question through the transformer and re-insert
//!    it into a fresh HNSW-backed cache (the "re-pay the miss storm"
//!    lower bound; real cold starts also re-pay the LLM calls).
//! 2. **Warm restart** — `Persistence::open` on a data dir holding a
//!    snapshot (entries + serialized graph): decode, install, serve.
//!
//! Acceptance floor: **warm restart ≥ 5× faster than cold rebuild** at
//! 10k entries (full mode), and a replayed lookup trace must report a
//! **bit-identical hit/miss pattern and responses pre- vs post-restart**
//! (that part is a hard assert in both modes — it is correctness, not
//! machine-dependent performance).
//!
//! Run: `cargo bench --bench bench_persist_restart`
//! Quick mode (CI / verify.sh): `SEMCACHE_BENCH_SMOKE=1 cargo bench --bench bench_persist_restart`
//! Gate on the floor: `SEMCACHE_BENCH_ENFORCE=1`

use std::sync::Arc;
use std::time::Instant;

use semcache::cache::{CacheConfig, IndexKind, SemanticCache};
use semcache::embedding::NativeEncoder;
use semcache::metrics::Metrics;
use semcache::persist::{PersistConfig, Persistence, WalSync};
use semcache::runtime::ModelParams;
use semcache::store::SystemClock;

fn smoke() -> bool {
    std::env::var("SEMCACHE_BENCH_SMOKE").is_ok()
}

fn params() -> ModelParams {
    let mut p = ModelParams::default();
    if smoke() {
        p.layers = 1;
        p.vocab_size = 1024;
        p.dim = 96;
        p.hidden = 192;
        p.heads = 4;
    }
    p
}

fn cache_cfg() -> CacheConfig {
    CacheConfig::builder().index(IndexKind::Hnsw).ttl_ms(0).build().unwrap()
}

/// Outcome fingerprint of one lookup: None = miss, Some(response).
fn replay_trace(cache: &SemanticCache, trace: &[Vec<f32>]) -> Vec<Option<String>> {
    trace.iter().map(|q| cache.lookup(q).map(|h| h.entry.response)).collect()
}

fn main() {
    let p = params();
    let n: usize = if smoke() { 2_000 } else { 10_000 };
    let workers = 4;
    let dir = std::env::temp_dir().join(format!("semcache-bench-persist-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let texts: Vec<String> = (0..n)
        .map(|i| format!("customer question {i} about billing plan {} and device {}", i % 23, i % 7))
        .collect();
    let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
    println!(
        "[workload: {n} cached entries, {} mode ({}d x {} layers), {workers} encode workers]",
        if smoke() { "smoke" } else { "full" },
        p.dim,
        p.layers,
    );

    let enc = NativeEncoder::new(p);
    let _ = enc.encode_batch_with_workers(&refs[..workers.min(refs.len())], 1); // warm-up

    // --- arm 1: cold rebuild = re-encode everything + re-index.
    let t0 = Instant::now();
    let embeddings = enc.encode_batch_with_workers(&refs, workers);
    let cold_cache = SemanticCache::new(cache_cfg());
    for (i, e) in embeddings.iter().enumerate() {
        cold_cache.try_insert(&texts[i], e, &format!("answer {i}")).unwrap();
    }
    let cold_secs = t0.elapsed().as_secs_f64();
    println!(
        "{:<44} {:>9.3} s   ({:.0} entries/s)",
        "cold rebuild (re-encode + re-index)",
        cold_secs,
        n as f64 / cold_secs
    );

    // --- populate a durable data dir and snapshot it (setup, untimed).
    let pcfg = PersistConfig {
        data_dir: dir.clone(),
        snapshot_interval_secs: 3_600,
        wal_sync: WalSync::Os,
    };
    let (cache, persist, _) = Persistence::open(
        &pcfg,
        cache_cfg(),
        Arc::new(SystemClock),
        Arc::new(Metrics::new()),
    )
    .expect("opening data dir");
    for (i, e) in embeddings.iter().enumerate() {
        cache.try_insert(&texts[i], e, &format!("answer {i}")).unwrap();
    }
    let stats = persist.snapshot(&cache).expect("snapshot");
    println!(
        "{:<44} {:>9} entries, {:.1} MiB on disk",
        "snapshot written",
        stats.entries,
        stats.bytes as f64 / (1024.0 * 1024.0)
    );

    // --- lookup trace: half exact repeats (hits), half novel (misses).
    let n_trace = if smoke() { 200 } else { 500 };
    let novel_texts: Vec<String> =
        (0..n_trace / 2).map(|i| format!("totally new unseen question number {i}")).collect();
    let novel_refs: Vec<&str> = novel_texts.iter().map(|s| s.as_str()).collect();
    let mut trace: Vec<Vec<f32>> = Vec::with_capacity(n_trace);
    for i in 0..n_trace / 2 {
        trace.push(embeddings[(i * 37) % n].clone());
    }
    trace.extend(enc.encode_batch_with_workers(&novel_refs, workers));
    let pre = replay_trace(&cache, &trace);
    let pre_hits = pre.iter().filter(|o| o.is_some()).count();
    drop(cache);
    drop(persist);

    // --- arm 2: warm restart from snapshot + WAL.
    let metrics = Arc::new(Metrics::new());
    let t0 = Instant::now();
    let (warm_cache, _p2, rep) =
        Persistence::open(&pcfg, cache_cfg(), Arc::new(SystemClock), metrics)
            .expect("warm restart");
    let warm_secs = t0.elapsed().as_secs_f64();
    assert_eq!(rep.entries, n, "warm restart must recover every entry");
    assert_eq!(rep.reindexed_partitions, 0, "persisted graph must load, not re-index");
    println!(
        "{:<44} {:>9.3} s   ({:.0} entries/s)",
        "warm restart (snapshot + WAL recovery)",
        warm_secs,
        n as f64 / warm_secs
    );

    // --- hit-rate parity: hard assert, both modes.
    let post = replay_trace(&warm_cache, &trace);
    let post_hits = post.iter().filter(|o| o.is_some()).count();
    assert_eq!(
        pre, post,
        "replayed trace must be outcome-identical pre- vs post-restart"
    );
    println!(
        "{:<44} {:>6}/{} hits pre == {}/{} hits post",
        "trace parity", pre_hits, n_trace, post_hits, n_trace
    );

    // --- acceptance floor.
    let ratio = cold_secs / warm_secs.max(1e-9);
    println!("\nwarm-restart speedup over cold rebuild: {ratio:.1}x  (acceptance floor: >= 5.0x)");
    let ok = ratio >= 5.0;
    println!(
        "[acceptance] warm >= 5x cold: {}   trace hit parity: PASS",
        if ok { "PASS" } else { "FAIL" },
    );
    println!("(SEMCACHE_BENCH_SMOKE=1 for the quick CI variant; SEMCACHE_BENCH_ENFORCE=1 to exit non-zero on FAIL)");
    let _ = std::fs::remove_dir_all(&dir);
    if !ok && std::env::var("SEMCACHE_BENCH_ENFORCE").is_ok() {
        eprintln!("SEMCACHE_BENCH_ENFORCE is set and an acceptance floor was missed; exiting 1");
        std::process::exit(1);
    }
}
