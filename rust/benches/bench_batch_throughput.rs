//! Batch serving throughput baseline: the sequential `handle()` loop vs
//! the pooled `handle_batch()` pipeline on the same workload.
//!
//! This is the number future PRs race against. The simulated LLM runs
//! with `real_sleep` enabled (misses block the calling thread like a
//! real API call), so the pooled pipeline wins from both overlapped
//! upstream waits and parallel embedding/ANN compute.
//!
//! Run: `cargo bench --bench bench_batch_throughput`
//! Quick mode (CI / verify.sh): `SEMCACHE_BENCH_SMOKE=1 cargo bench --bench bench_batch_throughput`

use std::sync::Arc;
use std::time::Instant;

use semcache::coordinator::{ReplySource, Server, ServerConfig};
use semcache::embedding::NativeEncoder;
use semcache::llm::SimLlmConfig;
use semcache::runtime::ModelParams;
use semcache::workload::{Category, DatasetConfig, QaPair, TestQuery, WorkloadGenerator};

struct BenchSetup {
    base: Vec<QaPair>,
    trace: Vec<TestQuery>,
    params: ModelParams,
}

fn smoke() -> bool {
    std::env::var("SEMCACHE_BENCH_SMOKE").is_ok()
}

fn setup() -> BenchSetup {
    // A mid-size encoder keeps one forward pass in the low milliseconds
    // so the bench finishes quickly while embedding still dominates the
    // hit path (the regime the serving pipeline is built for).
    let mut params = ModelParams::default();
    if smoke() {
        params.layers = 1;
        params.vocab_size = 1024;
        params.dim = 96;
        params.hidden = 192;
        params.heads = 4;
    } else {
        params.layers = 2;
        params.vocab_size = 2048;
        params.dim = 192;
        params.hidden = 384;
        params.heads = 6;
    }
    let cfg = if smoke() { DatasetConfig::tiny() } else { DatasetConfig::small() };
    let ds = WorkloadGenerator::new(0xBA7C4).generate(&cfg);
    let base: Vec<QaPair> = ds
        .base_for(Category::OrderShipping)
        .take(if smoke() { 40 } else { 150 })
        .cloned()
        .collect();
    // Replay the category's test queries a few times: the first pass
    // seeds the novel clusters, repeats hit — a serving-shaped mix. The
    // smoke trace repeats more so each arm has enough work for the
    // timing to be meaningful.
    let one_pass: Vec<TestQuery> = ds.tests_for(Category::OrderShipping).cloned().collect();
    let passes = if smoke() { 12 } else { 3 };
    let trace: Vec<TestQuery> =
        std::iter::repeat(one_pass).take(passes).flatten().collect();
    BenchSetup { base, trace, params }
}

/// Fresh identically-configured server (each arm replays the same
/// workload from the same initial cache state).
fn build_server(setup: &BenchSetup, workers: usize) -> Arc<Server> {
    let server = Arc::new(Server::new(
        Arc::new(NativeEncoder::new(setup.params.clone())),
        ServerConfig {
            llm: SimLlmConfig {
                // Modest but real blocking upstream: ~5-20 ms per miss.
                rtt_ms: 4.0,
                ms_per_token: 0.05,
                jitter_sigma: 0.2,
                real_sleep: true,
                ..SimLlmConfig::default()
            },
            workers,
            ..ServerConfig::default()
        },
    ));
    server.populate(&setup.base);
    server
}

fn main() {
    let setup = setup();
    let n = setup.trace.len();
    println!(
        "[workload: {} cached pairs, {} queries ({} mode); simulated LLM sleeps on miss]",
        setup.base.len(),
        n,
        if smoke() { "smoke" } else { "full" },
    );
    let texts: Vec<&str> = setup.trace.iter().map(|q| q.text.as_str()).collect();
    let clusters: Vec<Option<u64>> = setup.trace.iter().map(|_| None).collect();

    // --- arm 1: sequential handle() loop (the pre-batch serving path).
    let server = build_server(&setup, 1);
    let t0 = Instant::now();
    let mut hits = 0usize;
    for t in &texts {
        if matches!(server.handle(t, None).source, ReplySource::Cache { .. }) {
            hits += 1;
        }
    }
    let seq_secs = t0.elapsed().as_secs_f64();
    let seq_qps = n as f64 / seq_secs;
    println!(
        "{:<44} {:>10.0} queries/s  ({} queries in {:.2}s, {} hits)",
        "sequential handle() loop", seq_qps, n, seq_secs, hits
    );

    // --- arm 2..: pooled handle_batch() at increasing widths.
    let mut qps_at_4 = 0.0;
    for workers in [1usize, 2, 4, 8] {
        let server = build_server(&setup, workers);
        let t0 = Instant::now();
        let replies = server.handle_batch_clustered(&texts, &clusters);
        let secs = t0.elapsed().as_secs_f64();
        let qps = n as f64 / secs;
        if workers == 4 {
            qps_at_4 = qps;
        }
        let hits = replies
            .iter()
            .filter(|r| matches!(r.source, ReplySource::Cache { .. }))
            .count();
        let m = server.metrics().snapshot();
        println!(
            "{:<44} {:>10.0} queries/s  ({:.2}s, {} hits, {:.2}x vs sequential)",
            format!("handle_batch, {workers} workers"),
            qps,
            secs,
            hits,
            qps / seq_qps,
        );
        println!(
            "{:<44} embed {:.1} ms  merge {:.3} ms  total {:.1} ms",
            "  per-batch stage latency:",
            m.lat_batch_embed.mean,
            m.lat_batch_merge.mean,
            m.lat_batch_total.mean,
        );
    }

    println!(
        "\nbatch speedup (4 workers vs sequential): {:.2}x  (target: >= 2x)",
        qps_at_4 / seq_qps
    );
    println!("(SEMCACHE_BENCH_SMOKE=1 for the quick CI variant)");
}
