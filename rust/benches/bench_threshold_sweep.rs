//! §5.3 regeneration: similarity threshold sweep 0.60..0.90 step 0.05.
mod common;
use semcache::experiments::{render_sweep, sweep_grid, threshold_sweep};
use semcache::llm::JudgeConfig;

fn main() {
    let ctx = common::eval_context();
    let rows = threshold_sweep(&ctx, &Default::default(), &JudgeConfig::default(), &sweep_grid());
    println!("\n{}", render_sweep(&rows));
    println!("paper §5.3: hits fall / accuracy rises with θ; 0.8 is the knee");
}
