//! Figure 3 regeneration: average query response time with/without cache.
mod common;
use semcache::experiments::{render_fig3, run_paper_eval, PaperEvalConfig};

fn main() {
    let ctx = common::eval_context();
    let eval = run_paper_eval(&ctx, &PaperEvalConfig::default());
    println!("\n{}", render_fig3(&eval));
    println!("paper Figure 3 shape: cached path is an order of magnitude faster");
}
