//! Microbenchmarks for the hot paths (§Perf in EXPERIMENTS.md):
//! vector math, HNSW insert/search, flat scan, KV store ops, tokenizer,
//! native-encoder forward, end-to-end cache lookup, and — when artifacts
//! are built — the PJRT encoder path that production serving uses.

mod common;

use semcache::cache::{CacheConfig, SemanticCache};
use semcache::embedding::{Encoder, NativeEncoder, PjrtEncoder};
use semcache::index::{FlatIndex, HnswConfig, HnswIndex, VectorIndex};
use semcache::runtime::{artifacts_dir, pjrt_ready, ModelParams};
use semcache::store::{KvStore, StoreConfig};
use semcache::tokenizer::Tokenizer;
use semcache::util::{dot, Rng};

use common::{bench, bench_throughput};

fn random_vecs(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect())
        .collect()
}

fn main() {
    let dim = 384;

    // --- vector math ---
    let vs = random_vecs(2, dim, 1);
    let (a, b) = (vs[0].clone(), vs[1].clone());
    bench_throughput("dot 384-d", 1000, 2_000_000, || {
        std::hint::black_box(dot(&a, &b));
        1
    });

    // --- index ---
    let data = random_vecs(10_000, dim, 2);
    let queries = random_vecs(256, dim, 3);

    let mut hnsw = HnswIndex::new(dim, HnswConfig::default());
    let t0 = std::time::Instant::now();
    for (i, v) in data.iter().enumerate() {
        hnsw.insert(i as u64, v);
    }
    println!(
        "{:<44} {:>10.1} inserts/s  (10k x 384-d build)",
        "hnsw insert",
        10_000.0 / t0.elapsed().as_secs_f64()
    );
    let mut flat = FlatIndex::new(dim);
    for (i, v) in data.iter().enumerate() {
        flat.insert(i as u64, v);
    }
    let mut qi = 0;
    bench("hnsw search k=5 (n=10k)", 100, 2000, || {
        std::hint::black_box(hnsw.search(&queries[qi % queries.len()], 5));
        qi += 1;
    });
    bench("flat search k=5 (n=10k)", 10, 200, || {
        std::hint::black_box(flat.search(&queries[qi % queries.len()], 5));
        qi += 1;
    });

    // --- store ---
    let store: KvStore<u64> = KvStore::new(StoreConfig::default());
    for i in 0..10_000u64 {
        store.set(&format!("key{i}"), i);
    }
    let mut k = 0u64;
    bench_throughput("kv store get (10k entries)", 1000, 1_000_000, || {
        std::hint::black_box(store.get(&format!("key{}", k % 10_000)));
        k += 1;
        1
    });

    // --- tokenizer ---
    let tok = Tokenizer::new(4096, 32);
    bench_throughput("tokenize (typical query)", 1000, 500_000, || {
        std::hint::black_box(tok.encode("how do i reset my online banking password today"));
        1
    });

    // --- native encoder forward ---
    let enc = NativeEncoder::new(ModelParams::default());
    bench("native encoder forward (1 query)", 3, 30, || {
        std::hint::black_box(enc.encode_text("how do i reset my online banking password"));
    });

    // --- end-to-end cache lookup (hot path without LLM) ---
    let cache = SemanticCache::new(CacheConfig::default());
    for (i, v) in data.iter().take(8_000).enumerate() {
        cache.try_insert(&format!("q{i}"), v, "resp").expect("insert");
    }
    let mut qi = 0;
    bench("cache lookup incl. threshold (n=8k)", 100, 2000, || {
        std::hint::black_box(cache.lookup(&queries[qi % queries.len()]));
        qi += 1;
    });

    // --- PJRT encoder (production path) ---
    if pjrt_ready() {
        let pjrt = PjrtEncoder::from_artifacts_dir(&artifacts_dir()).expect("artifacts");
        bench("pjrt encoder b=1", 2, 20, || {
            std::hint::black_box(
                pjrt.encode_text("how do i reset my online banking password").unwrap(),
            );
        });
        let texts: Vec<&str> = (0..32).map(|_| "how do i reset my password").collect();
        bench("pjrt encoder b=32 (batch)", 2, 10, || {
            std::hint::black_box(pjrt.encode_batch(&texts).unwrap());
        });
    } else {
        println!("(artifacts not built; skipping PJRT encoder benches)");
    }
}
