//! Figure 4 regeneration: hit rates + positive-match accuracy.
mod common;
use semcache::experiments::{render_fig4, run_paper_eval, PaperEvalConfig};

fn main() {
    let ctx = common::eval_context();
    let eval = run_paper_eval(&ctx, &PaperEvalConfig::default());
    println!("\n{}", render_fig4(&eval));
    println!("paper Figure 4: hit rates 61.6-68.8%, positive accuracy 92.5-97.3%");
}
