//! Shared bench harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/σ/p50 reporting, plus helpers to build the
//! evaluation fixtures each paper-table bench needs.

use std::time::Instant;

use semcache::util::Summary;

/// Run `f` repeatedly: `warmup` unmeasured runs, then `iters` measured,
/// printing a criterion-style line. Returns the per-iteration summary (ms).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let s = Summary::of(&samples);
    println!(
        "{name:<44} {:>10.4} ms/iter  (p50 {:>9.4}, p95 {:>9.4}, n={})",
        s.mean, s.p50, s.p95, s.n
    );
    s
}

/// Like [`bench`] but the closure reports how many items it processed;
/// prints throughput.
pub fn bench_throughput<F: FnMut() -> usize>(name: &str, warmup: usize, iters: usize, mut f: F) {
    for _ in 0..warmup {
        f();
    }
    let mut total_items = 0usize;
    let t0 = Instant::now();
    for _ in 0..iters {
        total_items += f();
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "{name:<44} {:>10.0} items/s  ({} items in {:.2}s)",
        total_items as f64 / secs,
        total_items,
        secs
    );
}

/// Append one machine-readable result record to the JSON-lines file
/// named by `SEMCACHE_BENCH_JSON` (no-op when the variable is unset, so
/// interactive runs stay banner-only). Each line is a self-contained
/// object — `{"bench": ..., "metric": ..., "value": ..., "unit": ...}` —
/// so verify.sh can accumulate a perf trajectory across PRs by plain
/// append without parsing prior contents.
pub fn emit_json(bench: &str, metric: &str, value: f64, unit: &str) {
    let Ok(path) = std::env::var("SEMCACHE_BENCH_JSON") else { return };
    if path.is_empty() {
        return;
    }
    use std::io::Write;
    let record = semcache::json::obj([
        ("bench", bench.into()),
        ("metric", metric.into()),
        ("value", value.into()),
        ("unit", unit.into()),
    ]);
    match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        Ok(mut f) => {
            if let Err(e) = writeln!(f, "{record}") {
                eprintln!("[bench json: append to {path} failed: {e}]");
            }
        }
        Err(e) => eprintln!("[bench json: open {path} failed: {e}]"),
    }
}

/// Evaluation fixture shared by the paper-table benches: a small-scale
/// context (fast) or paper-scale when `SEMCACHE_BENCH_SCALE=paper`.
pub fn eval_context() -> semcache::experiments::EvalContext {
    use semcache::embedding::NativeEncoder;
    use semcache::runtime::ModelParams;
    use semcache::workload::DatasetConfig;
    let scale = std::env::var("SEMCACHE_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let cfg = match scale.as_str() {
        "paper" => DatasetConfig::paper(),
        "tiny" => DatasetConfig::tiny(),
        _ => DatasetConfig::small(),
    };
    let enc = NativeEncoder::new(ModelParams::default());
    println!(
        "[bench fixture: {} scale, native encoder; set SEMCACHE_BENCH_SCALE=paper for full]",
        scale
    );
    semcache::experiments::EvalContext::build(&enc, &cfg, 0xBEC)
}
