//! HTTP front-end throughput: queries/sec through the `semcached`
//! loopback wire vs the direct in-process `serve_batch` pipeline on the
//! same workload — i.e. what the network front-end costs on top of the
//! PR 1 `bench_batch_throughput` baseline.
//!
//! The HTTP arm drives N concurrent keep-alive connections, each
//! replaying its slice of the trace as `POST /v1/query` requests; the
//! direct arm serves the identical trace as one `serve_batch` call.
//!
//! Run: `cargo bench --bench bench_http_loopback`
//! Quick mode (CI / verify.sh): `SEMCACHE_BENCH_SMOKE=1 cargo bench --bench bench_http_loopback`

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use semcache::api::QueryRequest;
use semcache::coordinator::{serve_http, HttpConfig, Server, ServerConfig};
use semcache::embedding::NativeEncoder;
use semcache::llm::SimLlmConfig;
use semcache::runtime::ModelParams;
use semcache::workload::{Category, DatasetConfig, QaPair, TestQuery, WorkloadGenerator};

fn smoke() -> bool {
    std::env::var("SEMCACHE_BENCH_SMOKE").is_ok()
}

struct BenchSetup {
    base: Vec<QaPair>,
    trace: Vec<TestQuery>,
    params: ModelParams,
}

fn setup() -> BenchSetup {
    let mut params = ModelParams::default();
    if smoke() {
        params.layers = 1;
        params.vocab_size = 1024;
        params.dim = 96;
        params.hidden = 192;
        params.heads = 4;
    } else {
        params.layers = 2;
        params.vocab_size = 2048;
        params.dim = 192;
        params.hidden = 384;
        params.heads = 6;
    }
    let cfg = if smoke() { DatasetConfig::tiny() } else { DatasetConfig::small() };
    let ds = WorkloadGenerator::new(0xBA7C4).generate(&cfg);
    let base: Vec<QaPair> = ds
        .base_for(Category::OrderShipping)
        .take(if smoke() { 40 } else { 150 })
        .cloned()
        .collect();
    let one_pass: Vec<TestQuery> = ds.tests_for(Category::OrderShipping).cloned().collect();
    let passes = if smoke() { 8 } else { 3 };
    let trace: Vec<TestQuery> = std::iter::repeat(one_pass).take(passes).flatten().collect();
    BenchSetup { base, trace, params }
}

/// Fresh identically-configured server (each arm replays the same
/// workload from the same initial cache state).
fn build_server(setup: &BenchSetup) -> Arc<Server> {
    let server = Arc::new(Server::new(
        Arc::new(NativeEncoder::new(setup.params.clone())),
        ServerConfig::builder()
            .llm(SimLlmConfig {
                rtt_ms: 4.0,
                ms_per_token: 0.05,
                jitter_sigma: 0.2,
                real_sleep: true,
                ..SimLlmConfig::default()
            })
            .workers(4)
            .build()
            .expect("bench server config"),
    ));
    server.populate(&setup.base);
    server
}

/// One keep-alive client: POST each query on a single connection and
/// count `"type": "hit"` replies (compact JSON => exact match is safe).
fn client_worker(addr: &str, queries: &[String]) -> usize {
    let stream = TcpStream::connect(addr).expect("connect loopback");
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone().expect("clone stream");
    let mut reader = BufReader::new(stream);
    let mut hits = 0usize;
    for q in queries {
        let body = QueryRequest::new(q.as_str()).to_json().to_string();
        write!(
            writer,
            "POST /v1/query HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .expect("write request");
        writer.flush().expect("flush request");

        let mut line = String::new();
        reader.read_line(&mut line).expect("status line");
        assert!(line.starts_with("HTTP/1.1 200"), "unexpected status: {line}");
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).expect("header line");
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    content_length = v.trim().parse().expect("content-length");
                }
            }
        }
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body).expect("response body");
        if std::str::from_utf8(&body).expect("utf-8 body").contains("\"type\":\"hit\"") {
            hits += 1;
        }
    }
    hits
}

fn main() {
    let setup = setup();
    let n = setup.trace.len();
    let clients = 4usize;
    println!(
        "[workload: {} cached pairs, {} queries ({} mode); {} keep-alive clients; simulated LLM sleeps on miss]",
        setup.base.len(),
        n,
        if smoke() { "smoke" } else { "full" },
        clients,
    );

    // --- arm 1: direct in-process serve_batch (the PR 1 baseline path).
    let server = build_server(&setup);
    let reqs: Vec<QueryRequest> =
        setup.trace.iter().map(|q| QueryRequest::new(q.text.as_str())).collect();
    let t0 = Instant::now();
    let replies = server.serve_batch(&reqs);
    let direct_secs = t0.elapsed().as_secs_f64();
    let direct_qps = n as f64 / direct_secs;
    let direct_hits = replies.iter().filter(|r| r.is_hit()).count();
    println!(
        "{:<44} {:>10.0} queries/s  ({:.2}s, {} hits)",
        "direct serve_batch (4 workers)", direct_qps, direct_secs, direct_hits
    );

    // --- arm 2: the same trace through the HTTP loopback front-end.
    let server = build_server(&setup);
    let handle = serve_http(
        server,
        HttpConfig { addr: "127.0.0.1:0".into(), workers: clients, ..HttpConfig::default() },
    )
    .expect("bind loopback");
    let addr = handle.local_addr().to_string();
    let texts: Vec<String> = setup.trace.iter().map(|q| q.text.clone()).collect();
    let slice_len = texts.len().div_ceil(clients);
    let t0 = Instant::now();
    let http_hits: usize = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for slice in texts.chunks(slice_len) {
            let addr = addr.clone();
            joins.push(scope.spawn(move || client_worker(&addr, slice)));
        }
        joins.into_iter().map(|j| j.join().expect("client thread")).sum()
    });
    let http_secs = t0.elapsed().as_secs_f64();
    let http_qps = n as f64 / http_secs;
    println!(
        "{:<44} {:>10.0} queries/s  ({:.2}s, {} hits)",
        format!("HTTP loopback, {clients} connections"),
        http_qps,
        http_secs,
        http_hits
    );
    handle.shutdown();

    println!(
        "\nhttp-vs-direct throughput ratio: {:.2}x  (wire + parse overhead; compare both against bench_batch_throughput)",
        http_qps / direct_qps
    );
    println!("(SEMCACHE_BENCH_SMOKE=1 for the quick CI variant)");
}
