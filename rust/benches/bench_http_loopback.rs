//! HTTP front-end throughput: queries/sec through the `semcached`
//! loopback wire — batched (cross-request micro-batching engine) vs
//! unbatched (isolated `serve()` per request, the PR 2 path) — against
//! the direct in-process `serve_batch` ceiling on the same workload,
//! plus a high-fan-in arm for the event-driven reactor (ISSUE 5).
//!
//! The workload models the paper's premise — repetitive traffic from
//! many users: 8 concurrent keep-alive connections each replay the
//! *same* pass of paraphrased queries over a pre-populated cache, so at
//! any instant several in-flight requests are identical or near-
//! identical. The unbatched path pays one embedding per request; the
//! batcher coalesces identical in-flight queries into single
//! `serve_batch` calls and answers duplicates from the representative's
//! result.
//!
//! The high-fan-in arm models the *connection* shape of that traffic:
//! hundreds of mostly-idle keep-alive chatbot sessions (512 full /
//! 64 smoke) held open against an event-loop server running ≤ 8 HTTP
//! threads (1 reactor + 4 request workers) while the same 8 active
//! clients replay the pass.
//!
//! Two further arms exercise the sharded wire path (PR 8):
//!
//! * **reactor scaling** — a wire-bound all-hit replay (memoized
//!   embeddings, warm cache) at 1 reactor / 1 dispatcher vs 4 reactors
//!   / 2 dispatchers;
//! * **massive idle fan-in** — tens of thousands of raw idle keep-alive
//!   connections (auto-scaled to `RLIMIT_NOFILE`; 256 in smoke) held
//!   against a 4-reactor server, then one fresh query timed.
//!
//! Acceptance floors:
//! * (ISSUE 3) batched >= 1.5x unbatched queries/sec at 8 connections;
//! * (ISSUE 5) with the idle fleet held open, the event loop sustains
//!   >= 0.8x the batched arm's queries/sec;
//! * (PR 8) 4 reactors sustain >= 2x the 1-reactor queries/sec on the
//!   wire-bound replay — enforced only with >= 4 cores available (on
//!   smaller hosts there is nothing to scale onto; the floor degrades
//!   to a >= 0.6x non-regression check and the waiver is printed);
//! * (PR 8) a fresh query answers within 3 s with the massive idle
//!   fleet held open.
//!
//! Run: `cargo bench --bench bench_http_loopback`
//! Quick mode (CI / verify.sh): `SEMCACHE_BENCH_SMOKE=1 cargo bench --bench bench_http_loopback`
//! Gating: `SEMCACHE_BENCH_ENFORCE=1` exits non-zero on a missed floor.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use semcache::api::QueryRequest;
use semcache::coordinator::{serve_http, BatchConfig, HttpConfig, Server, ServerConfig};
use semcache::embedding::NativeEncoder;
use semcache::llm::SimLlmConfig;
use semcache::runtime::ModelParams;
use semcache::workload::{Category, DatasetConfig, QaPair, TestQuery, WorkloadGenerator};

const CLIENTS: usize = 8;

fn smoke() -> bool {
    std::env::var("SEMCACHE_BENCH_SMOKE").is_ok()
}

/// Idle keep-alive connections held open during the high-fan-in arm.
fn fanin_conns() -> usize {
    if smoke() {
        64
    } else {
        512
    }
}

struct BenchSetup {
    base: Vec<QaPair>,
    /// One pass of paraphrased queries; every client replays it.
    pass: Vec<String>,
    params: ModelParams,
}

fn setup() -> BenchSetup {
    let mut params = ModelParams::default();
    if smoke() {
        params.layers = 1;
        params.vocab_size = 1024;
        params.dim = 96;
        params.hidden = 192;
        params.heads = 4;
    } else {
        params.layers = 2;
        params.vocab_size = 2048;
        params.dim = 192;
        params.hidden = 384;
        params.heads = 6;
    }
    let cfg = if smoke() { DatasetConfig::tiny() } else { DatasetConfig::small() };
    let ds = WorkloadGenerator::new(0xBA7C4).generate(&cfg);
    let base: Vec<QaPair> = ds
        .base_for(Category::OrderShipping)
        .take(if smoke() { 40 } else { 150 })
        .cloned()
        .collect();
    let one_pass: Vec<TestQuery> = ds.tests_for(Category::OrderShipping).cloned().collect();
    let cap = if smoke() { 40 } else { 120 };
    let pass: Vec<String> = one_pass.iter().take(cap).map(|q| q.text.clone()).collect();
    BenchSetup { base, pass, params }
}

/// Fresh identically-configured server (each arm replays the same
/// workload from the same initial cache state).
fn build_server(setup: &BenchSetup) -> Arc<Server> {
    let server = Arc::new(Server::new(
        Arc::new(NativeEncoder::new(setup.params.clone())),
        ServerConfig::builder()
            .llm(SimLlmConfig {
                rtt_ms: 4.0,
                ms_per_token: 0.05,
                jitter_sigma: 0.2,
                real_sleep: true,
                ..SimLlmConfig::default()
            })
            .workers(4)
            // Tune the batch cap to the expected concurrency so a full
            // round of in-flight clients closes the window by count
            // (dispatching immediately, paying no wait at all); the
            // window is then only the straggler budget — generous
            // enough (5 ms) that an OS-scheduling hiccup on one client
            // rejoins its round instead of permanently splitting the
            // lockstep into smaller (less deduplicable) groups.
            .batch(BatchConfig {
                max_batch_size: CLIENTS,
                max_wait_us: 5_000,
                queue_capacity: 1024,
                dispatchers: 1,
            })
            .build()
            .expect("bench server config"),
    ));
    server.populate(&setup.base);
    server
}

/// One keep-alive client: POST each query on a single connection and
/// count `"type": "hit"` replies (compact JSON => exact match is safe).
fn client_worker(addr: &str, queries: &[String]) -> usize {
    let stream = TcpStream::connect(addr).expect("connect loopback");
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone().expect("clone stream");
    let mut reader = BufReader::new(stream);
    let mut hits = 0usize;
    for q in queries {
        let body = QueryRequest::new(q.as_str()).to_json().to_string();
        write!(
            writer,
            "POST /v1/query HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .expect("write request");
        writer.flush().expect("flush request");

        let mut line = String::new();
        reader.read_line(&mut line).expect("status line");
        assert!(line.starts_with("HTTP/1.1 200"), "unexpected status: {line}");
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).expect("header line");
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    content_length = v.trim().parse().expect("content-length");
                }
            }
        }
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body).expect("response body");
        if std::str::from_utf8(&body).expect("utf-8 body").contains("\"type\":\"hit\"") {
            hits += 1;
        }
    }
    hits
}

/// Drive `CLIENTS` concurrent keep-alive connections, each replaying the
/// full pass; returns (queries/sec, total hits).
fn http_arm(setup: &BenchSetup, batching: bool) -> (f64, usize, Arc<Server>) {
    let server = build_server(setup);
    let handle = serve_http(
        server.clone(),
        HttpConfig {
            addr: "127.0.0.1:0".into(),
            workers: CLIENTS,
            batching,
            // The historical arms (and their 1.5x / 0.8x floors) measure
            // the single-threaded wire path; the scaling arm below is
            // the one that varies the widths.
            reactors: 1,
            dispatchers: 1,
            ..HttpConfig::default()
        },
    )
    .expect("bind loopback");
    let addr = handle.local_addr().to_string();
    let n = setup.pass.len() * CLIENTS;
    let t0 = Instant::now();
    let hits: usize = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for _ in 0..CLIENTS {
            let addr = addr.clone();
            let pass = &setup.pass;
            joins.push(scope.spawn(move || client_worker(&addr, pass)));
        }
        joins.into_iter().map(|j| j.join().expect("client thread")).sum()
    });
    let secs = t0.elapsed().as_secs_f64();
    handle.shutdown();
    (n as f64 / secs, hits, server)
}

/// Open one keep-alive connection, prove it is a live session with a
/// single warm-up query, and hand the (still-open) socket back.
fn open_keepalive_with_one_query(addr: &str, text: &str) -> TcpStream {
    let stream = TcpStream::connect(addr).expect("connect idle keep-alive conn");
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(60))).ok();
    let mut writer = stream.try_clone().expect("clone stream");
    let body = QueryRequest::new(text).to_json().to_string();
    write!(
        writer,
        "POST /v1/query HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .expect("write warm-up request");
    writer.flush().expect("flush warm-up request");
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut line = String::new();
    reader.read_line(&mut line).expect("warm-up status line");
    assert!(line.starts_with("HTTP/1.1 200"), "warm-up status: {line}");
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).expect("warm-up header line");
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().expect("content-length");
            }
        }
    }
    let mut resp = vec![0u8; content_length];
    reader.read_exact(&mut resp).expect("warm-up body");
    // The exact response boundary was consumed, so dropping the cloned
    // reader loses nothing; the original socket stays open and idle.
    stream
}

/// Arm 4 (ISSUE 5): the idle-fan-in shape. Hundreds of mostly-idle
/// keep-alive connections held open against the event loop (1 reactor +
/// 4 request workers: <= 8 HTTP threads) while the usual 8 active
/// clients replay the pass. Returns (queries/sec, hits, server, fleet).
fn fanin_arm(setup: &BenchSetup) -> (f64, usize, Arc<Server>, usize) {
    let mut conns = fanin_conns();
    // Each held connection costs one fd on each end; raise the soft
    // RLIMIT_NOFILE (best-effort) and scale the fleet to what fits.
    // (`util::poll` is unix-only; elsewhere the event loop degrades to
    // threaded accept and the default fd limits are left alone.)
    #[cfg(unix)]
    {
        let effective = semcache::util::poll::raise_nofile_limit((2 * conns + 128) as u64);
        if (effective as usize) < 2 * conns + 128 {
            conns = ((effective as usize).saturating_sub(128) / 2).max(16);
            eprintln!("[fan-in arm: RLIMIT_NOFILE caps the idle fleet at {conns} connections]");
        }
    }
    let server = build_server(setup);
    let handle = serve_http(
        server.clone(),
        HttpConfig {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            batching: true,
            event_loop: true,
            reactors: 1,
            dispatchers: 1,
            max_conns: conns + CLIENTS + 32,
            // The fleet must stay open for the whole active phase.
            read_timeout: Duration::from_secs(600),
            ..HttpConfig::default()
        },
    )
    .expect("bind loopback");
    let addr = handle.local_addr().to_string();

    // Build the idle fleet (16 opener threads, one warm-up query each so
    // every connection is a proven live keep-alive session).
    const OPENERS: usize = 16;
    let held: Vec<TcpStream> = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for o in 0..OPENERS {
            let addr = addr.clone();
            let pass = &setup.pass;
            joins.push(scope.spawn(move || {
                let mut streams = Vec::new();
                let mut i = o;
                while i < conns {
                    streams.push(open_keepalive_with_one_query(&addr, &pass[i % pass.len()]));
                    i += OPENERS;
                }
                streams
            }));
        }
        joins
            .into_iter()
            .flat_map(|j| j.join().expect("opener thread"))
            .collect()
    });
    assert_eq!(held.len(), conns);

    // Active phase: measured with the fleet sitting idle.
    let n = setup.pass.len() * CLIENTS;
    let t0 = Instant::now();
    let hits: usize = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for _ in 0..CLIENTS {
            let addr = addr.clone();
            let pass = &setup.pass;
            joins.push(scope.spawn(move || client_worker(&addr, pass)));
        }
        joins.into_iter().map(|j| j.join().expect("client thread")).sum()
    });
    let secs = t0.elapsed().as_secs_f64();
    drop(held);
    handle.shutdown();
    (n as f64 / secs, hits, server, conns)
}

/// Arm 5 (PR 8): reactor/dispatcher scaling. A wire-bound replay — the
/// cache is warmed by one preliminary pass, so the measured phase is
/// all memoized-embedding cache hits and the reactor threads (HTTP
/// framing, JSON writes) dominate — run at (1 reactor, 1 dispatcher)
/// and (4 reactors, 2 dispatchers). Returns queries/sec.
fn scaling_arm(setup: &BenchSetup, reactors: usize, dispatchers: usize) -> f64 {
    const SCALE_CLIENTS: usize = 16;
    let repeats = if smoke() { 2 } else { 6 };
    let server = build_server(setup);
    let handle = serve_http(
        server.clone(),
        HttpConfig {
            addr: "127.0.0.1:0".into(),
            workers: 8,
            batching: true,
            event_loop: true,
            reactors,
            dispatchers,
            max_conns: SCALE_CLIENTS + 64,
            ..HttpConfig::default()
        },
    )
    .expect("bind loopback");
    let addr = handle.local_addr().to_string();
    // Warm pass: fills the cache and the embedding memo so the measured
    // phase never touches the encoder or the simulated LLM.
    client_worker(&addr, &setup.pass);

    let n = setup.pass.len() * SCALE_CLIENTS * repeats;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..SCALE_CLIENTS {
            let addr = addr.clone();
            let pass = &setup.pass;
            scope.spawn(move || {
                for _ in 0..repeats {
                    client_worker(&addr, pass);
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    handle.shutdown();
    n as f64 / secs
}

/// Arm 6 (PR 8): massive idle fan-in. Tens of thousands of raw idle
/// keep-alive connections (each costs two fds in this process — both
/// ends are ours — so the fleet auto-scales to `RLIMIT_NOFILE`; 256 in
/// smoke) held against a 4-reactor server, then one fresh query timed
/// end to end. Returns (fleet size, fresh-query seconds, open gauge).
fn massive_idle_arm(setup: &BenchSetup) -> (usize, f64, usize) {
    let want = if smoke() { 256 } else { 20_000 };
    let mut conns = want;
    #[cfg(unix)]
    {
        let effective = semcache::util::poll::raise_nofile_limit((2 * want + 256) as u64);
        if (effective as usize) < 2 * want + 256 {
            conns = ((effective as usize).saturating_sub(256) / 2).max(64);
            eprintln!(
                "[massive idle arm: RLIMIT_NOFILE {effective} caps the fleet at {conns} connections]"
            );
        }
    }
    let server = build_server(setup);
    let handle = serve_http(
        server.clone(),
        HttpConfig {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            batching: true,
            event_loop: true,
            reactors: 4,
            dispatchers: 2,
            max_conns: conns + 64,
            read_timeout: Duration::from_secs(600),
            ..HttpConfig::default()
        },
    )
    .expect("bind loopback");
    let addr = handle.local_addr().to_string();

    // Raw idle connections: no request ever sent — each one exercises
    // exactly the accept -> handoff -> register path and then sits in
    // the fd table.
    const OPENERS: usize = 16;
    let held: Vec<TcpStream> = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for o in 0..OPENERS {
            let addr = addr.clone();
            joins.push(scope.spawn(move || {
                let mut streams = Vec::new();
                let mut i = o;
                while i < conns {
                    streams.push(TcpStream::connect(&addr).expect("idle conn"));
                    i += OPENERS;
                }
                streams
            }));
        }
        joins.into_iter().flat_map(|j| j.join().expect("opener thread")).collect()
    });
    assert_eq!(held.len(), conns);
    // Wait for the reactors to admit the whole fleet (handoff inboxes
    // drain asynchronously from the opener threads).
    let deadline = Instant::now() + Duration::from_secs(60);
    let open_gauge = loop {
        let open = server.metrics().snapshot().http_conns_open as usize;
        if open >= conns || Instant::now() >= deadline {
            break open;
        }
        std::thread::sleep(Duration::from_millis(20));
    };

    // One fresh query, timed end to end against the loaded fd table.
    let body = QueryRequest::new("fresh probe query against the massive idle fleet")
        .to_json()
        .to_string();
    let t0 = Instant::now();
    let (status, _) = semcache::coordinator::http_request(&addr, "POST", "/v1/query", Some(&body))
        .expect("fresh query under massive idle fan-in");
    let fresh_secs = t0.elapsed().as_secs_f64();
    assert_eq!(status, 200, "fresh query must serve under idle fan-in");
    drop(held);
    handle.shutdown();
    (conns, fresh_secs, open_gauge)
}

fn main() {
    let setup = setup();
    let n = setup.pass.len() * CLIENTS;
    println!(
        "[workload: {} cached pairs; {} clients x {} queries = {} total ({} mode); simulated LLM sleeps on miss]",
        setup.base.len(),
        CLIENTS,
        setup.pass.len(),
        n,
        if smoke() { "smoke" } else { "full" },
    );

    // --- arm 1: direct in-process serve_batch (the in-process ceiling).
    let server = build_server(&setup);
    let reqs: Vec<QueryRequest> = (0..CLIENTS)
        .flat_map(|_| setup.pass.iter().map(|q| QueryRequest::new(q.as_str())))
        .collect();
    let t0 = Instant::now();
    let replies = server.serve_batch(&reqs);
    let direct_secs = t0.elapsed().as_secs_f64();
    let direct_qps = n as f64 / direct_secs;
    let direct_hits = replies.iter().filter(|r| r.is_hit()).count();
    println!(
        "{:<46} {:>10.0} queries/s  ({:.2}s, {} hits)",
        "direct serve_batch (4 workers, no coalescing)", direct_qps, direct_secs, direct_hits
    );

    // --- arm 2: unbatched HTTP (isolated serve() per request; PR 2 path).
    let (unbatched_qps, unbatched_hits, _) = http_arm(&setup, false);
    println!(
        "{:<46} {:>10.0} queries/s  ({} hits)",
        format!("HTTP unbatched, {CLIENTS} connections"),
        unbatched_qps,
        unbatched_hits
    );

    // --- arm 3: batched HTTP (cross-request micro-batching engine).
    let (batched_qps, batched_hits, batched_server) = http_arm(&setup, true);
    let bm = batched_server.metrics().snapshot();
    println!(
        "{:<46} {:>10.0} queries/s  ({} hits; {} dispatches, mean batch {:.1}, {} coalesced)",
        format!("HTTP batched, {CLIENTS} connections"),
        batched_qps,
        batched_hits,
        bm.batcher_dispatches,
        bm.batcher_batch_size.mean,
        bm.coalesced
    );

    // --- arm 4: event-loop HTTP under idle fan-in (ISSUE 5).
    let (fanin_qps, fanin_hits, fanin_server, fleet) = fanin_arm(&setup);
    let fm = fanin_server.metrics().snapshot();
    println!(
        "{:<46} {:>10.0} queries/s  ({} hits; {} conns accepted, open gauge peaked >= {}, {} dispatches)",
        format!("HTTP event loop, {CLIENTS} active + {fleet} idle"),
        fanin_qps,
        fanin_hits,
        fm.http_conns_accepted,
        fleet,
        fm.batcher_dispatches,
    );

    // --- arm 5: reactor/dispatcher scaling on the wire-bound replay.
    let one_qps = scaling_arm(&setup, 1, 1);
    let four_qps = scaling_arm(&setup, 4, 2);
    println!(
        "{:<46} {:>10.0} queries/s",
        "HTTP wire-bound, 1 reactor / 1 dispatcher", one_qps
    );
    println!(
        "{:<46} {:>10.0} queries/s",
        "HTTP wire-bound, 4 reactors / 2 dispatchers", four_qps
    );

    // --- arm 6: massive idle fan-in, 4 reactors.
    let (massive_fleet, fresh_secs, open_gauge) = massive_idle_arm(&setup);
    println!(
        "{:<46} {:>10.3} s fresh query  ({} idle conns held, open gauge {})",
        format!("HTTP massive idle fan-in, {massive_fleet} conns"),
        fresh_secs,
        massive_fleet,
        open_gauge,
    );

    let vs_unbatched = batched_qps / unbatched_qps;
    let vs_direct = batched_qps / direct_qps;
    let fanin_ratio = fanin_qps / batched_qps;
    let scaling_ratio = four_qps / one_qps;
    // The 2x scaling floor needs hardware to scale onto: with fewer
    // than 4 cores the 4-reactor fleet time-slices one or two CPUs and
    // the honest expectation is "not much slower", not "2x faster".
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let (scaling_floor, scaling_waived) = if cores >= 4 { (2.0, false) } else { (0.6, true) };
    println!("\nbatched-vs-unbatched throughput ratio: {vs_unbatched:.2}x  (acceptance floor: >= 1.50x)");
    println!("batched-vs-direct ratio:               {vs_direct:.2}x  (>1 = coalescing beats even the in-process no-dedup pipeline)");
    println!("fan-in-vs-batched ratio:               {fanin_ratio:.2}x  (acceptance floor: >= 0.80x with {fleet} idle keep-alive conns on <= 8 HTTP threads)");
    println!(
        "4-reactor-vs-1 scaling ratio:          {scaling_ratio:.2}x  (acceptance floor: >= {scaling_floor:.2}x{})",
        if scaling_waived {
            format!(" — 2x floor WAIVED: only {cores} core(s) available, non-regression floor applies")
        } else {
            String::new()
        }
    );
    let floor_met = vs_unbatched >= 1.5;
    let fanin_floor_met = fanin_ratio >= 0.8;
    let scaling_floor_met = scaling_ratio >= scaling_floor;
    let fresh_floor_met = fresh_secs <= 3.0;
    println!(
        "[acceptance] batched >= 1.5x unbatched at {} connections: {}",
        CLIENTS,
        if floor_met { "PASS" } else { "FAIL" }
    );
    println!(
        "[acceptance] event loop >= 0.8x batched with {} idle keep-alive connections: {}",
        fleet,
        if fanin_floor_met { "PASS" } else { "FAIL" }
    );
    println!(
        "[acceptance] 4 reactors >= {scaling_floor:.2}x 1 reactor on the wire-bound replay: {}",
        if scaling_floor_met { "PASS" } else { "FAIL" }
    );
    println!(
        "[acceptance] fresh query <= 3 s with {massive_fleet} idle connections held: {} ({fresh_secs:.3}s)",
        if fresh_floor_met { "PASS" } else { "FAIL" }
    );
    println!("(SEMCACHE_BENCH_SMOKE=1 for the quick CI variant; SEMCACHE_BENCH_ENFORCE=1 to exit non-zero on FAIL)");
    // Throughput ratios are machine-dependent, so the floors are printed
    // banners by default; gating environments opt into a hard failure.
    if (!floor_met || !fanin_floor_met || !scaling_floor_met || !fresh_floor_met)
        && std::env::var("SEMCACHE_BENCH_ENFORCE").is_ok()
    {
        eprintln!("SEMCACHE_BENCH_ENFORCE is set and an acceptance floor was missed; exiting 1");
        std::process::exit(1);
    }
}
