//! §2.4 regeneration: HNSW O(log n) vs exhaustive O(n) scaling study.
mod common;
use semcache::experiments::{render_scaling, scaling_study, ScalingConfig};

fn main() {
    let mut cfg = ScalingConfig::default();
    if std::env::var("SEMCACHE_BENCH_SCALE").as_deref() != Ok("paper") {
        cfg.sizes = vec![1_000, 2_000, 4_000, 8_000, 16_000];
        cfg.queries = 100;
    }
    let rows = scaling_study(&cfg);
    println!("\n{}", render_scaling(&rows));
    println!("paper §2.4 claim: HNSW reduces O(n) search to ~O(log n)");
}
