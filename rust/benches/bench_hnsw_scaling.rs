//! §2.4 regeneration: HNSW O(log n) vs exhaustive O(n) scaling study,
//! plus the ISSUE 10 quantized-scan arm.
//!
//! The quantized arm measures, at 10k stored vectors:
//!
//! * **candidate-scoring throughput** — vectors scored per second by the
//!   flat index's exact f32 scan vs its int8 scan (quantized dot +
//!   exact-f32 rerank of survivors). Acceptance floor: **≥ 2×** exact.
//! * **recall vs exact** — the same HNSW graph searched with the exact
//!   kernel and the quantized kernel (construction is always exact, so
//!   the graph is shared); average top-k id overlap on a planted
//!   near-duplicate workload at the default 0.8 threshold. Acceptance
//!   floor: **recall ≥ 0.99**.
//!
//! Both floors are printed banners by default and hard failures under
//! `SEMCACHE_BENCH_ENFORCE=1`. `SEMCACHE_BENCH_SMOKE=1` shrinks the
//! scaling sweep and query counts for CI; `SEMCACHE_BENCH_JSON=<path>`
//! appends machine-readable results (see `benches/common`).
//!
//! Run: `cargo bench --bench bench_hnsw_scaling`
mod common;

use std::time::Instant;

use semcache::experiments::{render_scaling, scaling_study, ScalingConfig};
use semcache::index::{FlatIndex, HnswConfig, HnswIndex, VectorIndex};
use semcache::util::l2_normalized;

fn smoke() -> bool {
    std::env::var("SEMCACHE_BENCH_SMOKE").is_ok()
}

/// xorshift64*-style deterministic stream: no external RNG offline.
struct Rng(u64);

impl Rng {
    fn f32(&mut self) -> f32 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        (self.0 >> 40) as f32 / 16_777_216.0 - 0.5
    }

    fn vec(&mut self, dim: usize) -> Vec<f32> {
        l2_normalized(&(0..dim).map(|_| self.f32()).collect::<Vec<_>>())
    }

    fn below(&mut self, n: usize) -> usize {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        (self.0 % n as u64) as usize
    }
}

fn main() {
    let mut cfg = ScalingConfig::default();
    if std::env::var("SEMCACHE_BENCH_SCALE").as_deref() != Ok("paper") {
        cfg.sizes = vec![1_000, 2_000, 4_000, 8_000, 16_000];
        cfg.queries = 100;
    }
    if smoke() {
        cfg.sizes = vec![1_000, 4_000];
        cfg.queries = 25;
    }
    let rows = scaling_study(&cfg);
    println!("\n{}", render_scaling(&rows));
    println!("paper §2.4 claim: HNSW reduces O(n) search to ~O(log n)");

    // --- quantized-scan arm (ISSUE 10): 10k vectors, MiniLM dim.
    let n = 10_000usize;
    let dim = 384usize;
    let k = 5usize;
    let queries = if smoke() { 40 } else { 200 };
    let mut rng = Rng(0x5eed_cafe);
    println!("\n[quantized-scan arm: {n} vectors, dim {dim}, top-{k}, {queries} planted queries]");

    let mut stored: Vec<Vec<f32>> = Vec::with_capacity(n);
    let mut flat_exact = FlatIndex::new(dim);
    let mut flat_quant = FlatIndex::with_quantized(dim, true);
    for id in 0..n as u64 {
        let v = rng.vec(dim);
        flat_exact.insert(id, &v);
        flat_quant.insert(id, &v);
        stored.push(v);
    }
    // Planted near-duplicates: the cache's hit-path shape at the default
    // 0.8 threshold (each query's true top-1 scores ~0.999).
    let qs: Vec<Vec<f32>> = (0..queries)
        .map(|_| {
            let base = &stored[rng.below(n)];
            let jittered: Vec<f32> = base.iter().map(|x| x + 0.02 * rng.f32()).collect();
            l2_normalized(&jittered)
        })
        .collect();

    // Candidate-scoring throughput: every query scores all n rows.
    let t0 = Instant::now();
    let mut exact_tops = Vec::with_capacity(queries);
    for q in &qs {
        exact_tops.push(flat_exact.search(q, k));
    }
    let exact_secs = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let mut quant_tops = Vec::with_capacity(queries);
    for q in &qs {
        quant_tops.push(flat_quant.search(q, k));
    }
    let quant_secs = t0.elapsed().as_secs_f64();
    let scored = (n * queries) as f64;
    let exact_vps = scored / exact_secs;
    let quant_vps = scored / quant_secs;
    let speedup = exact_secs / quant_secs.max(1e-12);
    println!(
        "{:<44} {:>12.0} vectors/s  ({:.3}s)",
        "flat exact f32 scan", exact_vps, exact_secs
    );
    println!(
        "{:<44} {:>12.0} vectors/s  ({:.3}s)",
        "flat int8 scan + exact rerank", quant_vps, quant_secs
    );

    // Recall of the quantized kernel over a shared HNSW graph: edges are
    // built exactly either way, so flipping the flag isolates the
    // query-time kernel.
    let mut hnsw = HnswIndex::new(dim, HnswConfig::default());
    for (id, v) in stored.iter().enumerate() {
        hnsw.insert(id as u64, v);
    }
    let mut overlap = 0usize;
    let mut wanted = 0usize;
    for q in &qs {
        let exact: Vec<u64> = hnsw.search(q, k).iter().map(|r| r.id).collect();
        hnsw.set_quantized(true);
        let quant: Vec<u64> = hnsw.search(q, k).iter().map(|r| r.id).collect();
        hnsw.set_quantized(false);
        wanted += exact.len();
        overlap += quant.iter().filter(|id| exact.contains(id)).count();
    }
    let recall = overlap as f64 / wanted.max(1) as f64;
    println!("{:<44} {:>12.4}", "quantized recall vs exact (same graph)", recall);

    let speed_ok = speedup >= 2.0;
    let recall_ok = recall >= 0.99;
    println!("\nint8-vs-f32 candidate-scoring speedup:   {speedup:.2}x  (acceptance floor: >= 2.00x at {n} vectors)");
    println!("quantized-vs-exact recall:               {recall:.4}  (acceptance floor: >= 0.99)");
    println!(
        "[acceptance] int8 scan >= 2x f32: {}   recall >= 0.99: {}",
        if speed_ok { "PASS" } else { "FAIL" },
        if recall_ok { "PASS" } else { "FAIL" },
    );
    println!("(SEMCACHE_BENCH_SMOKE=1 for the quick CI variant; SEMCACHE_BENCH_ENFORCE=1 to exit non-zero on FAIL)");

    common::emit_json("hnsw", "exact_scan_vps", exact_vps, "vectors/s");
    common::emit_json("hnsw", "quantized_scan_vps", quant_vps, "vectors/s");
    common::emit_json("hnsw", "quantized_speedup", speedup, "x");
    common::emit_json("hnsw", "quantized_recall", recall, "ratio");

    if (!speed_ok || !recall_ok) && std::env::var("SEMCACHE_BENCH_ENFORCE").is_ok() {
        eprintln!("SEMCACHE_BENCH_ENFORCE is set and an acceptance floor was missed; exiting 1");
        std::process::exit(1);
    }
}
