//! Figure 2 regeneration: API-call frequency, traditional vs cached.
mod common;
use semcache::experiments::{render_fig2, run_paper_eval, PaperEvalConfig};

fn main() {
    let ctx = common::eval_context();
    let eval = run_paper_eval(&ctx, &PaperEvalConfig::default());
    println!("\n{}", render_fig2(&eval));
    println!("paper Figure 2: API calls reduced to 33% / 33% / 31.2% / 38.4%");
}
