//! Table 1 regeneration: cache hits & positive hits per category.
//! `cargo bench --bench bench_table1_hits` (SEMCACHE_BENCH_SCALE=paper for 500q/category).
mod common;
use semcache::experiments::{render_table1, run_paper_eval, PaperEvalConfig};

fn main() {
    let ctx = common::eval_context();
    let t = std::time::Instant::now();
    let eval = run_paper_eval(&ctx, &PaperEvalConfig::default());
    println!("\n{}", render_table1(&eval));
    println!("paper Table 1 (per 500): hits 335/335/344/308, positives 310/326/331/298");
    println!("(evaluation protocol wall time: {:.2}s)", t.elapsed().as_secs_f64());
}
