//! Embedding hot-path throughput: the three layers of the ISSUE 4
//! overhaul, each against its own baseline on the same workload.
//!
//! 1. **Arena + sequential forward pass** — queries/sec of
//!    `encode_batch` pinned to one worker (every intermediate buffer
//!    lives in a reused `EncodeScratch`; the seed allocated 8 buffers +
//!    an s×d clone per encode).
//! 2. **Parallel batch** — the same batch across a 4-worker scoped
//!    pool. Acceptance floor: **≥ 2× sequential queries/sec** (needs ≥ 2
//!    usable cores; the floor is a printed banner by default and a hard
//!    exit under `SEMCACHE_BENCH_ENFORCE=1`, matching the PR 3
//!    convention).
//! 3. **Exact-match memo tier** — p50 per-encode latency of a repeated
//!    identical query answered by the memo vs the cold forward pass
//!    (measured on the same text via the per-request bypass, so the two
//!    arms encode byte-identical input). Acceptance floor: **memo p50 ≥
//!    20× faster than cold p50**.
//!
//! 4. **Blocked vs naive matmul** — mul-add throughput of the
//!    register-tiled `matmul_acc_blocked` against the seed ikj loop at
//!    the encoder's FFN GEMM shape, bit-identity asserted. Acceptance
//!    floor: **≥ 2× the naive kernel on ≥ 2-core hosts** (WAIVED
//!    banner on single-core hosts).
//!
//! With `SEMCACHE_BENCH_JSON=<path>` every headline number is also
//! appended to that file as JSON lines (see `benches/common`).
//!
//! The memoized arm is the paper's dominant traffic shape (repetitive
//! customer-service queries, 61.6–68.8% hit rates): every verbatim
//! repeat skips the transformer entirely. Compare the end-to-end effect
//! with `bench_http_loopback` (embedding is the dominant compute on the
//! cache-hit path there).
//!
//! Run: `cargo bench --bench bench_embed_throughput`
//! Quick mode (CI / verify.sh): `SEMCACHE_BENCH_SMOKE=1 cargo bench --bench bench_embed_throughput`

mod common;

use std::time::Instant;

use semcache::embedding::{
    matmul_acc_blocked, matmul_acc_naive, Encoder, MemoConfig, NativeEncoder,
};
use semcache::runtime::ModelParams;

fn smoke() -> bool {
    std::env::var("SEMCACHE_BENCH_SMOKE").is_ok()
}

fn params() -> ModelParams {
    let mut p = ModelParams::default();
    if smoke() {
        p.layers = 1;
        p.vocab_size = 1024;
        p.dim = 96;
        p.hidden = 192;
        p.heads = 4;
    }
    // Full mode: the default MiniLM-geometry serving encoder (384-d,
    // 4 layers) — the exact forward pass the daemon pays per query.
    p
}

fn p50(samples: &mut [f64]) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

fn main() {
    let p = params();
    let n_texts = if smoke() { 48 } else { 192 };
    let reps = if smoke() { 200 } else { 400 };
    let texts: Vec<String> = (0..n_texts)
        .map(|i| format!("how do i configure gadget model {i} firmware build {}", i % 7))
        .collect();
    let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();

    println!(
        "[workload: {n_texts} distinct queries, {} mode ({}d x {} layers); {reps} repeat-query samples]",
        if smoke() { "smoke" } else { "full" },
        p.dim,
        p.layers,
    );

    let enc = NativeEncoder::new(p.clone());
    // Warm up weights/caches and the thread-local scratch arena.
    let _ = enc.encode_batch_with_workers(&refs[..4.min(refs.len())], 1);

    // --- arm 1: sequential encode_batch (arena, one worker).
    let t0 = Instant::now();
    let seq_out = enc.encode_batch_with_workers(&refs, 1);
    let seq_secs = t0.elapsed().as_secs_f64();
    let seq_qps = n_texts as f64 / seq_secs;
    println!(
        "{:<44} {:>10.0} queries/s  ({:.3}s)",
        "sequential encode_batch (1 worker, arena)", seq_qps, seq_secs
    );

    // --- arm 2: parallel encode_batch, 4 workers.
    let t0 = Instant::now();
    let par_out = enc.encode_batch_with_workers(&refs, 4);
    let par_secs = t0.elapsed().as_secs_f64();
    let par_qps = n_texts as f64 / par_secs;
    println!(
        "{:<44} {:>10.0} queries/s  ({:.3}s)",
        "parallel encode_batch (4 workers)", par_qps, par_secs
    );
    assert_eq!(seq_out, par_out, "parallel encoding must be bit-identical");

    // --- arm 3: memoized repeat-query vs cold forward pass, same text.
    let memoized = NativeEncoder::new(p)
        .with_memo(MemoConfig { capacity: 1024, shards: 8 })
        .expect("memo config");
    let repeat = "how do i reset my password please"; // the paper's shape
    let warm = memoized.encode_batch_tracked(&[repeat], false); // admit
    assert!(!warm[0].memo_hit);

    let mut cold_ms = Vec::with_capacity(reps);
    let mut memo_ms = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        let out = memoized.encode_batch_tracked(&[repeat], true); // bypass = cold
        cold_ms.push(t.elapsed().as_secs_f64() * 1e3);
        assert!(!out[0].memo_hit);

        let t = Instant::now();
        let out = memoized.encode_batch_tracked(&[repeat], false); // memo hit
        memo_ms.push(t.elapsed().as_secs_f64() * 1e3);
        assert!(out[0].memo_hit, "warm repeat must hit the memo tier");
        assert_eq!(out[0].embedding, warm[0].embedding, "memo is bit-identical");
    }
    let cold_p50 = p50(&mut cold_ms);
    let memo_p50 = p50(&mut memo_ms);
    println!(
        "{:<44} {:>10.4} ms p50",
        "cold forward pass (per-request bypass)", cold_p50
    );
    println!("{:<44} {:>10.4} ms p50", "memoized repeat query", memo_p50);

    // --- arm 4: blocked vs naive matmul kernel (ISSUE 10), at the
    // encoder's FFN GEMM shape (seq x dim @ dim x hidden) — the single
    // hottest loop of the forward pass. Both kernels run the same
    // matrices and must stay bit-identical (the property tests pin the
    // same contract; the bench re-checks on real sizes for free).
    let (rows, inner, cols) = if smoke() { (32, p.dim, p.hidden) } else { (64, p.dim, p.hidden) };
    let kernel_reps = if smoke() { 40 } else { 120 };
    let mut seed = 0x5eed_cafe_u64;
    let mut next = move || {
        // xorshift64*: deterministic fill, no external RNG.
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        (seed >> 40) as f32 / 16_777_216.0 - 0.5
    };
    let a: Vec<f32> = (0..rows * inner).map(|_| next()).collect();
    let b: Vec<f32> = (0..inner * cols).map(|_| next()).collect();
    let mut out_naive = vec![0.0f32; rows * cols];
    let mut out_blocked = vec![0.0f32; rows * cols];

    let t0 = Instant::now();
    for _ in 0..kernel_reps {
        matmul_acc_naive(&a, &b, &mut out_naive, rows, inner, cols);
    }
    let naive_secs = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..kernel_reps {
        matmul_acc_blocked(&a, &b, &mut out_blocked, rows, inner, cols);
    }
    let blocked_secs = t0.elapsed().as_secs_f64();
    assert_eq!(
        out_naive, out_blocked,
        "blocked matmul must stay bit-identical to the seed kernel"
    );
    let madds = (rows * inner * cols * kernel_reps) as f64;
    let naive_gmadds = madds / naive_secs / 1e9;
    let blocked_gmadds = madds / blocked_secs / 1e9;
    println!(
        "{:<44} {:>10.2} Gmadd/s  ({rows}x{inner}x{cols}, {kernel_reps} reps)",
        "naive ikj matmul (seed kernel)", naive_gmadds
    );
    println!(
        "{:<44} {:>10.2} Gmadd/s  ({rows}x{inner}x{cols}, {kernel_reps} reps)",
        "blocked 4x8 matmul (dispatch default)", blocked_gmadds
    );

    // --- acceptance floors.
    let par_ratio = par_qps / seq_qps;
    let memo_ratio = cold_p50 / memo_p50.max(1e-9);
    let kernel_ratio = naive_secs / blocked_secs.max(1e-12);
    let multi_core = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) >= 2;
    println!("\nparallel-vs-sequential throughput ratio: {par_ratio:.2}x  (acceptance floor: >= 2.00x at 4 workers)");
    println!("cold-vs-memo p50 latency ratio:          {memo_ratio:.1}x  (acceptance floor: >= 20x)");
    println!("blocked-vs-naive matmul throughput ratio: {kernel_ratio:.2}x  (acceptance floor: >= 2.00x on >= 2-core hosts)");
    let par_ok = par_ratio >= 2.0;
    let memo_ok = memo_ratio >= 20.0;
    let kernel_ok = kernel_ratio >= 2.0;
    println!(
        "[acceptance] parallel >= 2x sequential: {}   memo >= 20x cold: {}   blocked >= 2x naive: {}",
        if par_ok { "PASS" } else { "FAIL" },
        if memo_ok { "PASS" } else { "FAIL" },
        if kernel_ok {
            "PASS"
        } else if !multi_core {
            "WAIVED (single-core host)"
        } else {
            "FAIL"
        },
    );
    println!("(SEMCACHE_BENCH_SMOKE=1 for the quick CI variant; SEMCACHE_BENCH_ENFORCE=1 to exit non-zero on FAIL; the parallel and kernel floors need >= 2 usable cores)");

    common::emit_json("embed", "sequential_qps", seq_qps, "queries/s");
    common::emit_json("embed", "parallel_qps", par_qps, "queries/s");
    common::emit_json("embed", "parallel_ratio", par_ratio, "x");
    common::emit_json("embed", "cold_p50_ms", cold_p50, "ms");
    common::emit_json("embed", "memo_p50_ms", memo_p50, "ms");
    common::emit_json("embed", "memo_ratio", memo_ratio, "x");
    common::emit_json("embed", "matmul_naive_gmadds", naive_gmadds, "Gmadd/s");
    common::emit_json("embed", "matmul_blocked_gmadds", blocked_gmadds, "Gmadd/s");
    common::emit_json("embed", "matmul_blocked_ratio", kernel_ratio, "x");

    // Throughput ratios are machine-dependent, so the floors are printed
    // banners by default; gating environments opt into a hard failure.
    // The kernel floor follows the WAIVED convention: single-core hosts
    // print the banner but never fail it.
    let kernel_gate = kernel_ok || !multi_core;
    if (!par_ok || !memo_ok || !kernel_gate) && std::env::var("SEMCACHE_BENCH_ENFORCE").is_ok() {
        eprintln!("SEMCACHE_BENCH_ENFORCE is set and an acceptance floor was missed; exiting 1");
        std::process::exit(1);
    }
}
