//! Eviction-policy shootout at a fixed byte budget (ISSUE 7): how much
//! simulated upstream LLM latency each policy's survivors save on a
//! skewed trace.
//!
//! Workload shape (the semantic-cache pathology byte budgets exist
//! for): a small set of *recurring, expensive* queries — slow LLM
//! answers that come back again and again — interleaved with a flood of
//! *one-shot, cheap* queries that are never asked twice. The byte
//! budget holds only a fraction of the trace's distinct entries, so the
//! policy decides which bytes survive:
//!
//! * **lru** treats every byte the same — the one-shot flood keeps
//!   pushing the recurring entries out before they recur.
//! * **lfu** protects the recurring set once it has been seen twice.
//! * **cost** scores latency-saved-per-byte
//!   ([`semcache::eviction::CostAware`]), so the expensive recurring
//!   answers survive the flood from their *first* sighting.
//!
//! Scored metric per arm: total LLM latency saved = Σ `latency_ms` of
//! every hit (exactly what the entry's miss would have re-paid).
//! Acceptance floor printed in the banner: **cost ≥ 1.2× lru** on
//! latency saved at the shared byte budget.
//!
//! Run: `cargo bench --bench bench_eviction`
//! Quick mode (CI / verify.sh): `SEMCACHE_BENCH_SMOKE=1 cargo bench --bench bench_eviction`
//! Gate on the floor: `SEMCACHE_BENCH_ENFORCE=1`

use semcache::cache::{CacheConfig, CachedEntry, SemanticCache};
use semcache::eviction::entry_footprint;
use semcache::util::{l2_normalized, SplitMix64};

fn smoke() -> bool {
    std::env::var("SEMCACHE_BENCH_SMOKE").is_ok()
}

const DIM: usize = 64;
/// Lookup gate: distinct random unit vectors in 64-d sit near cosine 0,
/// exact repeats at 1.0 — hits are exact-repeat hits only.
const THRESHOLD: f32 = 0.9;

/// One query class in the trace.
struct Query {
    text: String,
    embedding: Vec<f32>,
    /// Simulated upstream latency its miss pays (and a later hit saves).
    llm_ms: f64,
}

fn unit_vec(rng: &mut SplitMix64) -> Vec<f32> {
    let v: Vec<f32> = (0..DIM).map(|_| rng.next_f64() as f32 * 2.0 - 1.0).collect();
    l2_normalized(&v)
}

/// Replay the trace against one policy; returns (latency saved, hits,
/// misses, evictions).
fn run_policy(policy: &str, trace: &[Query], budget: u64) -> (f64, u64, u64, u64) {
    let cache = SemanticCache::new(CacheConfig {
        max_bytes: budget,
        eviction_policy: policy.to_string(),
        ..Default::default()
    });
    let mut saved_ms = 0.0;
    let mut hits = 0u64;
    let mut misses = 0u64;
    for q in trace {
        match cache.lookup_with_threshold(&q.embedding, THRESHOLD) {
            Some(hit) => {
                saved_ms += hit.entry.latency_ms;
                hits += 1;
            }
            None => {
                misses += 1;
                cache
                    .try_insert_entry(
                        &q.embedding,
                        CachedEntry {
                            question: q.text.clone(),
                            response: format!("answer to: {}", q.text),
                            cluster: 0,
                            latency_ms: q.llm_ms,
                        },
                    )
                    .expect("insert fits the budget");
            }
        }
    }
    let evictions = cache.tenant_stats().iter().map(|t| t.evictions).sum();
    assert!(
        cache.bytes() <= budget,
        "{policy}: resident {} B > budget {budget} B at rest",
        cache.bytes()
    );
    (saved_ms, hits, misses, evictions)
}

fn main() {
    let steps: usize = if smoke() { 2_000 } else { 10_000 };
    let recurring_n = 32usize;
    // ~30 % of steps re-ask one of the 32 expensive recurring queries;
    // the rest are a one-shot cheap flood.
    let recurring_every = 10u64; // of 32 -> ~31 % recurring
    let mut rng = SplitMix64::new(0x5EED_E71C);

    let recurring: Vec<Query> = (0..recurring_n)
        .map(|i| Query {
            text: format!("recurring expensive analytics question number {i}"),
            embedding: unit_vec(&mut rng),
            llm_ms: 1_500.0 + (i as f64) * 40.0,
        })
        .collect();

    // Budget: ~40 nominal entries — the full recurring set fits with
    // room to spare, but nowhere near the flood's distinct-entry count.
    let nominal = entry_footprint(48, 64, DIM);
    let budget = 40 * nominal;

    let mut trace: Vec<Query> = Vec::with_capacity(steps);
    let mut one_shots = 0usize;
    for step in 0..steps {
        if rng.next_u64() % (recurring_every * recurring_n as u64) < recurring_n as u64 * 3 {
            let i = (rng.next_u64() as usize) % recurring_n;
            let q = &recurring[i];
            trace.push(Query {
                text: q.text.clone(),
                embedding: q.embedding.clone(),
                llm_ms: q.llm_ms,
            });
        } else {
            one_shots += 1;
            trace.push(Query {
                text: format!("one-shot cheap lookup number {step}"),
                embedding: unit_vec(&mut rng),
                llm_ms: 40.0,
            });
        }
    }
    println!(
        "[workload: {steps} steps ({} recurring x{recurring_n} classes, {one_shots} one-shots), \
         budget {budget} B (~{} entries), {} mode]",
        steps - one_shots,
        budget / nominal,
        if smoke() { "smoke" } else { "full" },
    );

    let mut saved = std::collections::HashMap::new();
    for policy in ["lru", "lfu", "cost"] {
        let (saved_ms, hits, misses, evictions) = run_policy(policy, &trace, budget);
        println!(
            "{:<10} saved {:>10.0} ms of LLM latency   ({hits} hits / {misses} misses, {evictions} evictions)",
            policy, saved_ms,
        );
        saved.insert(policy, saved_ms);
    }

    let ratio = saved["cost"] / saved["lru"].max(1e-9);
    println!(
        "\ncost-aware latency saved over lru: {ratio:.2}x  (acceptance floor: >= 1.2x)"
    );
    let ok = ratio >= 1.2;
    println!("[acceptance] cost >= 1.2x lru latency saved: {}", if ok { "PASS" } else { "FAIL" });
    println!("(SEMCACHE_BENCH_SMOKE=1 for the quick CI variant; SEMCACHE_BENCH_ENFORCE=1 to exit non-zero on FAIL)");
    if !ok && std::env::var("SEMCACHE_BENCH_ENFORCE").is_ok() {
        eprintln!("SEMCACHE_BENCH_ENFORCE is set and an acceptance floor was missed; exiting 1");
        std::process::exit(1);
    }
}
