//! Adversarial tenant-isolation suite against the real `semcached`
//! daemon (ISSUE 7 satellite): a hot tenant flooding the cache past the
//! global byte budget must never evict a cold tenant's working set, the
//! budget must hold at every rest point, and the per-tenant metric
//! blocks on `/v1/metrics` must tell the story.
//!
//! Everything here runs over HTTP — the point is that the isolation
//! guarantees survive the full wire path (parse → batcher → serve →
//! tenant-scoped cache), not just the library API.

#![cfg(unix)]

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use semcache::api::QueryRequest;
use semcache::coordinator::http_request;
use semcache::json::Value;

/// Global byte budget the daemon serves under. Roomy enough for the
/// cold tenant's 4 entries (~3.5 KiB each at the default 384-d encoder
/// geometry), far too small for the hot tenant's 40-entry flood.
const MAX_BYTES: u64 = 64 * 1024;
const COLD_QUOTA: u64 = 1024 * 1024;

/// Kills the daemon (SIGKILL) when dropped so a failing assertion never
/// leaks a background `semcached` into the test runner.
struct Daemon(Child);

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("semcache-tenancy-{tag}-{}", std::process::id()));
    p
}

fn spawn_daemon(port_file: &Path) -> Daemon {
    let child = Command::new(env!("CARGO_BIN_EXE_semcached"))
        .args([
            "serve",
            "--port",
            "0",
            "--port-file",
            port_file.to_str().unwrap(),
            "--max_bytes",
            &MAX_BYTES.to_string(),
            "--eviction_policy",
            "lru",
            // Exercises the per-tenant config path end-to-end; generous
            // enough to never fire (the global budget is the pressure
            // source in this suite).
            "--tenant.cold.quota_bytes",
            &COLD_QUOTA.to_string(),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning semcached");
    Daemon(child)
}

/// Ready-signal handshake: wait for the atomically-written port file,
/// then poll /v1/metrics until the daemon answers.
fn wait_ready(port_file: &Path, daemon: &mut Daemon) -> String {
    let deadline = Instant::now() + Duration::from_secs(120);
    let addr = loop {
        if let Ok(s) = std::fs::read_to_string(port_file) {
            if !s.is_empty() {
                break s;
            }
        }
        if let Ok(Some(status)) = daemon.0.try_wait() {
            panic!("semcached exited before becoming ready: {status}");
        }
        assert!(Instant::now() < deadline, "semcached never wrote its port file");
        std::thread::sleep(Duration::from_millis(50));
    };
    loop {
        if http_request(&addr, "GET", "/v1/metrics", None).is_ok() {
            break;
        }
        assert!(Instant::now() < deadline, "semcached never became healthy at {addr}");
        std::thread::sleep(Duration::from_millis(50));
    }
    addr
}

fn post(addr: &str, req: &QueryRequest) -> (u16, Value) {
    http_request(addr, "POST", "/v1/query", Some(&req.to_json().to_string()))
        .expect("query round-trip")
}

fn metrics(addr: &str) -> Value {
    let (status, body) = http_request(addr, "GET", "/v1/metrics", None).unwrap();
    assert_eq!(status, 200);
    body
}

fn tenant_counter(m: &Value, tenant: &str, key: &str) -> u64 {
    m.get("tenants")
        .get(tenant)
        .get(key)
        .as_u64()
        .unwrap_or_else(|| panic!("metrics missing tenants.{tenant}.{key}: {m}"))
}

#[test]
fn hot_tenant_flood_cannot_evict_cold_tenant_over_http() {
    let port_file = tmpdir("flood").with_extension("port");
    let _ = std::fs::remove_file(&port_file);
    let mut daemon = spawn_daemon(&port_file);
    let addr = wait_ready(&port_file, &mut daemon);

    // Cold tenant parks a small working set. The strict per-request
    // threshold guarantees each distinct text misses (and inserts)
    // rather than accidentally hitting a semantic neighbor.
    let cold_texts = [
        "how do i reset my password",
        "what is the refund policy for the pro plan",
        "my invoice shows a duplicate charge",
        "how can i export all of my account data",
    ];
    for text in cold_texts {
        let (status, body) =
            post(&addr, &QueryRequest::new(text).with_client_tag("cold").with_threshold(0.9999));
        assert_eq!(status, 200, "cold insert failed: {body}");
        assert_eq!(body.get("outcome").get("type").as_str(), Some("miss"), "cold insert must miss: {body}");
    }
    let m = metrics(&addr);
    let cold_bytes = tenant_counter(&m, "cold", "bytes");
    assert!(cold_bytes > 0, "cold working set must be charged bytes");
    assert!(
        cold_bytes < MAX_BYTES / 2,
        "test geometry: cold set ({cold_bytes} B) must fit well within the {MAX_BYTES} B budget"
    );
    assert_eq!(
        tenant_counter(&m, "cold", "quota_bytes"),
        COLD_QUOTA,
        "--tenant.cold.quota_bytes must reach the tenant state"
    );

    // Hot tenant floods 40 distinct entries — several times the global
    // budget — so the budget must evict, repeatedly, mid-flood.
    for i in 0..40u64 {
        let text = format!("hot tenant flood query number {i} with unique marker {}", i * 31 + 7);
        let (status, body) =
            post(&addr, &QueryRequest::new(text).with_client_tag("hot").with_threshold(0.9999));
        assert_eq!(status, 200, "hot flood insert failed: {body}");
    }

    let m = metrics(&addr);
    // The budget bit: evictions happened, and every one of them was
    // charged to the tenant that caused the pressure.
    let hot_evictions = tenant_counter(&m, "hot", "evictions");
    assert!(hot_evictions >= 1, "flood past the budget must evict: {m}");
    assert_eq!(
        tenant_counter(&m, "cold", "evictions"),
        0,
        "zero cross-tenant evictions: {m}"
    );
    assert_eq!(
        tenant_counter(&m, "cold", "entries"),
        cold_texts.len() as u64,
        "cold working set intact: {m}"
    );
    // At rest the global budget holds outright (the one-footprint
    // overshoot allowance is only for the instant mid-insert).
    let cache_bytes = m.get("cache_bytes").as_u64().expect("cache_bytes");
    let cache_max = m.get("cache_max_bytes").as_u64().expect("cache_max_bytes");
    assert_eq!(cache_max, MAX_BYTES);
    assert!(cache_bytes <= cache_max, "resident {cache_bytes} B > budget {cache_max} B");
    // The batcher's queue-depth gauge rides the same payload and reads 0
    // with nothing in flight.
    assert_eq!(
        m.get("metrics").get("batch_queue_depth").as_u64(),
        Some(0),
        "queue-depth gauge missing or non-zero at rest: {m}"
    );

    // The proof that matters: every cold query still hits, verbatim,
    // after the flood.
    for text in cold_texts {
        let (status, body) = post(&addr, &QueryRequest::new(text).with_client_tag("cold"));
        assert_eq!(status, 200);
        assert_eq!(
            body.get("outcome").get("type").as_str(),
            Some("hit"),
            "cold entry lost to the hot flood: {body}"
        );
    }

    // And the flood never leaked across the namespace boundary: the hot
    // tenant asking a cold question verbatim must miss (and what it
    // inserts lands in its own namespace).
    let (status, body) =
        post(&addr, &QueryRequest::new(cold_texts[0]).with_client_tag("hot").with_threshold(0.9999));
    assert_eq!(status, 200);
    assert_eq!(
        body.get("outcome").get("type").as_str(),
        Some("miss"),
        "hot tenant must not see cold tenant's entries: {body}"
    );

    let _ = std::fs::remove_file(&port_file);
}
