//! Wire-format and HTTP front-end tests for the v1 serving API:
//! JSON round-trip property tests over the `api` types (via the in-tree
//! choice-stream harness), malformed-request handling (4xx JSON errors,
//! never panics), and an end-to-end miss→hit flow over a real loopback
//! socket.

use std::sync::Arc;
use std::time::Duration;

use semcache::api::{LatencyBreakdown, Outcome, QueryRequest, QueryResponse};
use semcache::coordinator::{
    http_request, serve_http, HttpConfig, HttpHandle, Server, ServerConfig,
};
use semcache::embedding::NativeEncoder;
use semcache::json;
use semcache::runtime::ModelParams;
use semcache::testutil::{prop_check, Gen, PropConfig};

// ---------- wire-format property tests ----------

fn gen_text(g: &mut Gen) -> String {
    let words = g.usize_in(1, 6);
    (0..words).map(|_| g.word()).collect::<Vec<_>>().join(" ")
}

fn gen_request(g: &mut Gen) -> QueryRequest {
    let mut req = QueryRequest::new(gen_text(g));
    if g.bool() {
        req = req.with_cluster(g.u64() % (1 << 32));
    }
    if g.bool() {
        req = req.with_threshold(g.f32_in(-1.0, 1.0));
    }
    if g.bool() {
        req = req.with_ttl_ms(g.u64() % 1_000_000);
    }
    if g.bool() {
        req = req.with_top_k(g.usize_in(1, 64));
    }
    if g.bool() {
        req = req.with_client_tag(g.word());
    }
    if g.bool() {
        req = req.with_embed_bypass();
    }
    if g.bool() {
        req = req.with_deadline_ms(1 + g.u64() % 60_000);
    }
    req
}

fn gen_outcome(g: &mut Gen) -> Outcome {
    match g.usize_below(4) {
        0 => Outcome::Hit { score: g.f32_in(-1.0, 1.0), entry_id: 1 + g.u64() % (1 << 48) },
        1 => Outcome::Miss { inserted_id: 1 + g.u64() % (1 << 48) },
        2 => Outcome::Degraded { score: g.f32_in(-1.0, 1.0), entry_id: 1 + g.u64() % (1 << 48) },
        _ => Outcome::Rejected { reason: gen_text(g) },
    }
}

fn gen_response(g: &mut Gen) -> QueryResponse {
    QueryResponse {
        response: if g.bool() { gen_text(g) } else { String::new() },
        outcome: gen_outcome(g),
        latency: LatencyBreakdown {
            total_ms: g.f32_in(0.0, 5_000.0) as f64,
            embed_ms: g.f32_in(0.0, 100.0) as f64,
            index_ms: g.f32_in(0.0, 10.0) as f64,
            llm_ms: g.f32_in(0.0, 5_000.0) as f64,
            embed_cached: g.bool(),
            degraded: g.bool(),
        },
        judged_positive: if g.bool() { Some(g.bool()) } else { None },
        matched_cluster: if g.bool() { Some(g.u64() % (1 << 32)) } else { None },
        client_tag: if g.bool() { Some(g.word()) } else { None },
    }
}

#[test]
fn prop_query_request_json_roundtrip() {
    prop_check(PropConfig { cases: 128, ..Default::default() }, "request-json-roundtrip", |g| {
        let req = gen_request(g);
        let wire = req.to_json().to_string();
        let v = json::parse(&wire).map_err(|e| format!("reparse: {e}"))?;
        let back = QueryRequest::from_json(&v).map_err(|e| format!("decode: {e:#}"))?;
        if back != req {
            return Err(format!("roundtrip diverged: {req:?} -> {wire} -> {back:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_outcome_json_roundtrip() {
    prop_check(PropConfig { cases: 128, ..Default::default() }, "outcome-json-roundtrip", |g| {
        let o = gen_outcome(g);
        let wire = o.to_json().to_string();
        let v = json::parse(&wire).map_err(|e| format!("reparse: {e}"))?;
        let back = Outcome::from_json(&v).map_err(|e| format!("decode: {e:#}"))?;
        if back != o {
            return Err(format!("roundtrip diverged: {o:?} -> {wire} -> {back:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_query_response_json_roundtrip() {
    prop_check(PropConfig { cases: 128, ..Default::default() }, "response-json-roundtrip", |g| {
        let resp = gen_response(g);
        let wire = resp.to_json().to_string();
        let v = json::parse(&wire).map_err(|e| format!("reparse: {e}"))?;
        let back = QueryResponse::from_json(&v).map_err(|e| format!("decode: {e:#}"))?;
        if back != resp {
            return Err(format!("roundtrip diverged: {resp:?} -> {wire} -> {back:?}"));
        }
        Ok(())
    });
}

// ---------- HTTP front-end over a real loopback socket ----------

fn tiny_server() -> Arc<Server> {
    let mut p = ModelParams::default();
    p.layers = 1;
    p.vocab_size = 1024;
    p.dim = 96;
    p.hidden = 192;
    p.heads = 4;
    Arc::new(Server::new(Arc::new(NativeEncoder::new(p)), ServerConfig::default()))
}

fn start_front_end() -> (HttpHandle, String) {
    start_front_end_with(true)
}

fn start_front_end_with(batching: bool) -> (HttpHandle, String) {
    let handle = serve_http(
        tiny_server(),
        HttpConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            max_body_bytes: 64 * 1024,
            read_timeout: Duration::from_secs(2),
            batching,
            ..HttpConfig::default()
        },
    )
    .expect("bind ephemeral port");
    let addr = handle.local_addr().to_string();
    // Readiness handshake instead of a sleep: `serve_http` returns with
    // the listener bound, but on a loaded machine we still confirm the
    // accept/worker pipeline answers before the test starts hammering it
    // (mirrors the --port-file + health-poll handshake verify.sh uses).
    for _ in 0..50 {
        if let Ok((200, _)) = http_request(&addr, "GET", "/v1/health", None) {
            return (handle, addr);
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("front-end at {addr} did not become healthy");
}

#[test]
fn http_miss_then_hit_with_metrics() {
    let (handle, addr) = start_front_end();

    let body = QueryRequest::new("how do i reset my password").to_json().to_string();
    let (status, v1) = http_request(&addr, "POST", "/v1/query", Some(&body)).unwrap();
    assert_eq!(status, 200);
    assert_eq!(v1.get("outcome").get("type").as_str(), Some("miss"), "first query: {v1}");
    let first_response = v1.get("response").as_str().expect("response text").to_string();

    // A semantically similar paraphrase is answered from cache, without
    // a simulated-LLM call.
    let body = QueryRequest::new("how can i reset my password").to_json().to_string();
    let (status, v2) = http_request(&addr, "POST", "/v1/query", Some(&body)).unwrap();
    assert_eq!(status, 200);
    assert_eq!(v2.get("outcome").get("type").as_str(), Some("hit"), "paraphrase: {v2}");
    assert!(
        v2.get("outcome").get("score").as_f64().expect("score") >= 0.8,
        "hit score clears the configured threshold: {v2}"
    );
    assert_eq!(v2.get("response").as_str(), Some(first_response.as_str()));
    assert_eq!(v2.get("latency").get("llm_ms").as_f64(), Some(0.0), "hits skip the LLM");

    // GET /v1/metrics reflects the hit.
    let (status, m) = http_request(&addr, "GET", "/v1/metrics", None).unwrap();
    assert_eq!(status, 200);
    let mm = m.get("metrics");
    assert_eq!(mm.get("requests").as_usize(), Some(2));
    assert_eq!(mm.get("cache_hits").as_usize(), Some(1));
    assert_eq!(mm.get("llm_calls").as_usize(), Some(1));
    assert!(mm.get("http_requests").as_usize().expect("http_requests") >= 3);
    assert_eq!(m.get("cache_entries").as_usize(), Some(1));

    handle.shutdown();
}

#[test]
fn http_batch_endpoint_preserves_order() {
    let (handle, addr) = start_front_end();
    let queries: Vec<json::Value> = (0..6)
        .map(|i| QueryRequest::new(format!("batch probe number {i} zulu")).to_json())
        .collect();
    let body = json::obj([("queries", json::Value::Array(queries))]).to_string();
    let (status, v) = http_request(&addr, "POST", "/v1/query_batch", Some(&body)).unwrap();
    assert_eq!(status, 200);
    let replies = v.get("replies").as_array().expect("replies array");
    assert_eq!(replies.len(), 6);
    for r in replies {
        assert_eq!(r.get("outcome").get("type").as_str(), Some("miss"), "{r}");
    }
    // Same batch again: every distinct probe now hits.
    let queries: Vec<json::Value> = (0..6)
        .map(|i| QueryRequest::new(format!("batch probe number {i} zulu")).to_json())
        .collect();
    let body = json::obj([("queries", json::Value::Array(queries))]).to_string();
    let (_, v) = http_request(&addr, "POST", "/v1/query_batch", Some(&body)).unwrap();
    for r in v.get("replies").as_array().unwrap() {
        assert_eq!(r.get("outcome").get("type").as_str(), Some("hit"), "{r}");
    }
    handle.shutdown();
}

#[test]
fn http_unbatched_path_still_serves_miss_then_hit() {
    // `batching: false` is the PR 2 isolated-serve() path; it must stay
    // fully functional (it is the bench baseline and an operator escape
    // hatch via `semcached serve --no-batch`).
    let (handle, addr) = start_front_end_with(false);
    let body = QueryRequest::new("how do i reset my password").to_json().to_string();
    let (status, v1) = http_request(&addr, "POST", "/v1/query", Some(&body)).unwrap();
    assert_eq!(status, 200);
    assert_eq!(v1.get("outcome").get("type").as_str(), Some("miss"), "{v1}");
    let body = QueryRequest::new("how can i reset my password").to_json().to_string();
    let (status, v2) = http_request(&addr, "POST", "/v1/query", Some(&body)).unwrap();
    assert_eq!(status, 200);
    assert_eq!(v2.get("outcome").get("type").as_str(), Some("hit"), "{v2}");
    let (_, m) = http_request(&addr, "GET", "/v1/metrics", None).unwrap();
    let mm = m.get("metrics");
    assert_eq!(mm.get("batcher_dispatches").as_usize(), Some(0), "no batcher on this path");
    assert_eq!(mm.get("cache_hits").as_usize(), Some(1));
    handle.shutdown();
}

#[test]
fn http_batched_path_reports_batcher_metrics() {
    let (handle, addr) = start_front_end();
    for i in 0..3 {
        let body = QueryRequest::new(format!("batcher metrics probe {i} lima"))
            .to_json()
            .to_string();
        let (status, _) = http_request(&addr, "POST", "/v1/query", Some(&body)).unwrap();
        assert_eq!(status, 200);
    }
    let (_, m) = http_request(&addr, "GET", "/v1/metrics", None).unwrap();
    let mm = m.get("metrics");
    let dispatches = mm.get("batcher_dispatches").as_usize().expect("batcher_dispatches");
    assert!((1..=3).contains(&dispatches), "3 sequential queries -> 1..=3 dispatches");
    assert_eq!(mm.get("batcher_queries").as_usize(), Some(3));
    assert_eq!(mm.get("requests").as_usize(), Some(3));
    handle.shutdown();
}

#[test]
fn http_per_request_threshold_rides_the_wire() {
    let (handle, addr) = start_front_end();
    let body = QueryRequest::new("tell me about the acme laptop").to_json().to_string();
    http_request(&addr, "POST", "/v1/query", Some(&body)).unwrap();
    // Unrelated query under a lenient per-request threshold: hit.
    let body = QueryRequest::new("completely different topic entirely")
        .with_threshold(-1.0)
        .to_json()
        .to_string();
    let (status, v) = http_request(&addr, "POST", "/v1/query", Some(&body)).unwrap();
    assert_eq!(status, 200);
    assert_eq!(v.get("outcome").get("type").as_str(), Some("hit"), "{v}");
    handle.shutdown();
}

#[test]
fn http_admin_flush_empties_the_cache() {
    let (handle, addr) = start_front_end();
    let body = QueryRequest::new("a question worth caching").to_json().to_string();
    http_request(&addr, "POST", "/v1/query", Some(&body)).unwrap();
    let (_, m) = http_request(&addr, "GET", "/v1/metrics", None).unwrap();
    assert_eq!(m.get("cache_entries").as_usize(), Some(1));

    let (status, v) =
        http_request(&addr, "POST", "/v1/admin", Some(r#"{"action": "flush"}"#)).unwrap();
    assert_eq!(status, 200);
    assert_eq!(v.get("removed").as_usize(), Some(1), "{v}");
    let (_, m) = http_request(&addr, "GET", "/v1/metrics", None).unwrap();
    assert_eq!(m.get("cache_entries").as_usize(), Some(0));

    // Housekeep and stats also answer 200 with typed bodies.
    let (status, v) =
        http_request(&addr, "POST", "/v1/admin", Some(r#"{"action": "housekeep"}"#)).unwrap();
    assert_eq!(status, 200);
    assert!(v.get("expired").as_usize().is_some(), "{v}");
    let (status, v) =
        http_request(&addr, "POST", "/v1/admin", Some(r#"{"action": "stats"}"#)).unwrap();
    assert_eq!(status, 200);
    assert!(v.get("metrics").get("requests").as_usize().is_some(), "{v}");
    handle.shutdown();
}

#[test]
fn http_malformed_requests_get_4xx_json_not_panics() {
    let (handle, addr) = start_front_end();

    // Bad JSON body.
    let (status, v) = http_request(&addr, "POST", "/v1/query", Some("{not json")).unwrap();
    assert_eq!(status, 400);
    assert!(v.get("error").as_str().unwrap().contains("invalid JSON"), "{v}");

    // Missing required field.
    let (status, v) = http_request(&addr, "POST", "/v1/query", Some(r#"{"cluster": 3}"#)).unwrap();
    assert_eq!(status, 400);
    assert!(v.get("error").as_str().unwrap().contains("text"), "{v}");

    // Invalid option values.
    let (status, v) =
        http_request(&addr, "POST", "/v1/query", Some(r#"{"text": "q", "top_k": 0}"#)).unwrap();
    assert_eq!(status, 400);
    assert!(v.get("error").as_str().unwrap().contains("top_k"), "{v}");

    // Batch body without the queries array / with a bad element.
    let (status, _) = http_request(&addr, "POST", "/v1/query_batch", Some(r#"{}"#)).unwrap();
    assert_eq!(status, 400);
    let (status, v) = http_request(
        &addr,
        "POST",
        "/v1/query_batch",
        Some(r#"{"queries": [{"text": "ok"}, {"nope": 1}]}"#),
    )
    .unwrap();
    assert_eq!(status, 400);
    assert!(v.get("error").as_str().unwrap().contains("queries[1]"), "{v}");

    // Unknown admin action.
    let (status, _) =
        http_request(&addr, "POST", "/v1/admin", Some(r#"{"action": "reboot"}"#)).unwrap();
    assert_eq!(status, 400);

    // Unknown path / wrong method.
    let (status, _) = http_request(&addr, "GET", "/v2/query", None).unwrap();
    assert_eq!(status, 404);
    let (status, _) = http_request(&addr, "GET", "/v1/query", None).unwrap();
    assert_eq!(status, 405);

    // Oversized body: 100 KB against a 64 KB limit.
    let huge = format!(r#"{{"text": "{}"}}"#, "a".repeat(100_000));
    let (status, v) = http_request(&addr, "POST", "/v1/query", Some(&huge)).unwrap();
    assert_eq!(status, 413, "{v}");

    // The server is still healthy after all of that.
    let (status, v) = http_request(&addr, "GET", "/v1/health", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(v.get("status").as_str(), Some("ok"));
    let (_, m) = http_request(&addr, "GET", "/v1/metrics", None).unwrap();
    assert!(m.get("metrics").get("http_errors").as_usize().unwrap() >= 8);

    handle.shutdown();
}

#[test]
fn http_keep_alive_serves_sequential_requests_on_one_connection() {
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;

    let (handle, addr) = start_front_end();
    let stream = TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    for i in 0..3 {
        let body = format!(r#"{{"text": "keep alive probe {i} tango"}}"#);
        write!(
            writer,
            "POST /v1/query HTTP/1.1\r\nHost: loopback\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        writer.flush().unwrap();

        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("HTTP/1.1 200"), "request {i}: {line}");
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, val)) = h.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    content_length = val.trim().parse().unwrap();
                }
            }
        }
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body).unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("outcome").get("type").as_str(), Some("miss"), "probe {i}");
    }
    handle.shutdown();
}
