//! Embedding hot-path parity + memo-tier serving tests (ISSUE 4).
//!
//! The encoder overhaul (scratch arena, parallel `encode_batch`, memo
//! tier) claims **bit-identical** output to the seed forward pass. The
//! oracle here is `seed_encode_ids`: a line-for-line re-implementation
//! of the seed `NativeEncoder::encode_ids` — naive per-call allocations,
//! full `x.clone()` before the final LayerNorm, identical formulas in
//! identical floating-point operation order. The property test drives
//! random texts, batch sizes, worker counts, memoization, and bypass
//! flags through the production paths and requires exact equality
//! against the oracle.

use std::sync::Arc;

use semcache::api::{AdminRequest, Outcome, QueryRequest};
use semcache::coordinator::{Server, ServerConfig};
use semcache::embedding::{Encoder, MemoConfig, NativeEncoder};
use semcache::runtime::ModelParams;
use semcache::testutil::{prop_check, Gen, PropConfig};
use semcache::tokenizer::PAD_ID;
use semcache::util::dot;

// ---------- the seed forward pass, reproduced naively ----------

const LN_EPS: f32 = 1e-6;

fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

fn layer_norm_rows(x: &[f32], out: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let mu = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|e| (e - mu) * (e - mu)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for c in 0..cols {
            out[r * cols + c] = (row[c] - mu) * inv;
        }
    }
}

fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, inner: usize, cols: usize) {
    for i in 0..rows {
        let a_row = &a[i * inner..(i + 1) * inner];
        let o_row = &mut out[i * cols..(i + 1) * cols];
        for (kk, &aik) in a_row.iter().enumerate() {
            let b_row = &b[kk * cols..(kk + 1) * cols];
            for j in 0..cols {
                o_row[j] += aik * b_row[j];
            }
        }
    }
}

fn matmul(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, inner: usize, cols: usize) {
    out.fill(0.0);
    matmul_acc(a, b, out, rows, inner, cols);
}

#[allow(clippy::too_many_arguments)]
fn attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: &[f32],
    out: &mut [f32],
    s: usize,
    heads: usize,
    dh: usize,
) {
    let d = heads * dh;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut scores = vec![0.0f32; s];
    for hd in 0..heads {
        let off = hd * dh;
        for i in 0..s {
            let qi = &q[i * d + off..i * d + off + dh];
            let mut max = f32::MIN;
            for j in 0..s {
                let kj = &k[j * d + off..j * d + off + dh];
                let mut sc = dot(qi, kj) * scale;
                sc += (1.0 - mask[j]) * -1e9;
                scores[j] = sc;
                if sc > max {
                    max = sc;
                }
            }
            let mut sum = 0.0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - max).exp();
                sum += *sc;
            }
            let inv = 1.0 / sum;
            let o = &mut out[i * d + off..i * d + off + dh];
            o.fill(0.0);
            for j in 0..s {
                let w = scores[j] * inv;
                let vj = &v[j * d + off..j * d + off + dh];
                for c in 0..dh {
                    o[c] += w * vj[c];
                }
            }
        }
    }
}

/// The seed `NativeEncoder::encode_ids`, allocations and all.
fn seed_encode_ids(enc: &NativeEncoder, ids: &[i64]) -> Vec<f32> {
    use semcache::embedding::EncoderWeights;
    let w = enc.weights();
    let p = &w.params;
    assert_eq!(ids.len(), p.seq_len);
    let (s, d, h) = (p.seq_len, p.dim, p.hidden);
    let heads = p.heads;
    let dh = d / heads;

    let mut x = vec![0.0f32; s * d];
    for (i, &t) in ids.iter().enumerate() {
        let row = w.embed_row(t);
        let pos = &w.pos[i * d..(i + 1) * d];
        for j in 0..d {
            x[i * d + j] = row[j] + pos[j];
        }
    }
    let mask: Vec<f32> = ids.iter().map(|&t| if t == PAD_ID { 0.0 } else { 1.0 }).collect();

    let mut hbuf = vec![0.0f32; s * d];
    let mut q = vec![0.0f32; s * d];
    let mut k = vec![0.0f32; s * d];
    let mut v = vec![0.0f32; s * d];
    let mut ctx = vec![0.0f32; s * d];
    let mut ffn_h = vec![0.0f32; s * h];

    for l in 0..p.layers {
        layer_norm_rows(&x, &mut hbuf, s, d);
        let wq = EncoderWeights::layer(&w.wq, l, d, d);
        let wk = EncoderWeights::layer(&w.wk, l, d, d);
        let wv = EncoderWeights::layer(&w.wv, l, d, d);
        let wo = EncoderWeights::layer(&w.wo, l, d, d);
        matmul(&hbuf, wq, &mut q, s, d, d);
        matmul(&hbuf, wk, &mut k, s, d, d);
        matmul(&hbuf, wv, &mut v, s, d, d);
        attention(&q, &k, &v, &mask, &mut ctx, s, heads, dh);
        matmul_acc(&ctx, wo, &mut x, s, d, d);

        layer_norm_rows(&x, &mut hbuf, s, d);
        let w1 = EncoderWeights::layer(&w.w1, l, d, h);
        let w2 = EncoderWeights::layer(&w.w2, l, h, d);
        matmul(&hbuf, w1, &mut ffn_h, s, d, h);
        for e in ffn_h.iter_mut() {
            *e = gelu(*e);
        }
        matmul_acc(&ffn_h, w2, &mut x, s, h, d);
    }

    layer_norm_rows(&x.clone(), &mut x, s, d);

    let denom = mask.iter().sum::<f32>().max(1.0);
    let mut pooled = vec![0.0f32; d];
    for i in 0..s {
        if mask[i] > 0.0 {
            for j in 0..d {
                pooled[j] += x[i * d + j];
            }
        }
    }
    for e in pooled.iter_mut() {
        *e /= denom;
    }
    let n = dot(&pooled, &pooled).sqrt().max(1e-12);
    for e in pooled.iter_mut() {
        *e /= n;
    }
    pooled
}

// ---------- parity property test ----------

fn small_params() -> ModelParams {
    let mut p = ModelParams::default();
    p.layers = 2;
    p.vocab_size = 512;
    p.dim = 96;
    p.hidden = 192;
    p.heads = 4;
    p
}

fn gen_text(g: &mut Gen) -> String {
    // 0 words = empty text (CLS-only sequence) is a legal encoder input
    // and must stay covered.
    let words = g.usize_in(0, 12);
    (0..words).map(|_| g.word()).collect::<Vec<_>>().join(" ")
}

#[test]
fn prop_hotpath_bit_identical_to_seed_forward_pass() {
    let p = small_params();
    let plain = NativeEncoder::new(p.clone());
    let memoized = NativeEncoder::new(p)
        .with_memo(MemoConfig { capacity: 64, shards: 2 })
        .unwrap();
    prop_check(
        PropConfig { cases: 24, ..Default::default() },
        "embed-hotpath-parity",
        |g| {
            let n = g.usize_in(1, 10);
            let texts: Vec<String> = (0..n).map(|_| gen_text(g)).collect();
            let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
            let want: Vec<Vec<f32>> = refs
                .iter()
                .map(|t| seed_encode_ids(&plain, &plain.tokenizer().encode(t)))
                .collect();

            // Arena path (thread-local scratch).
            let ids0 = plain.tokenizer().encode(refs[0]);
            if plain.encode_ids(&ids0) != want[0] {
                return Err("encode_ids (arena) diverged from the seed".into());
            }
            // Parallel batch at a random pool width.
            let workers = g.usize_in(1, 4);
            if plain.encode_batch_with_workers(&refs, workers) != want {
                return Err(format!("encode_batch at {workers} workers diverged from the seed"));
            }
            // Memoized path (random bypass): texts repeat across cases,
            // so this round-trips cold inserts and warm hits alike.
            let bypass = g.bool();
            let tracked = memoized.encode_batch_tracked(&refs, bypass);
            for (i, (o, w)) in tracked.iter().zip(&want).enumerate() {
                if &o.embedding != w {
                    return Err(format!(
                        "memoized encode (bypass={bypass}, memo_hit={}) diverged at {i}",
                        o.memo_hit
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn repeated_words_and_truncation_keep_parity() {
    // Directed edge cases the random generator rarely builds: heavy
    // repetition (memo-key stress) and >seq_len inputs (truncation).
    let p = small_params();
    let enc = NativeEncoder::new(p)
        .with_memo(MemoConfig { capacity: 8, shards: 1 })
        .unwrap();
    let long: String = (0..100).map(|i| format!("w{i} ")).collect();
    let texts = vec![
        "".to_string(),
        "same same same same".to_string(),
        long.clone(),
        long, // duplicate of the truncated text
        "same same same same".to_string(),
    ];
    let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
    let want: Vec<Vec<f32>> = refs
        .iter()
        .map(|t| seed_encode_ids(&enc, &enc.tokenizer().encode(t)))
        .collect();
    // Twice: cold pass, then fully memoized pass — both must be exact.
    for round in 0..2 {
        let got = enc.encode_batch_tracked(&refs, false);
        for (i, (o, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(&o.embedding, w, "round {round} text {i}");
        }
    }
}

// ---------- memo tier through the serving stack ----------

fn memo_server() -> Arc<Server> {
    let enc = NativeEncoder::new(small_params())
        .with_memo(MemoConfig { capacity: 256, shards: 4 })
        .unwrap();
    Arc::new(Server::new(Arc::new(enc), ServerConfig::default()))
}

#[test]
fn serve_repeat_query_rides_the_memo_and_admin_flush_clears_it() {
    let s = memo_server();
    let q = QueryRequest::new("how do i reset my password");
    let r1 = s.serve(&q);
    assert!(matches!(r1.outcome, Outcome::Miss { .. }));
    assert!(!r1.latency.embed_cached, "first sight pays the forward pass");

    let r2 = s.serve(&q);
    assert!(r2.is_hit(), "verbatim repeat hits the semantic cache");
    assert!(r2.latency.embed_cached, "…and its embedding came from the memo");

    // Per-request bypass: same answer, cold embed path.
    let r3 = s.serve(&QueryRequest::new("how do i reset my password").with_embed_bypass());
    assert!(r3.is_hit());
    assert!(!r3.latency.embed_cached, "bypass skips the memo read");
    assert_eq!(r3.response, r2.response);

    let m = s.metrics().snapshot();
    assert_eq!(m.embed_cache_hits, 1);
    assert_eq!(m.embed_cache_misses, 2);
    assert_eq!(m.lat_embed_memo.n, 1, "memo-hit latency histogram observed once");

    // The memo tier is visible in stats and emptied by admin flush.
    let stats = s.stats_json();
    assert_eq!(stats.get("embed_memo").get("entries").as_usize(), Some(1));
    s.admin(&AdminRequest::Flush);
    let c = s.encoder().memo_counters().expect("memoized encoder");
    assert_eq!(c.entries, 0, "admin flush empties the memo tier");

    // Post-flush repeat re-encodes (a fresh embed-cache miss)…
    let r4 = s.serve(&q);
    assert!(!r4.latency.embed_cached);
    // …and the semantic cache was flushed too, so it misses and re-inserts.
    assert!(matches!(r4.outcome, Outcome::Miss { .. }));
}

#[test]
fn batch_pipeline_reports_memo_hits_per_query() {
    let s = memo_server();
    let texts = ["alpha question one", "beta question two", "gamma question three"];
    let reqs: Vec<QueryRequest> = texts.iter().map(|t| QueryRequest::new(*t)).collect();
    let first = s.serve_batch(&reqs);
    assert!(first.iter().all(|r| !r.latency.embed_cached), "cold batch");

    let second = s.serve_batch(&reqs);
    assert!(second.iter().all(|r| r.latency.embed_cached), "warm batch all memo hits");
    assert!(second.iter().all(|r| r.is_hit()));

    // A mixed batch: one request opts out of the memo read; the chunk
    // falls back to per-request encodes and flags stay per-request.
    let mixed = vec![
        QueryRequest::new("alpha question one"),
        QueryRequest::new("beta question two").with_embed_bypass(),
        QueryRequest::new("gamma question three"),
    ];
    let out = s.serve_batch_with_workers(&mixed, 1);
    assert!(out[0].latency.embed_cached);
    assert!(!out[1].latency.embed_cached, "bypassed request is cold");
    assert!(out[2].latency.embed_cached);

    let m = s.metrics().snapshot();
    // 3 cold + 3 warm + (2 warm + 1 bypass) = 5 hits, 4 misses.
    assert_eq!(m.embed_cache_hits, 5);
    assert_eq!(m.embed_cache_misses, 4);
    assert_eq!(m.embed_cache_hits + m.embed_cache_misses, m.requests);
}

#[test]
fn memoless_server_counts_every_embed_as_miss() {
    // Servers without a memo tier keep the invariant
    // embed_cache_hits + embed_cache_misses == served (non-rejected)
    // requests, with zero hits.
    let s = Arc::new(Server::new(
        Arc::new(NativeEncoder::new(small_params())),
        ServerConfig::default(),
    ));
    let q = QueryRequest::new("no memo here");
    s.serve(&q);
    s.serve(&q);
    let m = s.metrics().snapshot();
    assert_eq!(m.embed_cache_hits, 0);
    assert_eq!(m.embed_cache_misses, 2);
    assert!(s.encoder().memo_counters().is_none());
    assert!(s.stats_json().get("embed_memo").is_null());
}
