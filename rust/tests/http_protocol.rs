//! Adversarial HTTP protocol and concurrency tests for the wire
//! front-end (ISSUE 5), run against live loopback servers in **both**
//! serving modes (event loop and threaded accept), plus the event
//! loop's portable `poll(2)` fallback backend:
//!
//! * slow-drip byte-at-a-time request delivery;
//! * pipelined requests on one connection (served in order);
//! * HTTP/1.0 vs HTTP/1.1 keep-alive semantics (`Connection` header
//!   included);
//! * garbage-prefix framing and newline-less floods (400/431);
//! * oversized header lines (431) and oversized bodies (413);
//! * the idle-connection starvation regression: 4× more idle keep-alive
//!   connections than workers must NOT delay a fresh query on the event
//!   loop, and must starve it on the threaded-accept path (the exact
//!   limitation the reactor fixes);
//! * the `max_conns` accept-time 503 budget;
//! * a seeded property test replaying random request traces through
//!   event-loop HTTP vs direct `serve()` (outcome- and
//!   counter-identical — the PR 3 parity convention on the new wire);
//! * a directed short-write regression for `write_response` over a
//!   tiny-`SO_SNDBUF` nonblocking socket.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use semcache::api::{Outcome, QueryRequest};
use semcache::coordinator::{
    http_request, serve_http, HttpConfig, HttpHandle, Server, ServerConfig,
};
use semcache::embedding::NativeEncoder;
use semcache::json;
use semcache::runtime::ModelParams;
use semcache::testutil::{prop_check, Gen, PropConfig};

fn tiny_server() -> Arc<Server> {
    let mut p = ModelParams::default();
    p.layers = 1;
    p.vocab_size = 1024;
    p.dim = 96;
    p.hidden = 192;
    p.heads = 4;
    Arc::new(Server::new(Arc::new(NativeEncoder::new(p)), ServerConfig::default()))
}

/// Start a front-end with test-suite defaults, tweaked by `adjust`, and
/// wait for it to answer health checks.
fn start_with(adjust: impl FnOnce(&mut HttpConfig)) -> (HttpHandle, String) {
    let mut cfg = HttpConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        max_body_bytes: 64 * 1024,
        read_timeout: Duration::from_secs(5),
        ..HttpConfig::default()
    };
    adjust(&mut cfg);
    let handle = serve_http(tiny_server(), cfg).expect("bind ephemeral port");
    let addr = handle.local_addr().to_string();
    for _ in 0..100 {
        if let Ok((200, _)) = http_request(&addr, "GET", "/v1/health", None) {
            return (handle, addr);
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("front-end at {addr} did not become healthy");
}

// ---------------------------------------------------------------------
// A raw HTTP client that controls framing byte-for-byte.
// ---------------------------------------------------------------------

struct RawResponse {
    status: u16,
    /// Lower-cased `Connection` header value ("" if absent).
    connection: String,
    /// Parsed `Retry-After` header, seconds (`None` if absent). Every
    /// 503 — queue-full, over-max_conns, upstream-unavailable — must
    /// carry one.
    retry_after: Option<u64>,
    body: String,
}

struct RawClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

fn find(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}

impl RawClient {
    fn connect(addr: &str) -> Self {
        let stream = TcpStream::connect(addr).expect("connect loopback");
        stream.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
        let _ = stream.set_nodelay(true);
        Self { stream, buf: Vec::new() }
    }

    fn send(&mut self, bytes: &[u8]) {
        self.stream.write_all(bytes).expect("write to server");
        self.stream.flush().expect("flush to server");
    }

    fn fill(&mut self) -> usize {
        let mut chunk = [0u8; 4096];
        let n = self.stream.read(&mut chunk).expect("read from server");
        self.buf.extend_from_slice(&chunk[..n]);
        n
    }

    /// Read exactly one response off the connection (leaving any
    /// pipelined follow-up bytes buffered).
    fn read_response(&mut self) -> RawResponse {
        let header_end = loop {
            if let Some(i) = find(&self.buf, b"\r\n\r\n") {
                break i + 4;
            }
            assert!(
                self.fill() > 0,
                "connection closed before response headers completed: {:?}",
                String::from_utf8_lossy(&self.buf)
            );
        };
        let head = String::from_utf8_lossy(&self.buf[..header_end]).to_string();
        let mut lines = head.split("\r\n");
        let status_line = lines.next().expect("status line");
        assert!(status_line.starts_with("HTTP/1.1 "), "status line: {status_line:?}");
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("malformed status line {status_line:?}"));
        let mut content_length = 0usize;
        let mut connection = String::new();
        let mut retry_after = None;
        for l in lines {
            if let Some((k, v)) = l.split_once(':') {
                let k = k.trim().to_ascii_lowercase();
                if k == "content-length" {
                    content_length = v.trim().parse().expect("content-length value");
                } else if k == "connection" {
                    connection = v.trim().to_ascii_lowercase();
                } else if k == "retry-after" {
                    retry_after = Some(v.trim().parse().expect("retry-after seconds"));
                }
            }
        }
        while self.buf.len() < header_end + content_length {
            assert!(self.fill() > 0, "connection closed mid-response-body");
        }
        let body =
            String::from_utf8_lossy(&self.buf[header_end..header_end + content_length]).to_string();
        self.buf.drain(..header_end + content_length);
        let resp = RawResponse { status, connection, retry_after, body };
        // Protocol-wide invariant, checked on every raw read: 503s are
        // backpressure and always advertise when to retry; success
        // responses never carry the header.
        if resp.status == 503 {
            assert!(
                resp.retry_after.is_some(),
                "503 without a Retry-After header: {}",
                resp.body
            );
        } else if resp.status == 200 {
            assert_eq!(resp.retry_after, None, "200 with a Retry-After header: {}", resp.body);
        }
        resp
    }

    /// Assert the server closes the connection (no further bytes).
    fn assert_closed(&mut self) {
        assert!(self.buf.is_empty(), "unexpected buffered bytes before close");
        let mut chunk = [0u8; 64];
        match self.stream.read(&mut chunk) {
            Ok(0) => {}
            Ok(n) => panic!(
                "expected the server to close, got {n} more bytes: {:?}",
                String::from_utf8_lossy(&chunk[..n])
            ),
            Err(e) => panic!("expected a clean close, got {e}"),
        }
    }
}

fn post_query_raw(text: &str, tag: &str) -> Vec<u8> {
    let body = QueryRequest::new(text).with_client_tag(tag).to_json().to_string();
    format!(
        "POST /v1/query HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .into_bytes()
}

fn health_raw(version: &str, extra_headers: &str) -> Vec<u8> {
    format!("GET /v1/health {version}\r\nHost: t\r\n{extra_headers}\r\n").into_bytes()
}

// ---------------------------------------------------------------------
// The protocol suite, shared by every mode/backend combination.
// ---------------------------------------------------------------------

fn run_protocol_suite(event_loop: bool, poll_fallback: bool, reactors: usize, dispatchers: usize) {
    let (handle, addr) = start_with(|c| {
        c.event_loop = event_loop;
        c.poll_fallback = poll_fallback;
        c.reactors = reactors;
        c.dispatchers = dispatchers;
    });

    // --- slow-drip: the request arrives one byte at a time.
    {
        let mut c = RawClient::connect(&addr);
        let raw = post_query_raw("slow drip probe query", "drip");
        for b in &raw {
            c.send(&[*b]);
            std::thread::sleep(Duration::from_millis(1));
        }
        let resp = c.read_response();
        assert_eq!(resp.status, 200, "slow-drip body: {}", resp.body);
        let v = json::parse(&resp.body).expect("json body");
        assert_eq!(v.get("outcome").get("type").as_str(), Some("miss"), "{v}");
        assert_eq!(v.get("client_tag").as_str(), Some("drip"));
    }

    // --- pipelining: three requests in one write, three responses in order.
    {
        let mut c = RawClient::connect(&addr);
        let mut blob = Vec::new();
        for i in 0..3 {
            blob.extend_from_slice(&post_query_raw(
                &format!("pipeline probe number {i} quebec"),
                &format!("p{i}"),
            ));
        }
        c.send(&blob);
        for i in 0..3 {
            let resp = c.read_response();
            assert_eq!(resp.status, 200, "pipelined response {i}: {}", resp.body);
            let v = json::parse(&resp.body).expect("json body");
            assert_eq!(
                v.get("client_tag").as_str(),
                Some(format!("p{i}").as_str()),
                "pipelined responses must come back in request order: {v}"
            );
        }
    }

    // --- pipelining + half-close: a client that sends two requests and
    //     shuts down its write side still gets both answers, then EOF.
    {
        let mut c = RawClient::connect(&addr);
        let mut blob = Vec::new();
        blob.extend_from_slice(&post_query_raw("half close probe one x-ray", "hc0"));
        blob.extend_from_slice(&post_query_raw("half close probe two yankee", "hc1"));
        c.send(&blob);
        c.stream.shutdown(std::net::Shutdown::Write).expect("half-close");
        for i in 0..2 {
            let resp = c.read_response();
            assert_eq!(resp.status, 200, "half-close response {i}: {}", resp.body);
            let v = json::parse(&resp.body).expect("json body");
            assert_eq!(
                v.get("client_tag").as_str(),
                Some(format!("hc{i}").as_str()),
                "buffered pipelined requests must all be served after a half-close: {v}"
            );
        }
        c.assert_closed();
    }

    // --- keep-alive semantics: version default + Connection overrides.
    {
        // HTTP/1.1 default: stays open for a second request.
        let mut c = RawClient::connect(&addr);
        c.send(&health_raw("HTTP/1.1", ""));
        let r = c.read_response();
        assert_eq!((r.status, r.connection.as_str()), (200, "keep-alive"));
        c.send(&health_raw("HTTP/1.1", ""));
        assert_eq!(c.read_response().status, 200);

        // HTTP/1.0 default: closes after the response.
        let mut c = RawClient::connect(&addr);
        c.send(&health_raw("HTTP/1.0", ""));
        let r = c.read_response();
        assert_eq!((r.status, r.connection.as_str()), (200, "close"));
        c.assert_closed();

        // HTTP/1.0 + `Connection: keep-alive`: stays open.
        let mut c = RawClient::connect(&addr);
        c.send(&health_raw("HTTP/1.0", "Connection: keep-alive\r\n"));
        let r = c.read_response();
        assert_eq!((r.status, r.connection.as_str()), (200, "keep-alive"));
        c.send(&health_raw("HTTP/1.0", "Connection: keep-alive\r\n"));
        assert_eq!(c.read_response().status, 200);

        // HTTP/1.1 + `Connection: close`: closes.
        let mut c = RawClient::connect(&addr);
        c.send(&health_raw("HTTP/1.1", "Connection: close\r\n"));
        let r = c.read_response();
        assert_eq!((r.status, r.connection.as_str()), (200, "close"));
        c.assert_closed();
    }

    // --- garbage-prefix framing: not HTTP -> 400, then close.
    {
        let mut c = RawClient::connect(&addr);
        c.send(b"totally not http\r\n");
        let r = c.read_response();
        assert_eq!(r.status, 400, "{}", r.body);
        c.assert_closed();
    }

    // --- newline-less flood past the line limit -> 431, then close.
    {
        let mut c = RawClient::connect(&addr);
        c.send(&vec![b'z'; 9 * 1024]);
        let r = c.read_response();
        assert_eq!(r.status, 431, "{}", r.body);
        c.assert_closed();
    }

    // --- one oversized header line -> 431, then close.
    {
        let mut c = RawClient::connect(&addr);
        let mut raw = b"GET /v1/health HTTP/1.1\r\nX-Big: ".to_vec();
        raw.extend(std::iter::repeat(b'h').take(9 * 1024));
        raw.extend_from_slice(b"\r\n\r\n");
        c.send(&raw);
        let r = c.read_response();
        assert_eq!(r.status, 431, "{}", r.body);
        c.assert_closed();
    }

    // --- oversized body (declared 100 KB vs the 64 KB limit) -> 413.
    {
        let huge = format!(r#"{{"text": "{}"}}"#, "a".repeat(100_000));
        let (status, v) = http_request(&addr, "POST", "/v1/query", Some(&huge)).unwrap();
        assert_eq!(status, 413, "{v}");
    }

    // --- the server is healthy after all of that, and the abuse shows
    //     up in the front-end counters.
    let (status, _) = http_request(&addr, "GET", "/v1/health", None).unwrap();
    assert_eq!(status, 200);
    let (_, m) = http_request(&addr, "GET", "/v1/metrics", None).unwrap();
    let mm = m.get("metrics");
    assert!(
        mm.get("conns_accepted").as_usize().expect("conns_accepted") >= 10,
        "{m}"
    );
    assert!(mm.get("http_errors").as_usize().expect("http_errors") >= 4, "{m}");
    if event_loop {
        assert!(
            mm.get("parse_stalls").as_usize().expect("parse_stalls") >= 1,
            "byte-at-a-time delivery must register parse stalls: {m}"
        );
    }
    handle.shutdown();
}

// The event-loop-dependent tests are unix-only (elsewhere `serve_http`
// silently degrades to threaded accept, which these tests exist to
// contrast against).
#[cfg(unix)]
#[test]
fn protocol_suite_event_loop() {
    run_protocol_suite(true, false, 1, 1);
}

#[cfg(unix)]
#[test]
fn protocol_suite_event_loop_poll_fallback() {
    run_protocol_suite(true, true, 1, 1);
}

/// The full adversarial matrix against the sharded wire path: 4 reactor
/// threads (rotating listener handoff) over 2 hash-sharded batcher
/// dispatchers. Every framing, keep-alive, and abuse behavior must be
/// indistinguishable from the single-threaded loop.
#[cfg(unix)]
#[test]
fn protocol_suite_multi_reactor_sharded_dispatch() {
    run_protocol_suite(true, false, 4, 2);
}

#[test]
fn protocol_suite_threaded_accept() {
    run_protocol_suite(false, false, 1, 1);
}

// ---------------------------------------------------------------------
// Idle-connection starvation regression.
// ---------------------------------------------------------------------

/// 4× more idle keep-alive connections than workers. The event loop
/// must serve a fresh query promptly anyway; the threaded-accept path
/// must starve it (each idle socket pins a pool worker until the read
/// timeout) — proving the reactor fixes a real, demonstrated failure.
#[cfg(unix)]
#[test]
fn idle_keepalive_connections_starve_threaded_accept_but_not_event_loop() {
    const WORKERS: usize = 2;
    const IDLE: usize = 8;

    // Event loop: idle connections cost an fd, not a worker.
    {
        let (handle, addr) = start_with(|c| {
            c.event_loop = true;
            c.workers = WORKERS;
            c.read_timeout = Duration::from_secs(10);
        });
        let held: Vec<TcpStream> =
            (0..IDLE).map(|_| TcpStream::connect(&addr).expect("idle conn")).collect();
        std::thread::sleep(Duration::from_millis(300)); // reactor registers them

        let t0 = Instant::now();
        let body = QueryRequest::new("starvation probe event loop").to_json().to_string();
        let (status, v) = http_request(&addr, "POST", "/v1/query", Some(&body)).expect("query");
        let elapsed = t0.elapsed();
        assert_eq!(status, 200, "{v}");
        assert!(
            elapsed < Duration::from_secs(5),
            "event loop took {elapsed:?} with {IDLE} idle connections"
        );

        // The open-connections gauge sees the idle fleet.
        let (_, m) = http_request(&addr, "GET", "/v1/metrics", None).unwrap();
        assert!(
            m.get("metrics").get("open_connections").as_usize().expect("gauge") >= IDLE,
            "{m}"
        );
        drop(held);
        handle.shutdown();
    }

    // Threaded accept: the same fan-in pins both workers; a fresh query
    // queued behind the idle connections gets no answer within its
    // deadline.
    {
        let (handle, addr) = start_with(|c| {
            c.event_loop = false;
            c.workers = WORKERS;
            c.read_timeout = Duration::from_secs(4);
        });
        let held: Vec<TcpStream> =
            (0..IDLE).map(|_| TcpStream::connect(&addr).expect("idle conn")).collect();
        std::thread::sleep(Duration::from_millis(300)); // accepted + queued ahead

        let mut probe = RawClient::connect(&addr);
        probe.stream.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let body = QueryRequest::new("starvation probe threaded").to_json().to_string();
        probe.send(
            format!(
                "POST /v1/query HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
                body.len(),
                body
            )
            .as_bytes(),
        );
        let mut chunk = [0u8; 1024];
        match probe.stream.read(&mut chunk) {
            Ok(n) => panic!(
                "threaded-accept served a query ({n} bytes) behind {IDLE} idle connections \
                 on {WORKERS} workers — idle sockets no longer pin workers?"
            ),
            Err(e) => assert!(
                matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ),
                "expected a starvation timeout, got {e}"
            ),
        }
        drop(held);
        handle.shutdown();
    }
}

// ---------------------------------------------------------------------
// max_conns accept-time budget (event loop).
// ---------------------------------------------------------------------

#[cfg(unix)]
#[test]
fn event_loop_max_conns_answers_503_at_accept() {
    let (handle, addr) = start_with(|c| {
        c.max_conns = 4;
    });
    let held: Vec<TcpStream> =
        (0..4).map(|_| TcpStream::connect(&addr).expect("budget conn")).collect();
    std::thread::sleep(Duration::from_millis(300)); // reactor registers them

    // Over budget: the server answers 503 unprompted and closes. The
    // body must be the *complete* JSON error (a truncated write would
    // fail the Content-Length read inside read_response, and the body
    // comparison pins the payload byte-for-byte) — the accept-path 503
    // used to be a single unchecked write() that could silently drop
    // part of the response.
    let mut c = RawClient::connect(&addr);
    let r = c.read_response();
    assert_eq!(r.status, 503, "{}", r.body);
    let v = json::parse(&r.body).expect("refusal body is whole, valid JSON");
    assert_eq!(v.get("error").as_str(), Some("connection limit reached"), "{}", r.body);
    assert_eq!(r.connection, "close", "refusals must advertise the close");
    assert_eq!(r.retry_after, Some(1), "accept-path 503 advertises Retry-After");
    c.assert_closed();

    // Dropping the fleet frees the budget again.
    drop(held);
    let mut recovered = false;
    for _ in 0..50 {
        if let Ok((200, m)) = http_request(&addr, "GET", "/v1/metrics", None) {
            assert!(
                m.get("metrics").get("conns_rejected").as_usize().expect("conns_rejected") >= 1,
                "{m}"
            );
            recovered = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(recovered, "server did not recover after the idle fleet closed");
    handle.shutdown();
}

// ---------------------------------------------------------------------
// Upstream-unavailable 503 over the wire.
// ---------------------------------------------------------------------

/// A full upstream outage with no degraded candidate in cache answers
/// a typed 503 on the batched query path — with the `Retry-After`
/// header, like every other 503 (the read_response invariant re-checks
/// that on every raw response in this file).
#[cfg(unix)]
#[test]
fn upstream_outage_rejection_is_503_with_retry_after() {
    let (handle, addr) = start_with(|_| {});
    let fault = r#"{"action": "fault", "plan": {"outage": true}}"#;
    let (status, _) = http_request(&addr, "POST", "/v1/admin", Some(fault)).expect("admin");
    assert_eq!(status, 200);

    let mut c = RawClient::connect(&addr);
    c.send(&post_query_raw("a question the dead upstream cannot answer", "t503"));
    let r = c.read_response();
    assert_eq!(r.status, 503, "{}", r.body);
    assert_eq!(r.retry_after, Some(1), "upstream-unavailable 503 advertises Retry-After");
    let v = json::parse(&r.body).expect("typed rejection body");
    assert_eq!(v.get("outcome").get("type").as_str(), Some("rejected"), "{}", r.body);
    assert!(
        v.get("outcome").get("reason").as_str().expect("reason").starts_with("upstream unavailable"),
        "{}",
        r.body
    );
    handle.shutdown();
}

// ---------------------------------------------------------------------
// Sharded wire path: coalescing across dispatchers, per-reactor gauges.
// ---------------------------------------------------------------------

/// The PR 3 coalescing guarantee, end to end over the sharded wire
/// path: N racing identical HTTP requests against a 4-reactor,
/// 2-dispatcher server cost exactly one LLM call. Identical requests
/// share a coalescing key, the batcher hash-routes on that key, so they
/// must all land on the same dispatcher shard and dedup there (any
/// straggler that misses the batch window finds the entry already
/// cached — still no second LLM call).
#[cfg(unix)]
#[test]
fn identical_http_requests_coalesce_across_sharded_dispatchers() {
    use semcache::coordinator::BatchConfig;

    const RACERS: usize = 8;
    let mut p = ModelParams::default();
    p.layers = 1;
    p.vocab_size = 1024;
    p.dim = 96;
    p.hidden = 192;
    p.heads = 4;
    // A wide dispatch window so every racer is in flight before the
    // batch fires.
    let cfg = ServerConfig::builder()
        .batch(BatchConfig {
            max_batch_size: RACERS,
            max_wait_us: 300_000,
            queue_capacity: 64,
            dispatchers: 1, // overridden by HttpConfig::dispatchers below
        })
        .build()
        .expect("server config");
    let server = Arc::new(Server::new(Arc::new(NativeEncoder::new(p)), cfg));
    let handle = serve_http(
        server.clone(),
        HttpConfig {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            reactors: 4,
            dispatchers: 2,
            ..HttpConfig::default()
        },
    )
    .expect("bind ephemeral port");
    let addr = handle.local_addr().to_string();

    let body = QueryRequest::new("rendezvous question for every racer")
        .to_json()
        .to_string();
    let answers: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..RACERS)
            .map(|_| {
                let (addr, body) = (addr.clone(), body.clone());
                scope.spawn(move || {
                    let (status, v) =
                        http_request(&addr, "POST", "/v1/query", Some(&body)).expect("query");
                    assert_eq!(status, 200, "{v}");
                    v.get("response").as_str().expect("response text").to_string()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("racer")).collect()
    });
    assert!(answers.windows(2).all(|w| w[0] == w[1]), "all racers share one answer: {answers:?}");

    let m = server.metrics().snapshot();
    assert_eq!(m.llm_calls, 1, "identical in-flight requests must cost one LLM call");
    assert_eq!(m.requests, RACERS as u64);
    assert_eq!(
        m.cache_hits + m.cache_misses + m.rejected,
        m.requests,
        "serving invariant must hold across sharded dispatch"
    );
    handle.shutdown();
}

/// The `/v1/metrics` per-reactor blocks must sum to the aggregate
/// gauges on a live 4-reactor server, and the round-robin handoff must
/// actually spread connections past reactor 0.
#[cfg(unix)]
#[test]
fn per_reactor_gauges_sum_to_aggregates_over_http() {
    const IDLE: usize = 8;
    let (handle, addr) = start_with(|c| {
        c.reactors = 4;
        c.read_timeout = Duration::from_secs(10);
    });
    let held: Vec<TcpStream> =
        (0..IDLE).map(|_| TcpStream::connect(&addr).expect("idle conn")).collect();
    std::thread::sleep(Duration::from_millis(300)); // reactors register them

    let (status, m) = http_request(&addr, "GET", "/v1/metrics", None).expect("metrics");
    assert_eq!(status, 200);
    let mm = m.get("metrics");
    let blocks = mm.get("reactors").as_array().expect("reactors array");
    assert_eq!(blocks.len(), 4, "one block per reactor: {m}");
    let (mut open_sum, mut accepted_sum, mut stall_sum) = (0usize, 0usize, 0usize);
    for b in blocks {
        open_sum += b.get("open").as_usize().expect("open");
        accepted_sum += b.get("accepted").as_usize().expect("accepted");
        stall_sum += b.get("stalls").as_usize().expect("stalls");
    }
    assert_eq!(open_sum, mm.get("open_connections").as_usize().unwrap(), "{m}");
    assert_eq!(accepted_sum, mm.get("conns_accepted").as_usize().unwrap(), "{m}");
    assert_eq!(stall_sum, mm.get("parse_stalls").as_usize().unwrap(), "{m}");
    assert!(open_sum >= IDLE, "the idle fleet shows up in the gauges: {m}");
    assert!(
        blocks.iter().filter(|b| b.get("accepted").as_usize().unwrap() > 0).count() >= 2,
        "round-robin handoff must spread {IDLE} connections past reactor 0: {m}"
    );
    drop(held);
    handle.shutdown();
}

// ---------------------------------------------------------------------
// Seeded trace-replay property: event-loop HTTP == direct serve().
// ---------------------------------------------------------------------

fn outcome_kind(o: &Outcome) -> &'static str {
    match o {
        Outcome::Hit { .. } => "hit",
        Outcome::Miss { .. } => "miss",
        Outcome::Rejected { .. } => "rejected",
    }
}

fn gen_trace(g: &mut Gen) -> Vec<QueryRequest> {
    let texts = [
        "how do i reset my password",
        "how can i reset my password",
        "where is my order right now",
        "cancel my subscription today",
        "what is the return policy",
    ];
    let n = g.usize_in(1, 10);
    (0..n)
        .map(|_| {
            let mut req = QueryRequest::new(*g.choose(&texts));
            if g.bool() {
                req = req.with_threshold(g.f32_in(-1.0, 1.0));
            }
            if g.bool() {
                req = req.with_ttl_ms(1 + g.u64() % 100_000);
            }
            if g.bool() {
                req = req.with_top_k(g.usize_in(1, 8));
            }
            req
        })
        .collect()
}

/// The PR 3 parity convention extended to the new wire path: a random
/// request trace replayed sequentially through the event-loop front-end
/// must produce outcome-identical responses and identical serving
/// counters to a direct `serve()` loop on a fresh, identically
/// configured server.
#[test]
fn prop_event_loop_http_replay_matches_direct_serve() {
    prop_check(
        PropConfig { cases: 8, max_shrink_rounds: 24, ..Default::default() },
        "event-http-trace-parity",
        |g| {
            let trace = gen_trace(g);

            // Arm 1: direct serve() on the calling thread.
            let direct = tiny_server();
            let direct_outcomes: Vec<(String, String)> = trace
                .iter()
                .map(|r| {
                    let resp = direct.serve(r);
                    (outcome_kind(&resp.outcome).to_string(), resp.response)
                })
                .collect();

            // Arm 2: the same trace over event-loop HTTP (batching on,
            // the default), sequentially so the order is pinned.
            let wire = tiny_server();
            let handle =
                serve_http(wire.clone(), HttpConfig { workers: 2, ..HttpConfig::default() })
                    .map_err(|e| format!("bind: {e:#}"))?;
            let addr = handle.local_addr().to_string();
            let mut wire_outcomes: Vec<(String, String)> = Vec::with_capacity(trace.len());
            for req in &trace {
                let body = req.to_json().to_string();
                let (status, v) = http_request(&addr, "POST", "/v1/query", Some(&body))
                    .map_err(|e| format!("query: {e:#}"))?;
                if status != 200 {
                    return Err(format!("unexpected status {status}: {v}"));
                }
                wire_outcomes.push((
                    v.get("outcome").get("type").as_str().unwrap_or("?").to_string(),
                    v.get("response").as_str().unwrap_or("").to_string(),
                ));
            }
            handle.shutdown();

            if direct_outcomes != wire_outcomes {
                return Err(format!(
                    "outcomes diverged\n direct: {direct_outcomes:?}\n   wire: {wire_outcomes:?}"
                ));
            }
            let dm = direct.metrics().snapshot();
            let wm = wire.metrics().snapshot();
            for (name, a, b) in [
                ("requests", dm.requests, wm.requests),
                ("cache_hits", dm.cache_hits, wm.cache_hits),
                ("cache_misses", dm.cache_misses, wm.cache_misses),
                ("llm_calls", dm.llm_calls, wm.llm_calls),
                ("rejected", dm.rejected, wm.rejected),
                ("positive_hits", dm.positive_hits, wm.positive_hits),
                ("negative_hits", dm.negative_hits, wm.negative_hits),
            ] {
                if a != b {
                    return Err(format!("counter {name} diverged: direct {a} vs wire {b}"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Short-write resumption (tiny SO_SNDBUF).
// ---------------------------------------------------------------------

/// `write_response` must deliver the whole response across short writes
/// and `EWOULDBLOCK`: a nonblocking server-side socket with a tiny
/// kernel send buffer against a deliberately slow reader loses no bytes.
#[cfg(unix)]
#[test]
fn write_response_resumes_across_tiny_sndbuf_short_writes() {
    use std::os::unix::io::AsRawFd;

    use semcache::coordinator::http::{write_response, HttpResponse};
    use semcache::util::poll::set_send_buffer;

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let reader = std::thread::spawn(move || {
        let mut client = TcpStream::connect(addr).expect("connect");
        client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut collected = Vec::new();
        let mut chunk = [0u8; 2048];
        loop {
            match client.read(&mut chunk) {
                Ok(0) => break,
                Ok(n) => {
                    collected.extend_from_slice(&chunk[..n]);
                    // Drain slowly so the tiny server-side send buffer
                    // keeps backing up.
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => panic!("reader failed: {e}"),
            }
        }
        collected
    });

    let (mut srv, _) = listener.accept().expect("accept");
    set_send_buffer(srv.as_raw_fd(), 4096).expect("shrink SO_SNDBUF");
    srv.set_nonblocking(true).expect("nonblocking");

    let payload = "x".repeat(512 * 1024);
    let resp = HttpResponse { status: 200, body: format!(r#"{{"payload": "{payload}"}}"#) };
    write_response(&mut srv, &resp, false).expect("resumable write completes");
    drop(srv); // EOF for the reader

    let got = reader.join().expect("reader thread");
    let text = String::from_utf8(got).expect("utf-8 response");
    let (head, body) = text.split_once("\r\n\r\n").expect("header/body split");
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert!(
        head.contains(&format!("Content-Length: {}", resp.body.len())),
        "content-length advertises the full body: {head}"
    );
    assert_eq!(body.len(), resp.body.len(), "bytes lost across short writes");
    assert_eq!(body, resp.body);
}
