//! Property-based tests over the coordinator-side invariants, using the
//! in-tree choice-stream harness (`semcache::testutil`): routing
//! (lookup/threshold), batching (embedding service), state (store
//! TTL/LRU vs a model, HNSW vs flat oracle, partition consistency), and
//! outcome accounting under seeded upstream fault schedules.

use std::sync::Arc;

use semcache::api::{Outcome, QueryRequest};
use semcache::cache::{CacheConfig, CachedEntry, SemanticCache};
use semcache::coordinator::{ResilienceConfig, Server, ServerConfig};
use semcache::embedding::NativeEncoder;
use semcache::eviction::entry_footprint;
use semcache::index::{FlatIndex, HnswConfig, HnswIndex, VectorIndex};
use semcache::llm::FaultPlan;
use semcache::runtime::ModelParams;
use semcache::store::{KvStore, ManualClock, StoreConfig};
use semcache::testutil::{prop_check, PropConfig};
use semcache::tokenizer::Tokenizer;
use semcache::util::l2_normalized;

fn cfg(cases: usize) -> PropConfig {
    PropConfig { cases, ..Default::default() }
}

/// The KV store behaves like a reference HashMap + expiry model under a
/// random interleaving of set/get/remove/advance/sweep.
#[test]
fn prop_store_matches_model() {
    prop_check(cfg(64), "store-vs-model", |g| {
        let clock = Arc::new(ManualClock::new(0));
        let store: KvStore<u64> = KvStore::with_clock(
            StoreConfig { shards: 4, capacity: 0, default_ttl_ms: 0, ..Default::default() },
            clock.clone(),
        );
        // model: key -> (value, expires_at)
        let mut model: std::collections::HashMap<String, (u64, u64)> =
            std::collections::HashMap::new();
        let mut now = 0u64;
        let keys = ["a", "b", "c", "d", "e", "f"];
        let ops = g.usize_in(1, 60);
        for i in 0..ops {
            match g.usize_below(5) {
                0 => {
                    let k = *g.choose(&keys);
                    let ttl = [0u64, 5, 50][g.usize_below(3)];
                    let exp = if ttl == 0 { u64::MAX } else { now + ttl };
                    store.set_ttl(k, i as u64, ttl);
                    model.insert(k.to_string(), (i as u64, exp));
                }
                1 => {
                    let k = *g.choose(&keys);
                    let got = store.get(k);
                    let want = model.get(k).and_then(|&(v, exp)| {
                        if exp > now {
                            Some(v)
                        } else {
                            None
                        }
                    });
                    if got != want {
                        return Err(format!("get({k}) = {got:?}, model says {want:?} (t={now})"));
                    }
                    if want.is_none() {
                        model.remove(k);
                    }
                }
                2 => {
                    let k = *g.choose(&keys);
                    let got = store.remove(k);
                    let want = model
                        .remove(k)
                        .map(|(_, exp)| exp > now)
                        .unwrap_or(false);
                    if got != want {
                        return Err(format!("remove({k}) = {got}, model says {want}"));
                    }
                }
                3 => {
                    let dt = g.usize_in(1, 30) as u64;
                    now += dt;
                    clock.advance(dt);
                }
                _ => {
                    store.sweep_expired();
                    model.retain(|_, &mut (_, exp)| exp > now);
                }
            }
            let live_model = model.values().filter(|&&(_, exp)| exp > now).count();
            if store.len() != live_model {
                return Err(format!("len {} != model {live_model} (t={now})", store.len()));
            }
        }
        Ok(())
    });
}

/// Capacity is never exceeded and recently-touched keys survive eviction.
#[test]
fn prop_store_capacity_respected() {
    prop_check(cfg(64), "store-capacity", |g| {
        let cap = g.usize_in(2, 8);
        let store: KvStore<usize> = KvStore::new(StoreConfig {
            shards: 1,
            capacity: cap,
            default_ttl_ms: 0,
            ..Default::default()
        });
        let n = g.usize_in(1, 40);
        for i in 0..n {
            store.set(&format!("k{i}"), i);
            if store.len() > cap {
                return Err(format!("len {} exceeds capacity {cap}", store.len()));
            }
            // The just-inserted key is always present.
            if store.get(&format!("k{i}")).is_none() {
                return Err(format!("just-inserted k{i} missing"));
            }
        }
        Ok(())
    });
}

/// HNSW top-1 matches the flat oracle for clearly-separated queries.
#[test]
fn prop_hnsw_top1_matches_flat() {
    prop_check(cfg(24), "hnsw-top1-vs-flat", |g| {
        let dim = g.usize_in(8, 24);
        let n = g.usize_in(10, 300);
        let mut hnsw = HnswIndex::new(dim, HnswConfig::default());
        let mut flat = FlatIndex::new(dim);
        let mut rows = Vec::new();
        for id in 0..n as u64 {
            let v = l2_normalized(&g.vec_f32(dim, -1.0, 1.0));
            hnsw.insert(id, &v);
            flat.insert(id, &v);
            rows.push(v);
        }
        // Query very near a stored row: both must return that row first.
        let target = g.usize_below(n);
        let q: Vec<f32> = rows[target].iter().map(|x| x + 0.01).collect();
        let f = flat.search(&q, 1)[0];
        let h = hnsw.search(&q, 1)[0];
        if f.id != h.id {
            return Err(format!(
                "flat top1 {} ({:.4}) vs hnsw top1 {} ({:.4}), n={n} dim={dim}",
                f.id, f.score, h.id, h.score
            ));
        }
        Ok(())
    });
}

/// Removals never surface removed ids; re-inserts revive them.
#[test]
fn prop_index_removal_soundness() {
    prop_check(cfg(32), "index-removal", |g| {
        let dim = 8;
        let mut idx = HnswIndex::new(dim, HnswConfig::default());
        let n = g.usize_in(5, 60);
        let mut vecs = Vec::new();
        for id in 0..n as u64 {
            let v = l2_normalized(&g.vec_f32(dim, -1.0, 1.0));
            idx.insert(id, &v);
            vecs.push(v);
        }
        let mut removed = std::collections::HashSet::new();
        for _ in 0..g.usize_in(1, n) {
            let id = g.usize_below(n) as u64;
            idx.remove(id);
            removed.insert(id);
        }
        for _ in 0..5 {
            let q = l2_normalized(&g.vec_f32(dim, -1.0, 1.0));
            for r in idx.search(&q, n) {
                if removed.contains(&r.id) {
                    return Err(format!("removed id {} returned", r.id));
                }
            }
        }
        Ok(())
    });
}

/// Cache lookups respect the threshold exactly: any returned hit has
/// score >= θ, and raising θ can only shrink the hit set.
#[test]
fn prop_cache_threshold_monotone() {
    prop_check(cfg(24), "cache-threshold-monotone", |g| {
        let dim = 16;
        let cache = SemanticCache::new(CacheConfig::default());
        let n = g.usize_in(3, 80);
        for i in 0..n {
            let v = g.vec_f32(dim, -1.0, 1.0);
            cache.try_insert(&format!("q{i}"), &v, "r").map_err(|e| format!("insert: {e:#}"))?;
        }
        for _ in 0..10 {
            let q = g.vec_f32(dim, -1.0, 1.0);
            let lo = g.f32_in(0.0, 0.9);
            let hi = (lo + g.f32_in(0.01, 0.1)).min(1.0);
            let hit_lo = cache.lookup_with_threshold(&q, lo);
            let hit_hi = cache.lookup_with_threshold(&q, hi);
            if let Some(h) = &hit_lo {
                if h.score < lo {
                    return Err(format!("hit below threshold: {} < {lo}", h.score));
                }
            }
            if hit_hi.is_some() && hit_lo.is_none() {
                return Err(format!("hit at θ={hi} but not at θ={lo}"));
            }
        }
        Ok(())
    });
}

/// Recall of the int8 quantized scan vs the exact path, measured at the
/// cache API over a seeded workload (ISSUE 10 acceptance): two caches
/// differing only in `quantized_scan` must agree on the hit/miss
/// outcome for >= 99% of queries at the default threshold, and every
/// planted near-duplicate ("positive") query that hits must return the
/// identical cached answer on both sides. Quantized rerank scores are
/// exact f32 dots, so any residual disagreement can only come from the
/// candidate preselect — which the 1% budget bounds.
#[test]
fn prop_quantized_recall_matches_exact() {
    prop_check(cfg(8), "quantized-recall-vs-exact", |g| {
        let dim = 24;
        let mut exact_cfg = CacheConfig::default();
        exact_cfg.quantized_scan = false;
        let exact = SemanticCache::new(exact_cfg);
        let quant = SemanticCache::new(CacheConfig::default());
        let n = g.usize_in(60, 250);
        let mut rows = Vec::new();
        for i in 0..n {
            let v = l2_normalized(&g.vec_f32(dim, -1.0, 1.0));
            let question = format!("q{i}");
            let answer = format!("r{i}");
            exact.try_insert(&question, &v, &answer).map_err(|e| format!("insert: {e:#}"))?;
            quant.try_insert(&question, &v, &answer).map_err(|e| format!("insert: {e:#}"))?;
            rows.push(v);
        }
        let queries = 200;
        let mut disagreements = 0usize;
        for qi in 0..queries {
            let positive = qi % 2 == 0;
            let q: Vec<f32> = if positive {
                // Near-duplicate of a stored row: unambiguous top-1
                // with score ~0.999 >> the ~0.3 typical of the rest.
                let t = g.usize_below(n);
                rows[t].iter().map(|x| x + g.f32_in(-0.02, 0.02)).collect()
            } else {
                g.vec_f32(dim, -1.0, 1.0)
            };
            let he = exact.lookup(&q);
            let hq = quant.lookup(&q);
            match (&he, &hq) {
                (Some(a), Some(b)) => {
                    if positive && a.entry.response != b.entry.response {
                        return Err(format!(
                            "positive hit answers diverge: '{}' vs '{}' (scores {:.6}/{:.6})",
                            a.entry.response, b.entry.response, a.score, b.score
                        ));
                    }
                    if a.entry.response != b.entry.response {
                        disagreements += 1;
                    }
                }
                (None, None) => {}
                _ => {
                    if positive {
                        return Err(format!(
                            "positive query hit on one side only: exact={} quantized={}",
                            he.is_some(),
                            hq.is_some()
                        ));
                    }
                    disagreements += 1;
                }
            }
        }
        // >= 99% outcome parity over the whole workload.
        if disagreements * 100 > queries {
            return Err(format!("{disagreements}/{queries} outcome disagreements (> 1%)"));
        }
        Ok(())
    });
}

/// Byte accounting is exact for every eviction policy: after a random
/// trace of tenant-scoped inserts (with TTLs and budget evictions),
/// removes, clock advances, and lookups, the global ledger, every
/// tenant ledger, and every partition's ledger must equal the footprint
/// sum recomputed from scratch over the entries actually resident —
/// and no budget is ever exceeded at a rest point.
#[test]
fn prop_byte_accounting_exact_for_every_policy() {
    for policy in ["lru", "lfu", "cost"] {
        prop_check(cfg(24), &format!("byte-accounting-{policy}"), |g| {
            let clock = Arc::new(ManualClock::new(0));
            let one = entry_footprint(8, 8, 8);
            let max_bytes = if g.bool() { g.usize_in(4, 12) as u64 * one } else { 0 };
            let quota = if g.bool() { g.usize_in(2, 6) as u64 * one } else { 0 };
            let cache = SemanticCache::with_clock(
                CacheConfig {
                    max_bytes,
                    eviction_policy: policy.to_string(),
                    tenant_quota_bytes: quota,
                    ..Default::default()
                },
                clock.clone(),
            );
            let tenants = ["default", "alice", "bob"];
            let dims = [8usize, 16];
            let mut inserted: Vec<(String, usize, u64)> = Vec::new();
            let ops = g.usize_in(1, 60);
            for i in 0..ops {
                match g.usize_below(6) {
                    0 | 1 | 2 => {
                        let tenant = *g.choose(&tenants);
                        let dim = *g.choose(&dims);
                        let entry = CachedEntry {
                            question: "q".repeat(g.usize_below(24)),
                            response: "r".repeat(g.usize_below(24)),
                            cluster: 0,
                            latency_ms: g.f32_in(0.0, 5_000.0) as f64,
                        };
                        let emb = l2_normalized(&g.vec_f32(dim, -1.0, 1.0));
                        let ttl = [0u64, 0, 20][g.usize_below(3)];
                        // An Err here is a typed quota rejection of an
                        // oversized entry — nothing was admitted.
                        if let Ok(id) =
                            cache.try_insert_entry_ttl_for(tenant, &emb, entry, Some(ttl))
                        {
                            inserted.push((tenant.to_string(), dim, id));
                        }
                    }
                    3 => {
                        if !inserted.is_empty() {
                            let (t, dim, id) = inserted.swap_remove(g.usize_below(inserted.len()));
                            cache.remove_entry_for(&t, dim, id);
                        }
                    }
                    4 => clock.advance(g.usize_in(1, 30) as u64),
                    _ => {
                        let tenant = *g.choose(&tenants);
                        let dim = *g.choose(&dims);
                        let q = l2_normalized(&g.vec_f32(dim, -1.0, 1.0));
                        let _ = cache.lookup_with_opts_for(tenant, &q, 0.5, None);
                    }
                }
                if max_bytes > 0 && cache.bytes() > max_bytes {
                    return Err(format!(
                        "global bytes {} > budget {max_bytes} at rest (op {i}, {policy})",
                        cache.bytes()
                    ));
                }
            }
            // Sweep expired residents (they legitimately hold bytes until
            // swept), then audit every ledger against a from-scratch
            // recompute of the resident footprints.
            cache.housekeep();
            let mut global = 0u64;
            let mut per_tenant: std::collections::HashMap<String, u64> =
                std::collections::HashMap::new();
            for p in cache.partitions() {
                let d = p.dump();
                let part_bytes: u64 = d
                    .entries
                    .iter()
                    .map(|e| {
                        entry_footprint(
                            e.entry.question.len(),
                            e.entry.response.len(),
                            e.embedding.len(),
                        )
                    })
                    .sum();
                if p.bytes() != part_bytes {
                    return Err(format!(
                        "partition ({}, {}) ledger {} != recomputed {part_bytes} ({policy})",
                        d.tenant,
                        d.dim,
                        p.bytes()
                    ));
                }
                global += part_bytes;
                *per_tenant.entry(d.tenant.clone()).or_default() += part_bytes;
            }
            if cache.bytes() != global {
                return Err(format!(
                    "global ledger {} != recomputed {global} ({policy})",
                    cache.bytes()
                ));
            }
            for t in cache.tenant_stats() {
                let want = per_tenant.get(&t.name).copied().unwrap_or(0);
                if t.bytes != want {
                    return Err(format!(
                        "tenant '{}' ledger {} != recomputed {want} ({policy})",
                        t.name, t.bytes
                    ));
                }
                if t.quota_bytes > 0 && t.bytes > t.quota_bytes {
                    return Err(format!(
                        "tenant '{}' bytes {} > quota {} ({policy})",
                        t.name, t.bytes, t.quota_bytes
                    ));
                }
            }
            Ok(())
        });
    }
}

/// The extended outcome balance `cache_hits + cache_misses +
/// degraded_hits + rejected == requests` holds *exactly* for any
/// request trace replayed under any seeded upstream fault schedule —
/// and every counter equals the number of typed outcomes actually
/// returned, so nothing is double- or un-counted on any path
/// (retries, breaker trips, shedding, degraded serving, deadline
/// exhaustion, insert failure).
#[test]
fn prop_extended_balance_under_seeded_upstream_faults() {
    let mut p = ModelParams::default();
    p.layers = 1;
    p.vocab_size = 1024;
    p.dim = 96;
    p.hidden = 192;
    p.heads = 4;
    let encoder = Arc::new(NativeEncoder::new(p));
    prop_check(cfg(6), "extended-balance-under-faults", |g| {
        let resilience = ResilienceConfig {
            deadline_ms: 1_000,
            max_retries: g.usize_below(3) as u32,
            backoff_base_ms: 1,
            backoff_max_ms: 2,
            breaker_failures: [2u32, 5, 10_000][g.usize_below(3)],
            breaker_open_ms: 10,
            breaker_halfopen_probes: 1 + g.usize_below(2) as u32,
            max_inflight: [0usize, 1, 4][g.usize_below(3)],
        };
        let server = Server::new(
            encoder.clone(),
            ServerConfig::builder()
                .resilience(resilience)
                .degraded_threshold(0.6)
                .build()
                .map_err(|e| format!("config: {e:#}"))?,
        );
        // Hangs carry a latency far past the deadline, so with a
        // deadline always configured they surface as typed timeouts
        // (never a wall-clock sleep — `real_sleep` is off).
        server.llm().set_fault_plan(FaultPlan {
            seed: g.u64(),
            error_prob: g.f32_in(0.0, 0.6) as f64,
            rate_limit_prob: g.f32_in(0.0, 0.4) as f64,
            retry_after_ms: 1,
            hang_prob: g.f32_in(0.0, 0.3) as f64,
            hang_ms: 60_000,
            outage_from_call: if g.bool() { 0 } else { 4 },
            outage_until_call: if g.bool() { 8 } else { 0 },
            ..FaultPlan::default()
        });

        // A trace over a small text pool (repeats ⇒ real cache hits),
        // some requests carrying their own deadline override; a random
        // prefix goes through serve(), the rest through serve_batch().
        let n = g.usize_in(1, 24);
        let reqs: Vec<QueryRequest> = (0..n)
            .map(|_| {
                let mut req =
                    QueryRequest::new(format!("fault trace question {}", g.usize_below(8)));
                if g.bool() {
                    req = req.with_deadline_ms(1 + g.usize_below(500) as u64);
                }
                req
            })
            .collect();
        let split = g.usize_below(n + 1);
        let mut responses = Vec::with_capacity(n);
        for r in &reqs[..split] {
            responses.push(server.serve(r));
        }
        responses.extend(server.serve_batch(&reqs[split..]));

        let m = server.metrics().snapshot();
        if m.requests != n as u64 {
            return Err(format!("{n} requests sent, {} recorded", m.requests));
        }
        let sum = m.cache_hits + m.cache_misses + m.degraded_hits + m.rejected;
        if sum != m.requests {
            return Err(format!(
                "balance violated: {} + {} + {} + {} = {sum} != {}",
                m.cache_hits, m.cache_misses, m.degraded_hits, m.rejected, m.requests
            ));
        }
        let (mut hits, mut misses, mut degraded, mut rejected) = (0u64, 0u64, 0u64, 0u64);
        for resp in &responses {
            match &resp.outcome {
                Outcome::Hit { .. } => hits += 1,
                Outcome::Miss { .. } => misses += 1,
                Outcome::Degraded { .. } => {
                    degraded += 1;
                    if !resp.latency.degraded {
                        return Err("degraded outcome without the latency flag".into());
                    }
                }
                Outcome::Rejected { .. } => rejected += 1,
            }
        }
        for (name, counted, returned) in [
            ("cache_hits", m.cache_hits, hits),
            ("cache_misses", m.cache_misses, misses),
            ("degraded_hits", m.degraded_hits, degraded),
            ("rejected", m.rejected, rejected),
        ] {
            if counted != returned {
                return Err(format!(
                    "counter {name} = {counted} but {returned} such outcomes were returned"
                ));
            }
        }
        Ok(())
    });
}

/// Tokenizer invariants under arbitrary input bytes.
#[test]
fn prop_tokenizer_total() {
    prop_check(cfg(256), "tokenizer-total", |g| {
        let tok = Tokenizer::new(4096, 32);
        let len = g.usize_below(120);
        let text: String = (0..len)
            .map(|_| {
                let c = g.usize_below(128) as u8;
                c as char
            })
            .collect();
        let ids = tok.encode(&text);
        if ids.len() != 32 {
            return Err(format!("len {}", ids.len()));
        }
        if ids[0] != 1 {
            return Err("missing CLS".into());
        }
        if ids.iter().any(|&i| i < 0 || i >= 4096) {
            return Err("id out of range".into());
        }
        // Deterministic.
        if tok.encode(&text) != ids {
            return Err("non-deterministic".into());
        }
        Ok(())
    });
}

/// JSON roundtrip for arbitrary generated values.
#[test]
fn prop_json_roundtrip() {
    use semcache::json::{parse, to_string_pretty, Value};
    fn gen_value(g: &mut semcache::testutil::Gen, depth: usize) -> Value {
        match if depth == 0 { g.usize_below(4) } else { g.usize_below(6) } {
            0 => Value::Null,
            1 => Value::Bool(g.bool()),
            2 => Value::Num((g.usize_below(10_000) as f64) / 8.0 - 100.0),
            3 => Value::Str(g.word()),
            4 => Value::Array((0..g.usize_below(4)).map(|_| gen_value(g, depth - 1)).collect()),
            _ => Value::Object(
                (0..g.usize_below(4)).map(|_| (g.word(), gen_value(g, depth - 1))).collect(),
            ),
        }
    }
    prop_check(cfg(256), "json-roundtrip", |g| {
        let v = gen_value(g, 3);
        let text = to_string_pretty(&v);
        let back = parse(&text).map_err(|e| e.to_string())?;
        if back != v {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        Ok(())
    });
}
