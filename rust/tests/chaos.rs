//! Chaos suite: upstream fault injection against a live HTTP daemon.
//!
//! Drives the whole wire path — reactor event loop, sharded batcher,
//! resilience layer, degraded serving — while the simulated upstream
//! fails in controlled ways (full outage, per-call errors, rate limits),
//! and asserts the invariants ISSUE 9 pins down:
//!
//! * every request gets exactly one well-formed response (200 or 503,
//!   never a hang or a dropped connection);
//! * the extended balance `cache_hits + cache_misses + degraded_hits +
//!   rejected == requests` holds exactly, including under concurrency
//!   over multiple reactors and dispatchers;
//! * a 100% outage is answered in bounded time (deadline, not hang),
//!   from cache at the relaxed gate when a candidate exists (explicitly
//!   marked degraded), else 503 — and inserts nothing;
//! * the circuit breaker walks open → half-open → closed as the fault
//!   clears, and hit-rate behavior recovers to parity.

use std::sync::Arc;
use std::time::{Duration, Instant};

use semcache::api::QueryRequest;
use semcache::coordinator::{
    http_request, serve_http, HttpConfig, HttpHandle, ResilienceConfig, Server, ServerConfig,
};
use semcache::embedding::NativeEncoder;
use semcache::json;
use semcache::llm::FaultPlan;
use semcache::runtime::ModelParams;

// ---------------------------------------------------------------------
// Harness
// ---------------------------------------------------------------------

fn chaos_server(resilience: ResilienceConfig, degraded_threshold: f32) -> Arc<Server> {
    let mut p = ModelParams::default();
    p.layers = 1;
    p.vocab_size = 1024;
    p.dim = 96;
    p.hidden = 192;
    p.heads = 4;
    let cfg = ServerConfig::builder()
        .resilience(resilience)
        .degraded_threshold(degraded_threshold)
        .build()
        .expect("valid chaos server config");
    Arc::new(Server::new(Arc::new(NativeEncoder::new(p)), cfg))
}

/// Fast-failing resilience knobs for tests: tiny backoffs so a rejected
/// request costs milliseconds, a breaker that (by default) never trips
/// so individual tests opt into breaker behavior explicitly.
fn fast_resilience() -> ResilienceConfig {
    ResilienceConfig {
        deadline_ms: 2_000,
        max_retries: 1,
        backoff_base_ms: 1,
        backoff_max_ms: 5,
        breaker_failures: 10_000,
        breaker_open_ms: 100,
        breaker_halfopen_probes: 2,
        max_inflight: 0,
    }
}

fn start(server: Arc<Server>, reactors: usize, dispatchers: usize) -> (HttpHandle, String) {
    let handle = serve_http(
        server,
        HttpConfig {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            max_body_bytes: 64 * 1024,
            read_timeout: Duration::from_secs(2),
            batching: true,
            reactors,
            dispatchers,
            ..HttpConfig::default()
        },
    )
    .expect("bind ephemeral port");
    let addr = handle.local_addr().to_string();
    for _ in 0..50 {
        if let Ok((200, _)) = http_request(&addr, "GET", "/v1/health", None) {
            return (handle, addr);
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("front-end at {addr} did not become healthy");
}

fn query(addr: &str, req: &QueryRequest) -> (u16, json::Value) {
    http_request(addr, "POST", "/v1/query", Some(&req.to_json().to_string()))
        .expect("query must always get exactly one well-formed response")
}

/// Reconfigure fault injection over the wire (the `/v1/admin` fault
/// verb), exactly as the chaos harness in verify.sh does.
fn set_fault(addr: &str, plan_json: &str) {
    let body = format!(r#"{{"action": "fault", "plan": {plan_json}}}"#);
    let (status, v) = http_request(addr, "POST", "/v1/admin", Some(&body)).expect("admin fault");
    assert_eq!(status, 200, "fault verb must be accepted: {v}");
    assert_eq!(v.get("action").as_str(), Some("fault"), "{v}");
}

fn metrics(addr: &str) -> json::Value {
    let (status, v) = http_request(addr, "GET", "/v1/metrics", None).expect("metrics");
    assert_eq!(status, 200);
    v
}

fn counter(m: &json::Value, key: &str) -> usize {
    m.get("metrics").get(key).as_usize().unwrap_or_else(|| panic!("metric {key} in {m}"))
}

/// The extended balance invariant: every accepted request is accounted
/// exactly once across the four outcome counters.
fn assert_balance(m: &json::Value) {
    let sum = counter(m, "cache_hits")
        + counter(m, "cache_misses")
        + counter(m, "degraded_hits")
        + counter(m, "rejected");
    assert_eq!(sum, counter(m, "requests"), "extended balance violated: {m}");
}

// ---------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------

/// Full outage: a paraphrase of a cached answer is served degraded
/// (explicitly marked, never as a fresh hit); clearing the fault
/// restores normal miss→hit behavior; the outage inserts nothing.
#[test]
fn outage_serves_degraded_from_cache_then_recovers() {
    let (handle, addr) = start(chaos_server(fast_resilience(), 0.6), 1, 1);

    // Populate: one fault-free miss.
    let (status, v) = query(&addr, &QueryRequest::new("how do i reset my password"));
    assert_eq!(status, 200);
    assert_eq!(v.get("outcome").get("type").as_str(), Some("miss"), "{v}");
    let cached_answer = v.get("response").as_str().expect("answer text").to_string();

    // Kill the upstream, then ask a paraphrase with a strict per-request
    // gate so the normal lookup misses and the request must go upstream.
    set_fault(&addr, r#"{"outage": true}"#);
    let req = QueryRequest::new("how can i reset my password").with_threshold(0.9999);
    let t = Instant::now();
    let (status, v) = query(&addr, &req);
    let elapsed = t.elapsed();
    assert_eq!(status, 200, "degraded answers are servable answers: {v}");
    assert_eq!(v.get("outcome").get("type").as_str(), Some("degraded"), "{v}");
    assert_eq!(v.get("latency").get("degraded").as_bool(), Some(true), "{v}");
    assert_eq!(v.get("latency").get("llm_ms").as_f64(), Some(0.0), "no upstream leg: {v}");
    assert_eq!(v.get("response").as_str(), Some(cached_answer.as_str()), "{v}");
    assert!(
        elapsed < Duration::from_secs(10),
        "outage answer must be deadline-bounded, took {elapsed:?}"
    );

    let m = metrics(&addr);
    assert_eq!(counter(&m, "degraded_hits"), 1, "{m}");
    assert!(counter(&m, "upstream_errors") >= 1, "outage attempts recorded: {m}");
    assert_eq!(m.get("cache_entries").as_usize(), Some(1), "outage inserted nothing: {m}");
    assert_balance(&m);

    // Clear the fault: a fresh topic misses (upstream answers again) and
    // its paraphrase is a first-class hit — parity restored.
    set_fault(&addr, "{}");
    let (status, v) = query(&addr, &QueryRequest::new("where is the nearest train station"));
    assert_eq!(status, 200);
    assert_eq!(v.get("outcome").get("type").as_str(), Some("miss"), "{v}");
    let (status, v) = query(&addr, &QueryRequest::new("where is the closest train station"));
    assert_eq!(status, 200);
    assert_eq!(v.get("outcome").get("type").as_str(), Some("hit"), "{v}");
    assert_eq!(v.get("latency").get("degraded").as_bool(), Some(false), "{v}");

    let m = metrics(&addr);
    assert_eq!(m.get("cache_entries").as_usize(), Some(2), "{m}");
    assert_balance(&m);
    handle.shutdown();
}

/// Full outage against an *empty* cache: no degraded candidate exists at
/// any gate, so every query is a typed 503 rejection, answered within
/// its (per-request) deadline, and the cache stays empty.
#[test]
fn outage_with_empty_cache_rejects_503_bounded_and_pollution_free() {
    let (handle, addr) = start(chaos_server(fast_resilience(), 0.6), 1, 1);
    set_fault(&addr, r#"{"outage": true}"#);

    for i in 0..3 {
        let req =
            QueryRequest::new(format!("unanswerable question number {i}")).with_deadline_ms(500);
        let t = Instant::now();
        let (status, v) = query(&addr, &req);
        let elapsed = t.elapsed();
        assert_eq!(status, 503, "upstream-unavailable rejections are 503: {v}");
        assert_eq!(v.get("outcome").get("type").as_str(), Some("rejected"), "{v}");
        let reason = v.get("outcome").get("reason").as_str().expect("reason");
        assert!(
            reason.starts_with("upstream unavailable"),
            "typed reason prefix, got: {reason}"
        );
        assert!(
            elapsed < Duration::from_secs(5),
            "rejection {i} must be bounded by the deadline, took {elapsed:?}"
        );
    }

    let m = metrics(&addr);
    assert_eq!(counter(&m, "requests"), 3, "{m}");
    assert_eq!(counter(&m, "rejected"), 3, "{m}");
    assert_eq!(counter(&m, "cache_hits") + counter(&m, "cache_misses"), 0, "{m}");
    assert_eq!(m.get("cache_entries").as_usize(), Some(0), "outage polluted the cache: {m}");
    assert_balance(&m);
    handle.shutdown();
}

/// Breaker lifecycle over live HTTP: consecutive failures open it, an
/// open breaker refuses without burning upstream attempts, and after the
/// fault clears it walks half-open → closed and serving recovers.
#[test]
fn breaker_opens_halfopens_closes_over_http() {
    // The open hold is generous relative to the few milliseconds the
    // while-open probe below needs, so a loaded CI machine cannot let
    // the hold expire early and turn the instant refusal into a
    // half-open upstream attempt.
    let resilience = ResilienceConfig {
        max_retries: 0,
        breaker_failures: 2,
        breaker_open_ms: 800,
        breaker_halfopen_probes: 2,
        ..fast_resilience()
    };
    let (handle, addr) = start(chaos_server(resilience, 0.6), 1, 1);
    set_fault(&addr, r#"{"outage": true}"#);

    // Two failing misses trip the breaker (one attempt each).
    for i in 0..2 {
        let (status, _) = query(&addr, &QueryRequest::new(format!("doomed question {i}")));
        assert_eq!(status, 503);
    }
    let m = metrics(&addr);
    assert_eq!(m.get("metrics").get("breaker_state").as_str(), Some("open"), "{m}");
    assert_eq!(counter(&m, "breaker_opens"), 1, "{m}");
    let errors_at_open = counter(&m, "upstream_errors");

    // While open, requests are refused instantly — no upstream attempt.
    let (status, _) = query(&addr, &QueryRequest::new("refused at the breaker"));
    assert_eq!(status, 503);
    let m = metrics(&addr);
    assert_eq!(
        counter(&m, "upstream_errors"),
        errors_at_open,
        "an open breaker must not burn upstream attempts: {m}"
    );

    // Clear the fault and wait out the open hold: the next two misses
    // are half-open probes; both succeed, closing the breaker.
    set_fault(&addr, "{}");
    std::thread::sleep(Duration::from_millis(1_000));
    for i in 0..2 {
        let (status, v) = query(&addr, &QueryRequest::new(format!("recovery probe {i}")));
        assert_eq!(status, 200, "half-open probes serve normally: {v}");
        assert_eq!(v.get("outcome").get("type").as_str(), Some("miss"), "{v}");
    }
    let m = metrics(&addr);
    assert_eq!(m.get("metrics").get("breaker_state").as_str(), Some("closed"), "{m}");
    assert!(counter(&m, "breaker_half_opens") >= 1, "{m}");
    assert_eq!(counter(&m, "breaker_closes"), 1, "{m}");

    // Hit-rate parity after recovery: a paraphrase of a recovery miss is
    // a first-class hit.
    let (status, v) = query(&addr, &QueryRequest::new("recovery probe 0"));
    assert_eq!(status, 200);
    assert_eq!(v.get("outcome").get("type").as_str(), Some("hit"), "{v}");

    let m = metrics(&addr);
    // 2 tripping rejections + 1 breaker-open rejection + 2 recovery
    // misses + 1 hit = 6 requests, balanced exactly.
    assert_eq!(counter(&m, "requests"), 6, "{m}");
    assert_eq!(counter(&m, "rejected"), 3, "{m}");
    assert_eq!(counter(&m, "cache_misses"), 2, "{m}");
    assert_eq!(counter(&m, "cache_hits"), 1, "{m}");
    assert_balance(&m);
    handle.shutdown();
}

/// Seeded mixed faults under concurrency over the full sharded wire path
/// (multiple reactors, multiple dispatchers, coalescing batcher): every
/// request gets exactly one response and the extended balance holds
/// exactly when the dust settles.
#[test]
fn mixed_faults_keep_extended_balance_over_sharded_wire_path() {
    let (handle, addr) = start(chaos_server(fast_resilience(), 0.6), 4, 2);
    set_fault(
        &addr,
        r#"{"error_prob": 0.3, "rate_limit_prob": 0.2, "retry_after_ms": 1, "seed": 7}"#,
    );

    const THREADS: usize = 8;
    const PER_THREAD: usize = 6;
    // A pool smaller than the request count so identical in-flight texts
    // exercise coalescing while faults fail some representatives.
    let texts: Vec<String> =
        (0..12).map(|i| format!("chaos workload question number {i}")).collect();
    let mut joins = Vec::new();
    for t in 0..THREADS {
        let addr = addr.clone();
        let texts = texts.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..PER_THREAD {
                let text = &texts[(t * PER_THREAD + i) % texts.len()];
                let (status, v) = http_request(
                    &addr,
                    "POST",
                    "/v1/query",
                    Some(&QueryRequest::new(text.as_str()).to_json().to_string()),
                )
                .expect("exactly one response per request");
                assert!(status == 200 || status == 503, "unexpected status {status}: {v}");
                let kind = v.get("outcome").get("type").as_str().expect("typed outcome");
                assert!(
                    ["hit", "miss", "degraded", "rejected"].contains(&kind),
                    "unknown outcome {kind}: {v}"
                );
            }
        }));
    }
    for j in joins {
        j.join().expect("chaos client thread");
    }

    let m = metrics(&addr);
    assert_eq!(counter(&m, "requests"), THREADS * PER_THREAD, "{m}");
    assert_balance(&m);
    assert!(counter(&m, "upstream_errors") > 0, "faults were injected: {m}");
    assert!(counter(&m, "upstream_retries") > 0, "failed attempts were retried: {m}");
    handle.shutdown();
}
