//! Cross-language parity: the AOT-compiled JAX/Pallas encoder (executed
//! through PJRT) must agree with the pure-Rust native encoder, because
//! both derive their weights from the same splitmix64 streams and
//! implement the same formulas. This is the load-bearing test for the
//! whole three-layer architecture — if it passes, the Python compile path
//! and the Rust request path are interchangeable.
//!
//! Skips (with a note) when `artifacts/` has not been built.

use semcache::embedding::{Encoder, NativeEncoder, PjrtEncoder};
use semcache::index::{FlatIndex, VectorIndex};
use semcache::runtime::{artifacts_available, artifacts_dir, ArtifactManifest, Runtime};
use semcache::util::{dot, norm, Rng};

fn skip() -> bool {
    if !semcache::runtime::pjrt_enabled() {
        eprintln!("SKIP: built without the `pjrt` feature");
        true
    } else if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        true
    } else {
        false
    }
}

const TEXTS: &[&str] = &[
    "how do i reset my password",
    "how can i reset my password",
    "what are the interest rates for savings accounts",
    "write a python function to reverse a string",
    "python function to reverse text",
    "where is my order it has not arrived yet",
    "",
    "a",
    "this is a very long query that will definitely exceed the maximum \
     sequence length of the encoder because it just keeps going and going \
     and going with more and more words than fit in thirty two positions",
];

#[test]
fn pjrt_encoder_matches_native() {
    if skip() {
        return;
    }
    let pjrt = PjrtEncoder::from_artifacts_dir(&artifacts_dir()).expect("load artifacts");
    let native = NativeEncoder::new(pjrt.params().clone());

    let got = pjrt.encode_batch(TEXTS).expect("pjrt encode");
    let want = native.encode_batch(TEXTS);
    assert_eq!(got.len(), want.len());
    let mut max_diff = 0f32;
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.len(), w.len());
        assert!((norm(g) - 1.0).abs() < 1e-3, "pjrt embedding unit norm");
        for (a, b) in g.iter().zip(w) {
            max_diff = max_diff.max((a - b).abs());
        }
    }
    assert!(max_diff < 1e-3, "pjrt vs native max abs diff = {max_diff}");
}

#[test]
fn pjrt_batch_sizes_agree_with_each_other() {
    if skip() {
        return;
    }
    let pjrt = PjrtEncoder::from_artifacts_dir(&artifacts_dir()).expect("load artifacts");
    // Encoding one text alone (b1) and inside a padded batch (b4/b8...)
    // must give the same embedding: padding rows cannot leak.
    let alone = pjrt.encode_batch(&["where is my order"]).unwrap();
    let batch = pjrt
        .encode_batch(&["where is my order", "x", "y z", "w", "v"])
        .unwrap();
    let diff: f32 = alone[0]
        .iter()
        .zip(&batch[0])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(diff < 1e-4, "batch padding leaked into embedding: {diff}");
}

#[test]
fn scorer_artifact_matches_flat_scan() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let manifest = ArtifactManifest::load(&dir.join("manifest.json")).unwrap();
    let runtime = Runtime::load(&dir).unwrap();
    let dim = manifest.model.dim;

    let mut rng = Rng::new(0xABCDEF);
    let n = 1024;
    // Random normalized corpus + query.
    let mut corpus = vec![0.0f32; n * dim];
    for x in corpus.iter_mut() {
        *x = rng.range_f64(-1.0, 1.0) as f32;
    }
    for row in corpus.chunks_mut(dim) {
        semcache::util::l2_normalize(row);
    }
    let mut q: Vec<f32> = (0..dim).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    semcache::util::l2_normalize(&mut q);

    // PJRT scorer top-16.
    let exe = runtime.get("scorer_n1024").unwrap();
    let out = exe
        .run_f32(&[(&q, &[dim]), (&corpus, &[n, dim])])
        .expect("scorer execute");
    let (values, indices) = (&out[0], &out[1]);
    assert_eq!(values.len(), 16);

    // Flat oracle.
    let mut flat = FlatIndex::new(dim);
    for (i, row) in corpus.chunks(dim).enumerate() {
        flat.insert(i as u64, row);
    }
    let truth = flat.search(&q, 16);

    for (i, t) in truth.iter().enumerate() {
        assert_eq!(indices[i].round() as u64, t.id, "rank {i} index");
        assert!((values[i] - t.score).abs() < 1e-4, "rank {i} score");
    }
}

#[test]
fn semantic_structure_preserved_through_pjrt() {
    if skip() {
        return;
    }
    let pjrt = PjrtEncoder::from_artifacts_dir(&artifacts_dir()).expect("load artifacts");
    let e = pjrt
        .encode_batch(&[
            "how do i track my package",
            "how can i track my package",
            "explain the difference between tcp and udp",
        ])
        .unwrap();
    let near = dot(&e[0], &e[1]);
    let far = dot(&e[0], &e[2]);
    assert!(near > 0.8, "paraphrase sim through pjrt = {near}");
    assert!(far < 0.5, "unrelated sim through pjrt = {far}");
}
