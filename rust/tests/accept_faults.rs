//! Accept-path fault injection: what happens when the reactor *cannot*
//! take ownership of a freshly accepted connection.
//!
//! Historically `set_nonblocking`/`Poller::register` failures at accept
//! time silently dropped the socket — the client saw a connection that
//! opened and then died with no bytes, and no counter moved. The
//! reactor now answers a complete best-effort 503 and bumps
//! `conns_rejected` on every refusal path. This test drives the
//! register-failure arm deterministically through the
//! `FAIL_NEXT_REGISTERS` shim in `util::poll` (real fd exhaustion is
//! neither portable nor hermetic).
//!
//! The shim is process-wide, so this regression lives in its own
//! integration-test binary: cargo runs tests *within* one binary in
//! parallel, and an armed shim must never eat another test's legitimate
//! register call.

#![cfg(unix)]

use std::io::Read;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use semcache::api::QueryRequest;
use semcache::coordinator::{http_request, serve_http, HttpConfig, Server, ServerConfig};
use semcache::embedding::NativeEncoder;
use semcache::json;
use semcache::runtime::ModelParams;
use semcache::util::poll::FAIL_NEXT_REGISTERS;

fn tiny_server() -> Arc<Server> {
    let mut p = ModelParams::default();
    p.layers = 1;
    p.vocab_size = 1024;
    p.dim = 96;
    p.hidden = 192;
    p.heads = 4;
    Arc::new(Server::new(Arc::new(NativeEncoder::new(p)), ServerConfig::default()))
}

#[test]
fn failed_conn_registration_answers_503_and_counts_rejected() {
    // One reactor so the armed failure deterministically hits the next
    // accepted connection's registration (with several reactors it
    // still hits *a* register call, but a single reactor makes the
    // before/after metrics exact).
    let server = tiny_server();
    let handle = serve_http(
        server.clone(),
        HttpConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            reactors: 1,
            read_timeout: Duration::from_secs(5),
            ..HttpConfig::default()
        },
    )
    .expect("bind ephemeral port");
    let addr = handle.local_addr().to_string();

    // Healthy baseline (also proves startup's own registrations are
    // done, so the armed failure cannot land on anything but the victim
    // connection).
    let body = QueryRequest::new("baseline before the fault").to_json().to_string();
    let (status, _) = http_request(&addr, "POST", "/v1/query", Some(&body)).expect("baseline");
    assert_eq!(status, 200);
    let rejected_before =
        server.metrics().snapshot().http_conns_rejected;

    FAIL_NEXT_REGISTERS.store(1, Ordering::SeqCst);
    // The victim: accepted, then its poller registration fails. The old
    // code dropped it silently (EOF with zero bytes); now it must get a
    // complete 503 before the close.
    let mut victim = TcpStream::connect(&addr).expect("victim connect");
    victim.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
    let mut raw = Vec::new();
    victim.read_to_end(&mut raw).expect("read the refusal to EOF");
    assert_eq!(
        FAIL_NEXT_REGISTERS.load(Ordering::SeqCst),
        0,
        "the armed failure was consumed by the victim's registration"
    );
    let text = String::from_utf8_lossy(&raw);
    assert!(
        text.starts_with("HTTP/1.1 503 "),
        "a failed registration must be answered, not silently dropped; got {:?}",
        text
    );
    let (head, resp_body) = text.split_once("\r\n\r\n").expect("complete head/body split");
    assert!(head.contains("Connection: close"), "{head}");
    let v = json::parse(resp_body).expect("refusal body is whole, valid JSON");
    assert_eq!(v.get("error").as_str(), Some("connection setup failed"), "{text}");

    // The refusal is visible in the metrics...
    let snap = server.metrics().snapshot();
    assert_eq!(
        snap.http_conns_rejected,
        rejected_before + 1,
        "a dropped registration must count as a rejected connection"
    );
    // ...the admission budget was refunded (the victim never became an
    // open connection)...
    assert_eq!(
        snap.reactors.iter().map(|r| r.accepted).sum::<u64>(),
        snap.http_conns_accepted,
        "per-reactor accepted stays in sync with the aggregate"
    );
    // ...and the server keeps serving afterwards.
    let body = QueryRequest::new("service resumes after the fault").to_json().to_string();
    let (status, v) = http_request(&addr, "POST", "/v1/query", Some(&body)).expect("after");
    assert_eq!(status, 200, "{v}");
    handle.shutdown();
}
