//! Adversarial serving tests for the cross-request micro-batching
//! engine (`coordinator::batcher`):
//!
//! * seeded trace-replay parity — the same trace served through the
//!   batched HTTP front-end by many concurrent connections must produce
//!   the same per-query hit/miss outcomes and the same serving counters
//!   as a sequential `serve()` loop on one thread;
//! * a 16-thread stress run hammering `POST /v1/query` against periodic
//!   `/v1/admin` flushes (exactly one response per request, and
//!   `cache_hits + cache_misses + rejected == requests` holds);
//! * property tests for the (max_batch_size, max_wait_us) window policy
//!   over random arrival patterns (exactly-once answering, batch-size
//!   bound, per-request override preservation through coalescing);
//! * per-entry TTL expiry under batching;
//! * the in-flight duplicate caveat fix (concurrent identical novel
//!   queries cost exactly one LLM call);
//! * deterministic 503 backpressure through the HTTP front-end.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use semcache::api::{LatencyBreakdown, Outcome, QueryRequest, QueryResponse};
use semcache::coordinator::{
    http_request, serve_http, BatchConfig, BatchExecutor, Batcher, HttpConfig, Server,
    ServerConfig,
};
use semcache::embedding::NativeEncoder;
use semcache::llm::SimLlmConfig;
use semcache::metrics::Metrics;
use semcache::runtime::ModelParams;
use semcache::testutil::{prop_check, Gen, PropConfig};
use semcache::util::SplitMix64;
use semcache::workload::{Category, Dataset, QaPair};

fn small_encoder() -> Arc<NativeEncoder> {
    let mut p = ModelParams::default();
    p.layers = 1;
    p.vocab_size = 1024;
    p.dim = 96;
    p.hidden = 192;
    p.heads = 4;
    Arc::new(NativeEncoder::new(p))
}

fn server_with_batch(batch: BatchConfig) -> Arc<Server> {
    let cfg = ServerConfig::builder().batch(batch).build().expect("test server config");
    Arc::new(Server::new(small_encoder(), cfg))
}

fn qa(cluster: u64, question: &str, answer: &str) -> QaPair {
    QaPair {
        cluster,
        answer_group: cluster,
        category: Category::PythonBasics,
        question: question.to_string(),
        answer: answer.to_string(),
    }
}

// ---------- trace-replay parity ----------

/// The seeded trace: paraphrases of populated entries (always hits) and
/// pairwise-distinct novel queries, each appearing exactly twice (one
/// miss + one hit per text, in *any* serving order — which is what makes
/// the comparison insensitive to thread interleaving while still
/// pinning every outcome).
fn parity_trace() -> (Vec<QaPair>, Vec<QaPair>, Vec<(String, u64)>) {
    let cached: Vec<QaPair> = (0..16)
        .map(|i| {
            qa(
                i,
                &format!("how do i configure gadget model {i} firmware"),
                &format!("cached answer {i}"),
            )
        })
        .collect();
    let novel: Vec<QaPair> = (0..10)
        .map(|j| {
            qa(
                1000 + j,
                &format!("unique{j} zebra{j} quasar{j} lantern{j}"),
                &format!("novel answer {j}"),
            )
        })
        .collect();
    let mut trace: Vec<(String, u64)> = Vec::new();
    for _ in 0..2 {
        for i in 0..16u64 {
            trace.push((format!("how can i configure gadget model {i} firmware"), i));
        }
        for (j, p) in novel.iter().enumerate() {
            trace.push((p.question.clone(), 1000 + j as u64));
        }
    }
    // Deterministic seeded shuffle (Fisher-Yates).
    let mut rng = SplitMix64::new(0x7AC3_5EED);
    for i in (1..trace.len()).rev() {
        let j = (rng.next_u64() % (i as u64 + 1)) as usize;
        trace.swap(i, j);
    }
    (cached, novel, trace)
}

fn parity_server(cached: &[QaPair], novel: &[QaPair]) -> Arc<Server> {
    let s = server_with_batch(BatchConfig {
        max_batch_size: 8,
        max_wait_us: 2_000,
        queue_capacity: 256,
        dispatchers: 1,
    });
    s.populate(cached);
    let all = Dataset { base: cached.iter().chain(novel).cloned().collect(), tests: Vec::new() };
    s.register_ground_truth(&all);
    s
}

/// text -> sorted multiset of (outcome kind, response text).
type OutcomeMap = BTreeMap<String, Vec<(String, String)>>;

fn sort_outcomes(mut m: OutcomeMap) -> OutcomeMap {
    for v in m.values_mut() {
        v.sort();
    }
    m
}

#[test]
fn trace_replay_parity_batched_http_vs_sequential() {
    let (cached, novel, trace) = parity_trace();

    // Arm 1: sequential serve() on one thread.
    let seq = parity_server(&cached, &novel);
    let mut seq_outcomes: OutcomeMap = BTreeMap::new();
    for (text, cluster) in &trace {
        let resp = seq.serve(&QueryRequest::new(text.as_str()).with_cluster(*cluster));
        let kind = match resp.outcome {
            Outcome::Hit { .. } => "hit",
            Outcome::Miss { .. } => "miss",
            Outcome::Rejected { .. } => "rejected",
        };
        seq_outcomes
            .entry(text.clone())
            .or_default()
            .push((kind.to_string(), resp.response.clone()));
    }

    // Arm 2: the same trace through the batched HTTP front-end, split
    // round-robin over 8 concurrent client threads.
    let batched = parity_server(&cached, &novel);
    let handle = serve_http(
        batched.clone(),
        HttpConfig { workers: 8, batching: true, ..HttpConfig::default() },
    )
    .expect("bind ephemeral port");
    let addr = handle.local_addr().to_string();
    let collected: Mutex<OutcomeMap> = Mutex::new(BTreeMap::new());
    std::thread::scope(|scope| {
        for t in 0..8usize {
            let addr = addr.clone();
            let trace = &trace;
            let collected = &collected;
            scope.spawn(move || {
                for (i, (text, cluster)) in trace.iter().enumerate() {
                    if i % 8 != t {
                        continue;
                    }
                    let body = QueryRequest::new(text.as_str())
                        .with_cluster(*cluster)
                        .to_json()
                        .to_string();
                    let (status, v) =
                        http_request(&addr, "POST", "/v1/query", Some(&body)).expect("query");
                    assert_eq!(status, 200, "parity trace must not be rejected: {v}");
                    let kind = v
                        .get("outcome")
                        .get("type")
                        .as_str()
                        .expect("outcome type")
                        .to_string();
                    let resp = v.get("response").as_str().expect("response text").to_string();
                    collected
                        .lock()
                        .unwrap()
                        .entry(text.clone())
                        .or_default()
                        .push((kind, resp));
                }
            });
        }
    });
    handle.shutdown();

    let seq_outcomes = sort_outcomes(seq_outcomes);
    let bat_outcomes = sort_outcomes(collected.into_inner().unwrap());
    assert_eq!(
        seq_outcomes, bat_outcomes,
        "batched HTTP serving must be outcome-identical to sequential serving"
    );

    // Final serving counters agree exactly.
    let sm = seq.metrics().snapshot();
    let bm = batched.metrics().snapshot();
    assert_eq!(sm.requests, trace.len() as u64);
    assert_eq!(bm.requests, sm.requests, "requests");
    assert_eq!(bm.cache_hits, sm.cache_hits, "cache_hits");
    assert_eq!(bm.cache_misses, sm.cache_misses, "cache_misses");
    assert_eq!(bm.llm_calls, sm.llm_calls, "llm_calls");
    assert_eq!(bm.rejected, sm.rejected, "rejected");
    assert_eq!(bm.positive_hits, sm.positive_hits, "positive_hits");
    assert_eq!(bm.negative_hits, sm.negative_hits, "negative_hits");
    // Coalescing can only save embedding work, never add it.
    assert!(
        bm.embedding_tokens <= sm.embedding_tokens,
        "batched path embedded more tokens ({}) than sequential ({})",
        bm.embedding_tokens,
        sm.embedding_tokens
    );
    assert!(bm.batcher_dispatches >= 1, "the trace must have gone through the batcher");
    assert_eq!(bm.batcher_queries, bm.requests, "every request went through the batcher");
}

// ---------- concurrency stress ----------

#[test]
fn stress_16_threads_with_admin_flushes() {
    const THREADS: usize = 16;
    const PER_THREAD: usize = 25;
    let server = server_with_batch(BatchConfig {
        max_batch_size: 16,
        max_wait_us: 500,
        queue_capacity: 64,
        dispatchers: 1,
    });
    let handle = serve_http(
        server.clone(),
        HttpConfig { workers: 8, batching: true, ..HttpConfig::default() },
    )
    .expect("bind ephemeral port");
    let addr = handle.local_addr().to_string();

    let served = Mutex::new((0usize, 0usize)); // (ok_200, backpressure_503)
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let addr = addr.clone();
            let served = &served;
            scope.spawn(move || {
                for i in 0..PER_THREAD {
                    // A small hot set (heavy duplication across threads)
                    // plus an occasional per-thread unique query.
                    let text = if i % 5 == 4 {
                        format!("stress unique thread {t} item {i}")
                    } else {
                        format!("stress hot question number {}", (t + i) % 7)
                    };
                    let body = QueryRequest::new(text).to_json().to_string();
                    let (status, v) =
                        http_request(&addr, "POST", "/v1/query", Some(&body)).expect("query");
                    let kind = v.get("outcome").get("type").as_str().expect("typed outcome");
                    match status {
                        200 => {
                            assert!(kind == "hit" || kind == "miss", "200 carries hit|miss: {v}");
                            served.lock().unwrap().0 += 1;
                        }
                        503 => {
                            assert_eq!(kind, "rejected", "503 carries a rejected outcome: {v}");
                            served.lock().unwrap().1 += 1;
                        }
                        other => panic!("unexpected status {other}: {v}"),
                    }
                }
            });
        }
        // Periodic admin flushes racing the query traffic.
        let addr2 = addr.clone();
        scope.spawn(move || {
            for _ in 0..12 {
                let (status, _) =
                    http_request(&addr2, "POST", "/v1/admin", Some(r#"{"action": "flush"}"#))
                        .expect("flush");
                assert_eq!(status, 200);
                std::thread::sleep(Duration::from_millis(3));
            }
        });
    });

    let (ok, rejected_503) = *served.lock().unwrap();
    assert_eq!(ok + rejected_503, THREADS * PER_THREAD, "exactly one response per request");

    // The server is alive and the counters are consistent.
    let (status, v) = http_request(&addr, "GET", "/v1/health", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(v.get("status").as_str(), Some("ok"));
    let m = server.metrics().snapshot();
    assert_eq!(m.requests, (THREADS * PER_THREAD) as u64);
    assert_eq!(
        m.cache_hits + m.cache_misses + m.rejected,
        m.requests,
        "hits {} + misses {} + rejected {} != requests {}",
        m.cache_hits,
        m.cache_misses,
        m.rejected,
        m.requests
    );
    assert_eq!(m.rejected as usize, rejected_503, "rejects are exactly the 503s");
    handle.shutdown();
}

// ---------- window-policy property tests ----------

/// The dedup identity of a request, printable (used both as the mock
/// executor's echoed payload and as the counting key).
fn identity(r: &QueryRequest) -> String {
    format!(
        "{}|{:?}|{:?}|{:?}|{:?}",
        r.text,
        r.options.threshold.map(f32::to_bits),
        r.options.ttl_ms,
        r.options.top_k,
        r.cluster
    )
}

/// Mock executor: echoes each request's identity (so submitters can
/// verify their overrides survived coalescing) and records every
/// executed batch for post-hoc invariant checks.
struct RecordingExec {
    max_allowed: usize,
    batches: Mutex<Vec<Vec<String>>>,
    violations: Mutex<Vec<String>>,
}

impl RecordingExec {
    fn new(max_allowed: usize) -> Arc<Self> {
        Arc::new(Self {
            max_allowed,
            batches: Mutex::new(Vec::new()),
            violations: Mutex::new(Vec::new()),
        })
    }
}

impl BatchExecutor for RecordingExec {
    fn execute(&self, reqs: &[QueryRequest]) -> Vec<QueryResponse> {
        if reqs.is_empty() {
            self.violations.lock().unwrap().push("empty batch dispatched".into());
        }
        if reqs.len() > self.max_allowed {
            self.violations
                .lock()
                .unwrap()
                .push(format!("batch of {} exceeds max_batch_size {}", reqs.len(), self.max_allowed));
        }
        self.batches.lock().unwrap().push(reqs.iter().map(identity).collect());
        reqs.iter()
            .map(|r| QueryResponse {
                response: identity(r),
                outcome: Outcome::Miss { inserted_id: 1 },
                latency: LatencyBreakdown::default(),
                judged_positive: None,
                matched_cluster: None,
                client_tag: r.client_tag.clone(),
            })
            .collect()
    }
}

fn gen_case_requests(g: &mut Gen, threads: usize, per_thread: usize) -> Vec<Vec<QueryRequest>> {
    (0..threads)
        .map(|t| {
            (0..per_thread)
                .map(|i| {
                    // ~25% duplicates drawn from a tiny shared pool with
                    // fixed (absent) options, so they share an identity
                    // across threads; the rest are unique with random
                    // per-request overrides.
                    let dup = g.bool() && g.bool();
                    let mut req = if dup {
                        QueryRequest::new(format!("dup-{}", g.usize_below(2)))
                    } else {
                        let mut r = QueryRequest::new(format!("q-{t}-{i}"));
                        if g.bool() {
                            r = r.with_threshold(g.f32_in(-1.0, 1.0));
                        }
                        if g.bool() {
                            r = r.with_ttl_ms(g.u64() % 100_000);
                        }
                        if g.bool() {
                            r = r.with_top_k(g.usize_in(1, 16));
                        }
                        if g.bool() {
                            r = r.with_cluster(g.u64() % 4);
                        }
                        r
                    };
                    req = req.with_client_tag(format!("tag-{t}-{i}"));
                    req
                })
                .collect()
        })
        .collect()
}

#[test]
fn prop_window_policy_exactly_once_bounded_and_override_preserving() {
    // Each case spins up a real batcher + submitter threads, so keep
    // the shrink budget small (a failing case is already tiny).
    prop_check(
        PropConfig { cases: 24, max_shrink_rounds: 60, ..Default::default() },
        "batcher-window-policy",
        |g| {
            let max_batch = g.usize_in(1, 6);
            let wait_us = *g.choose(&[0u64, 0, 200, 1_000, 3_000]);
            let threads = g.usize_in(1, 4);
            let per_thread = g.usize_in(1, 6);
            let requests = gen_case_requests(g, threads, per_thread);
            let submitted: Vec<QueryRequest> =
                requests.iter().flatten().cloned().collect();

            let exec = RecordingExec::new(max_batch);
            let metrics = Arc::new(Metrics::new());
            let batcher = Batcher::start(
                exec.clone(),
                metrics.clone(),
                BatchConfig {
                    max_batch_size: max_batch,
                    max_wait_us: wait_us,
                    queue_capacity: 64,
                    dispatchers: 1,
                },
            )
            .map_err(|e| format!("start: {e:#}"))?;

            let results: Vec<(QueryRequest, Result<QueryResponse, _>)> =
                std::thread::scope(|scope| {
                    let handles: Vec<_> = requests
                        .into_iter()
                        .map(|reqs| {
                            let b = batcher.clone();
                            scope.spawn(move || {
                                reqs.into_iter()
                                    .map(|r| {
                                        let resp = b.submit(&r);
                                        (r, resp)
                                    })
                                    .collect::<Vec<_>>()
                            })
                        })
                        .collect();
                    handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
                });
            batcher.shutdown();

            // Every submission answered exactly once, with its own
            // identity echoed back (overrides preserved through
            // coalescing) under its own client_tag.
            if results.len() != submitted.len() {
                return Err(format!(
                    "{} submissions, {} results",
                    submitted.len(),
                    results.len()
                ));
            }
            for (req, resp) in &results {
                let resp = resp
                    .as_ref()
                    .map_err(|e| format!("submit of {:?} failed: {e}", req.text))?;
                if resp.response != identity(req) {
                    return Err(format!(
                        "override lost in coalescing: {:?} answered with {:?}",
                        identity(req),
                        resp.response
                    ));
                }
                if resp.client_tag != req.client_tag {
                    return Err(format!(
                        "client_tag not preserved: {:?} vs {:?}",
                        req.client_tag, resp.client_tag
                    ));
                }
            }

            let violations = exec.violations.lock().unwrap().clone();
            if !violations.is_empty() {
                return Err(violations.join("; "));
            }

            // Per identity: executed at least once (someone did the
            // work) and at most as often as it was submitted
            // (exactly-once for unique identities).
            let mut submitted_count: BTreeMap<String, usize> = BTreeMap::new();
            for r in &submitted {
                *submitted_count.entry(identity(r)).or_default() += 1;
            }
            let mut executed_count: BTreeMap<String, usize> = BTreeMap::new();
            for batch in exec.batches.lock().unwrap().iter() {
                for id in batch {
                    *executed_count.entry(id.clone()).or_default() += 1;
                }
            }
            for (id, &n) in &submitted_count {
                let e = executed_count.get(id).copied().unwrap_or(0);
                if e == 0 {
                    return Err(format!("identity {id:?} submitted {n}x, never executed"));
                }
                if e > n {
                    return Err(format!("identity {id:?} submitted {n}x, executed {e}x"));
                }
            }
            if executed_count.keys().any(|id| !submitted_count.contains_key(id)) {
                return Err("executor saw an identity nobody submitted".into());
            }

            let m = metrics.snapshot();
            let executed_total: usize = executed_count.values().sum();
            if m.batcher_queries as usize != submitted.len() {
                return Err(format!(
                    "batcher_queries {} != submissions {}",
                    m.batcher_queries,
                    submitted.len()
                ));
            }
            if m.coalesced as usize != submitted.len() - executed_total {
                return Err(format!(
                    "coalesced {} != submitted {} - executed {}",
                    m.coalesced,
                    submitted.len(),
                    executed_total
                ));
            }
            Ok(())
        },
    );
}

// ---------- TTL expiry under batching ----------

#[test]
fn per_entry_ttl_expires_under_batching() {
    let server = server_with_batch(BatchConfig {
        max_batch_size: 8,
        max_wait_us: 0,
        queue_capacity: 16,
        dispatchers: 1,
    });
    let batcher = server.start_batcher().unwrap();
    let probe = || QueryRequest::new("ephemeral ttl probe request").with_ttl_ms(150);

    let r1 = batcher.submit(&probe()).unwrap();
    assert!(matches!(r1.outcome, Outcome::Miss { .. }), "fresh insert: {:?}", r1.outcome);
    let r2 = batcher.submit(&probe()).unwrap();
    assert!(r2.is_hit(), "within TTL the entry serves hits: {:?}", r2.outcome);

    std::thread::sleep(Duration::from_millis(400));
    let r3 = batcher.submit(&probe()).unwrap();
    assert!(
        matches!(r3.outcome, Outcome::Miss { .. }),
        "expired entry must not serve a hit in a later batch: {:?}",
        r3.outcome
    );
    batcher.shutdown();
    let m = server.metrics().snapshot();
    assert_eq!(m.cache_misses, 2);
    assert_eq!(m.cache_hits, 1);
}

// ---------- in-flight duplicate caveat fix ----------

#[test]
fn concurrent_identical_novel_queries_cost_one_llm_call() {
    let server = server_with_batch(BatchConfig {
        max_batch_size: 16,
        max_wait_us: 3_000,
        queue_capacity: 64,
        dispatchers: 1,
    });
    let batcher = server.start_batcher().unwrap();
    let responses: Vec<QueryResponse> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let b = batcher.clone();
                scope.spawn(move || {
                    b.submit(&QueryRequest::new("concurrent duplicate novel query")).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    batcher.shutdown();

    let misses = responses.iter().filter(|r| matches!(r.outcome, Outcome::Miss { .. })).count();
    let hits = responses.iter().filter(|r| r.is_hit()).count();
    assert_eq!(misses, 1, "exactly one thread pays the miss");
    assert_eq!(hits, 7, "everyone else is served the same answer");
    for r in &responses {
        assert_eq!(r.response, responses[0].response, "all replies share the one answer");
    }
    let m = server.metrics().snapshot();
    assert_eq!(m.requests, 8);
    assert_eq!(m.llm_calls, 1, "the duplicate in-flight caveat is fixed by coalescing");
}

// ---------- HTTP backpressure ----------

#[test]
fn http_backpressure_answers_503_with_rejected_outcome() {
    // A slow (really-sleeping) upstream pins the dispatcher on the first
    // miss; with a 1-deep queue and 1-deep batches, later concurrent
    // requests must be bounced with 503 + Outcome::Rejected.
    let cfg = ServerConfig::builder()
        .llm(SimLlmConfig {
            rtt_ms: 300.0,
            ms_per_token: 0.0,
            jitter_sigma: 0.0,
            real_sleep: true,
            ..SimLlmConfig::default()
        })
        .batch(BatchConfig { max_batch_size: 1, max_wait_us: 0, queue_capacity: 1, dispatchers: 1 })
        .build()
        .expect("config");
    let server = Arc::new(Server::new(small_encoder(), cfg));
    let handle = serve_http(
        server.clone(),
        HttpConfig { workers: 6, batching: true, ..HttpConfig::default() },
    )
    .expect("bind ephemeral port");
    let addr = handle.local_addr().to_string();

    let statuses: Vec<(u16, String)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..6)
            .map(|i| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let body = QueryRequest::new(format!("backpressure probe number {i}"))
                        .to_json()
                        .to_string();
                    let (status, v) =
                        http_request(&addr, "POST", "/v1/query", Some(&body)).expect("query");
                    let kind =
                        v.get("outcome").get("type").as_str().expect("outcome type").to_string();
                    (status, kind)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    handle.shutdown();

    let ok = statuses.iter().filter(|(s, _)| *s == 200).count();
    let bounced = statuses.iter().filter(|(s, _)| *s == 503).count();
    assert_eq!(ok + bounced, 6);
    assert!(ok >= 1, "the dispatched request (and any queued one) is served: {statuses:?}");
    assert!(bounced >= 3, "most concurrent requests bounce off the full queue: {statuses:?}");
    for (status, kind) in &statuses {
        match status {
            200 => assert!(kind == "hit" || kind == "miss"),
            503 => assert_eq!(kind, "rejected"),
            other => panic!("unexpected status {other}"),
        }
    }
    let m = server.metrics().snapshot();
    assert_eq!(m.requests, 6);
    assert_eq!(m.cache_hits + m.cache_misses + m.rejected, m.requests);
    assert_eq!(m.rejected as usize, bounced);
}
