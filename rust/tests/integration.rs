//! Cross-module integration tests: full workflows over the public API
//! (no PJRT required; the parity suite covers the artifact path).

use std::sync::Arc;
use std::time::Duration;

use semcache::api::QueryRequest;
use semcache::cache::{CacheConfig, IndexKind, SemanticCache};
use semcache::config::Config;
use semcache::coordinator::{ReplySource, Server, ServerConfig, TraceConfig, TraceRunner};
use semcache::embedding::{Encoder, NativeEncoder};
use semcache::llm::SimLlmConfig;
use semcache::runtime::ModelParams;
use semcache::store::ManualClock;
use semcache::workload::{Category, DatasetConfig, WorkloadGenerator, ALL_CATEGORIES};

fn small_params() -> ModelParams {
    let mut p = ModelParams::default();
    p.layers = 2;
    p.vocab_size = 2048;
    p.dim = 128;
    p.hidden = 256;
    p.heads = 4;
    p
}

fn server() -> Arc<Server> {
    Arc::new(Server::new(
        Arc::new(NativeEncoder::new(small_params())),
        ServerConfig::default(),
    ))
}

#[test]
fn end_to_end_populate_and_trace() {
    let ds = WorkloadGenerator::new(99).generate(&DatasetConfig::small());
    let s = server();
    s.populate(&ds.base);
    s.register_ground_truth(&ds);
    let _hk = s.start_housekeeping(Duration::from_millis(50));

    let queries: Vec<_> = ds.tests_for(Category::NetworkSupport).cloned().collect();
    let report = TraceRunner::new(s.clone()).run(
        &queries,
        &TraceConfig { workers: 4, qps: 0.0, use_cache: true, seed: 1 },
    );
    assert_eq!(report.replies.len(), queries.len());
    let hit_rate = report.hits as f64 / queries.len() as f64;
    assert!(hit_rate > 0.4 && hit_rate < 0.95, "hit rate {hit_rate}");

    // Traditional baseline on the same trace: zero hits, higher latency.
    let base = TraceRunner::new(s.clone()).run(
        &queries,
        &TraceConfig { workers: 4, qps: 0.0, use_cache: false, seed: 1 },
    );
    assert_eq!(base.hits, 0);
    assert!(
        base.latency.mean > report.latency.mean,
        "no-cache mean {} <= cached mean {}",
        base.latency.mean,
        report.latency.mean
    );
}

#[test]
fn batch_pipeline_end_to_end() {
    let ds = WorkloadGenerator::new(77).generate(&DatasetConfig::small());
    let s = server();
    // Hits only ever come from same-category entries, so populating the
    // queried category keeps the test fast without changing coverage.
    let base: Vec<_> = ds.base_for(Category::OrderShipping).cloned().collect();
    s.populate(&base);
    s.register_ground_truth(&ds);

    let queries: Vec<_> = ds.tests_for(Category::OrderShipping).cloned().collect();
    let texts: Vec<&str> = queries.iter().map(|q| q.text.as_str()).collect();
    let clusters: Vec<Option<u64>> = queries.iter().map(|q| Some(q.answer_group)).collect();
    let replies = s.handle_batch_clustered(&texts, &clusters);

    assert_eq!(replies.len(), queries.len(), "one reply per query, in order");
    let hits = replies
        .iter()
        .filter(|r| matches!(r.source, ReplySource::Cache { .. }))
        .count();
    let hit_rate = hits as f64 / replies.len() as f64;
    assert!(hit_rate > 0.4 && hit_rate < 0.95, "batch hit rate {hit_rate}");
    // Every cache hit must return the exact answer of its answer group
    // (in-order merge: reply i belongs to query i).
    let answers: std::collections::HashMap<u64, &str> =
        ds.base.iter().map(|p| (p.answer_group, p.answer.as_str())).collect();
    for (q, r) in queries.iter().zip(&replies) {
        if matches!(r.source, ReplySource::Cache { .. }) && r.judged_positive == Some(true) {
            assert_eq!(Some(r.response.as_str()), answers.get(&q.answer_group).copied());
        }
    }
    let m = s.metrics().snapshot();
    assert_eq!(m.batches, 1);
    assert_eq!(m.batch_queries as usize, queries.len());
    assert_eq!(m.requests as usize, queries.len());
    assert_eq!(m.cache_hits as usize, hits);
}

#[test]
fn flat_and_hnsw_agree_on_served_responses() {
    let ds = WorkloadGenerator::new(5).generate(&DatasetConfig::tiny());
    let enc = NativeEncoder::new(small_params());
    let build = |kind: IndexKind| {
        let cache = SemanticCache::new(CacheConfig { index: kind, ..Default::default() });
        for p in &ds.base {
            let e = enc.encode_text(&p.question);
            cache.try_insert(&p.question, &e, &p.answer).unwrap();
        }
        cache
    };
    let flat = build(IndexKind::Flat);
    let hnsw = build(IndexKind::Hnsw);
    let mut agree = 0;
    let mut total = 0;
    for q in &ds.tests {
        let e = enc.encode_text(&q.text);
        let a = flat.lookup(&e).map(|h| h.entry.response);
        let b = hnsw.lookup(&e).map(|h| h.entry.response);
        total += 1;
        if a == b {
            agree += 1;
        }
    }
    // HNSW is approximate; it may very occasionally return a different
    // above-threshold neighbor, but must agree in the vast majority.
    assert!(agree as f64 / total as f64 > 0.9, "{agree}/{total}");
}

#[test]
fn ttl_and_rebuild_under_serving() {
    let clock = Arc::new(ManualClock::new(0));
    let cache = SemanticCache::with_clock(
        CacheConfig { ttl_ms: 1_000, rebuild_garbage_ratio: 0.2, ..Default::default() },
        clock.clone(),
    );
    let enc = NativeEncoder::new(small_params());
    let texts: Vec<String> =
        (0..40).map(|i| format!("question number {i} about topic {i}")).collect();
    for t in &texts {
        cache.try_insert(t, &enc.encode_text(t), "answer").unwrap();
    }
    assert_eq!(cache.len(), 40);
    clock.advance(1_500);
    // All entries expired: lookups miss, housekeeping reclaims.
    assert!(cache.lookup(&enc.encode_text(&texts[0])).is_none());
    let (_expired, rebuilt) = cache.housekeep();
    assert!(rebuilt >= 1, "garbage-heavy partition must rebuild");
    assert_eq!(cache.len(), 0);
    // Cache continues to serve fresh inserts.
    cache.try_insert(&texts[0], &enc.encode_text(&texts[0]), "fresh").unwrap();
    assert!(cache.lookup(&enc.encode_text(&texts[0])).is_some());
}

#[test]
fn adaptive_threshold_reacts_to_negative_feedback() {
    // Serve with a deliberately low threshold; feed the judge's verdicts
    // into the controller; the effective gate must rise.
    use semcache::cache::AdaptiveThreshold;
    let s = server();
    let ds = WorkloadGenerator::new(3).generate(&DatasetConfig::small());
    s.populate(&ds.base);
    s.register_ground_truth(&ds);
    let mut ctl = AdaptiveThreshold::with_band(0.60, 0.55, 0.95);
    let mut raised = false;
    for q in &ds.tests {
        // The controller's gate rides on each request (v1 API) instead
        // of mutating server-wide state between queries.
        let req = QueryRequest::new(q.text.as_str())
            .with_cluster(q.answer_group)
            .with_threshold(ctl.get());
        let r = s.serve(&req);
        if let Some(ok) = r.judged_positive {
            ctl.observe(ok);
        }
        if ctl.get() > 0.60 {
            raised = true;
        }
    }
    assert!(raised, "low threshold must produce negatives that raise the gate");
}

#[test]
fn config_file_drives_server_behaviour() {
    let dir = std::env::temp_dir().join("semcache_int_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("strict.toml");
    std::fs::write(&path, "[cache]\nsimilarity_threshold = 0.99\n").unwrap();
    let cfg = Config::from_file(&path).unwrap();
    assert_eq!(cfg.similarity_threshold, 0.99);

    let s = Arc::new(Server::new(
        Arc::new(NativeEncoder::new(small_params())),
        ServerConfig {
            cache: CacheConfig { threshold: cfg.similarity_threshold, ..Default::default() },
            llm: SimLlmConfig::default(),
            judge: Default::default(),
            workers: 4,
            batch: Default::default(),
        },
    ));
    s.handle("how do i reset my password", None);
    // Under θ=0.99 a paraphrase no longer hits.
    let r = s.handle("how can i reset my password", None);
    assert_eq!(r.source, ReplySource::Llm);
}

#[test]
fn workload_covers_all_categories_with_ground_truth() {
    let ds = WorkloadGenerator::new(1).generate(&DatasetConfig::small());
    for c in ALL_CATEGORIES {
        let base: Vec<_> = ds.base_for(c).collect();
        assert!(!base.is_empty());
        // Every non-novel test query's answer group exists in the base.
        let groups: std::collections::HashSet<u64> =
            base.iter().map(|p| p.answer_group).collect();
        for q in ds.tests_for(c).filter(|q| !q.novel) {
            assert!(groups.contains(&q.answer_group), "{c:?}: {}", q.text);
        }
    }
}
