//! Durability proof suite (ISSUE 6): crash injection against the real
//! `semcached` binary, a seeded corruption fuzzer over WAL/snapshot
//! bytes, a state-parity property test over random op traces, and a
//! directed TTL-across-downtime test.
//!
//! The crash-safety contract under test (see `persist/mod.rs`):
//! * every acknowledged mutation survives SIGKILL (WAL-before-ack);
//! * recovery treats torn tails as normal — valid prefix, never a panic;
//! * a record that fails its checksum is never served;
//! * entries that expired while the process was down are not served, and
//!   their graph nodes are tombstoned then compacted at the next
//!   snapshot.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use semcache::cache::{CacheConfig, CachedEntry, IndexKind, SemanticCache};
use semcache::metrics::Metrics;
use semcache::persist::{PersistConfig, Persistence, WalSync};
use semcache::store::{Clock, ManualClock};
use semcache::testutil::{prop_check, PropConfig};
use semcache::util::SplitMix64;

// ---------- shared helpers ----------

/// Fresh (pre-cleaned) scratch directory under the system temp dir.
fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("semcache-durab-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    d
}

fn pcfg(dir: &Path) -> PersistConfig {
    PersistConfig {
        data_dir: dir.to_path_buf(),
        snapshot_interval_secs: 3_600,
        wal_sync: WalSync::Os,
    }
}

fn ccfg() -> CacheConfig {
    CacheConfig::builder().index(IndexKind::Hnsw).ttl_ms(0).build().unwrap()
}

/// Deterministic non-degenerate embedding for entry `i`.
fn vec_for(i: u64, dim: usize) -> Vec<f32> {
    (0..dim).map(|d| ((i * 31 + d as u64 * 7) % 13) as f32 - 6.0).collect()
}

/// One-hot vector (orthogonal directions; lookups discriminate exactly).
fn axis(i: usize, dim: usize) -> Vec<f32> {
    let mut v = vec![0.0; dim];
    v[i % dim] = 1.0;
    v
}

fn entry(q: &str, r: &str) -> CachedEntry {
    CachedEntry { question: q.to_string(), response: r.to_string(), cluster: 0, latency_ms: 0.0 }
}

/// Canonical comparable image of the cache's live state: per partition
/// (sorted by dim) the id allocator and every live entry with exact
/// embedding bits and absolute expiry.
type StateImage = Vec<(usize, u64, Vec<(u64, u64, String, String, u64, Vec<u32>)>)>;

fn state_image(cache: &SemanticCache) -> StateImage {
    cache
        .partitions()
        .iter()
        .map(|p| {
            let d = p.dump();
            let entries = d
                .entries
                .iter()
                .map(|e| {
                    (
                        e.id,
                        e.expires_wall_ms,
                        e.entry.question.clone(),
                        e.entry.response.clone(),
                        e.entry.cluster,
                        e.embedding.iter().map(|f| f.to_bits()).collect(),
                    )
                })
                .collect();
            (d.dim, d.next_id, entries)
        })
        .collect()
}

// ---------- crash injection against the real daemon ----------

#[cfg(unix)]
mod crash {
    use super::*;
    use std::process::{Child, Command, Stdio};
    use std::time::{Duration, Instant};

    use semcache::api::QueryRequest;
    use semcache::coordinator::http_request;
    use semcache::json::Value;

    /// Kills the daemon (SIGKILL) when dropped, so a failing assertion
    /// never leaks a background `semcached` into the test runner.
    struct Daemon(Child);

    impl Drop for Daemon {
        fn drop(&mut self) {
            let _ = self.0.kill();
            let _ = self.0.wait();
        }
    }

    fn spawn_daemon(data_dir: &Path, port_file: &Path) -> Daemon {
        let child = Command::new(env!("CARGO_BIN_EXE_semcached"))
            .args([
                "serve",
                "--port",
                "0",
                "--port-file",
                port_file.to_str().unwrap(),
                "--data-dir",
                data_dir.to_str().unwrap(),
            ])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning semcached");
        Daemon(child)
    }

    /// Ready-signal handshake: wait for the atomically-written port file,
    /// then poll /v1/metrics until the daemon answers.
    fn wait_ready(port_file: &Path, daemon: &mut Daemon) -> String {
        let deadline = Instant::now() + Duration::from_secs(120);
        let addr = loop {
            if let Ok(s) = fs::read_to_string(port_file) {
                if !s.is_empty() {
                    break s;
                }
            }
            if let Ok(Some(status)) = daemon.0.try_wait() {
                panic!("semcached exited before becoming ready: {status}");
            }
            assert!(Instant::now() < deadline, "semcached never wrote its port file");
            std::thread::sleep(Duration::from_millis(50));
        };
        loop {
            if http_request(&addr, "GET", "/v1/metrics", None).is_ok() {
                break;
            }
            assert!(Instant::now() < deadline, "semcached never became healthy at {addr}");
            std::thread::sleep(Duration::from_millis(50));
        }
        addr
    }

    fn post_query(addr: &str, text: &str) -> (u16, Value) {
        let req = QueryRequest::new(text).to_json().to_string();
        http_request(addr, "POST", "/v1/query", Some(&req)).expect("query round-trip")
    }

    #[test]
    fn sigkill_mid_write_recovers_every_acked_entry() {
        let root = tmpdir("crash");
        fs::create_dir_all(&root).unwrap();
        let data = root.join("data");
        let port_file = root.join("port");

        let mut daemon = spawn_daemon(&data, &port_file);
        let addr = wait_ready(&port_file, &mut daemon);

        // Acked inserts: once /v1/query returns, the record is in the
        // WAL (write-before-ack), so it MUST survive SIGKILL.
        let mut acked: Vec<(String, String)> = Vec::new();
        let texts = [
            "how do i reset my password",
            "what is the refund policy for the pro plan",
            "my invoice shows a duplicate charge",
            "how can i export all of my account data",
        ];
        for text in texts {
            let (status, body) = post_query(&addr, text);
            assert_eq!(status, 200, "pre-crash insert failed: {body}");
            let resp = body.get("response").as_str().expect("miss carries a response").to_string();
            acked.push((text.to_string(), resp));
        }

        // Seeded mid-write kill: hammer inserts from a side thread and
        // SIGKILL the daemon at a seeded point inside the burst, so the
        // WAL tail is torn mid-record with high probability.
        let burst_addr = addr.clone();
        let burst = std::thread::spawn(move || {
            for i in 0..256u64 {
                let text = format!("in flight write number {i} about topic {}", i * 7 % 31);
                let req = QueryRequest::new(text).to_json().to_string();
                if http_request(&burst_addr, "POST", "/v1/query", Some(&req)).is_err() {
                    break; // daemon died mid-burst — the point of the test
                }
            }
        });
        let mut rng = SplitMix64::new(0xC4A5_4001);
        std::thread::sleep(Duration::from_millis(30 + rng.next_u64() % 400));
        daemon.0.kill().expect("SIGKILL"); // std kill = SIGKILL on unix
        let _ = daemon.0.wait();
        let _ = burst.join();
        drop(daemon);

        // Restart on the same data dir: recovery must come up clean.
        let _ = fs::remove_file(&port_file);
        let mut daemon2 = spawn_daemon(&data, &port_file);
        let addr2 = wait_ready(&port_file, &mut daemon2);

        // /v1/metrics must report the recovery.
        let (status, metrics) = http_request(&addr2, "GET", "/v1/metrics", None).unwrap();
        assert_eq!(status, 200);
        let recovered = metrics.get("recovered_entries").as_u64().unwrap_or(0);
        assert!(
            recovered >= acked.len() as u64,
            "recovered_entries = {recovered}, expected at least the {} acked inserts",
            acked.len()
        );

        // Every acked entry serves a hit with its original response.
        for (text, resp) in &acked {
            let (status, body) = post_query(&addr2, text);
            assert_eq!(status, 200);
            assert_eq!(
                body.get("outcome").get("type").as_str(),
                Some("hit"),
                "pre-crash entry '{text}' must hit after recovery, got {body}"
            );
            assert_eq!(
                body.get("response").as_str(),
                Some(resp.as_str()),
                "recovered entry must serve its original response"
            );
        }

        // Semantic (paraphrase) hit survives too — the graph recovered,
        // not just exact bytes (same pair verify.sh uses).
        let (_, body) = post_query(&addr2, "how can i reset my password");
        assert_eq!(
            body.get("outcome").get("type").as_str(),
            Some("hit"),
            "paraphrase of a recovered entry must hit, got {body}"
        );
        assert_eq!(body.get("response").as_str(), Some(acked[0].1.as_str()));

        drop(daemon2);
        let _ = fs::remove_dir_all(&root);
    }
}

// ---------- seeded corruption fuzzer ----------

fn copy_dir(src: &Path, dst: &Path) {
    fs::create_dir_all(dst).unwrap();
    for e in fs::read_dir(src).unwrap().flatten() {
        if e.path().is_file() {
            fs::copy(e.path(), dst.join(e.file_name())).unwrap();
        }
    }
}

/// Apply one seeded mutation to a random persistence file: a truncation,
/// a burst of bit-flips, or both.
fn mutate_dir(dir: &Path, rng: &mut SplitMix64) {
    let mut files: Vec<PathBuf> =
        fs::read_dir(dir).unwrap().flatten().map(|e| e.path()).filter(|p| p.is_file()).collect();
    files.sort(); // deterministic order for a given seed
    if files.is_empty() {
        return;
    }
    let target = &files[(rng.next_u64() % files.len() as u64) as usize];
    let mut bytes = fs::read(target).unwrap();
    let mode = rng.next_u64() % 3;
    if (mode == 0 || mode == 2) && !bytes.is_empty() {
        // Torn tail / torn file: cut at a random length (possibly 0).
        bytes.truncate((rng.next_u64() % (bytes.len() as u64 + 1)) as usize);
    }
    if (mode == 1 || mode == 2) && !bytes.is_empty() {
        let flips = 1 + rng.next_u64() % 8;
        for _ in 0..flips {
            let at = (rng.next_u64() % bytes.len() as u64) as usize;
            bytes[at] ^= 1 << (rng.next_u64() % 8);
        }
    }
    fs::write(target, bytes).unwrap();
}

#[test]
fn corruption_fuzzer_never_panics_never_serves_corrupt_records() {
    // Pristine history: 40 inserts with a snapshot in the middle (so both
    // snapshot bytes and WAL-suffix bytes exist to corrupt), one remove.
    let base = tmpdir("fuzz-base");
    let dim = 12;
    let mut truth: BTreeMap<String, (String, Vec<u32>)> = BTreeMap::new();
    {
        let clock = Arc::new(ManualClock::new(10_000));
        let (cache, p, _) =
            Persistence::open(&pcfg(&base), ccfg(), clock, Arc::new(Metrics::new())).unwrap();
        for i in 0..40u64 {
            let emb = vec_for(i, dim);
            let q = format!("question {i}");
            let r = format!("answer {i}");
            cache.try_insert(&q, &emb, &r).unwrap();
            truth.insert(q, (r, emb.iter().map(|f| f.to_bits()).collect()));
            if i == 24 {
                p.snapshot(&cache).unwrap();
            }
        }
        // A remove record in the WAL suffix. `truth` deliberately keeps
        // the removed entry's content: a truncation landing before the
        // remove record legitimately recovers the pre-remove prefix, and
        // the subset check below is about content fidelity, not about
        // which prefix of history survived.
        assert!(cache.remove_entry(dim, 3));
    }

    // >= 64 seeded mutations (ISSUE 6 floor), each over a fresh copy.
    let mut survived = 0usize;
    for seed in 0..72u64 {
        let work = tmpdir(&format!("fuzz-{seed}"));
        copy_dir(&base, &work);
        let mut rng = SplitMix64::new(0xF0_22ED ^ (seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)));
        mutate_dir(&work, &mut rng);

        // Recovery must not panic and must not error on corrupt bytes —
        // corruption degrades to recovering less, never to failure.
        let clock = Arc::new(ManualClock::new(10_000));
        let (cache, _p, rep) =
            Persistence::open(&pcfg(&work), ccfg(), clock, Arc::new(Metrics::new()))
                .unwrap_or_else(|e| panic!("seed {seed}: recovery errored on corrupt dir: {e:#}"));

        // Whatever was recovered must be a content-identical subset of
        // what was written: a checksum-failing record is dropped whole,
        // never served with altered bytes.
        let mut n = 0usize;
        for part in cache.partitions() {
            let d = part.dump();
            assert_eq!(d.dim, dim);
            for e in d.entries {
                let (resp, emb_bits) = truth
                    .get(&e.entry.question)
                    .unwrap_or_else(|| panic!("seed {seed}: recovered a never-written entry {:?}", e.entry.question));
                assert_eq!(&e.entry.response, resp, "seed {seed}: response bytes altered");
                let got: Vec<u32> = e.embedding.iter().map(|f| f.to_bits()).collect();
                assert_eq!(&got, emb_bits, "seed {seed}: embedding bits altered");
                n += 1;
            }
        }
        assert_eq!(n, rep.entries, "seed {seed}: report disagrees with state");
        assert!(n <= truth.len(), "seed {seed}: recovered more than was ever written");
        // The recovered subset still serves.
        if n > 0 {
            let served = (0..40u64)
                .filter(|i| cache.lookup(&vec_for(*i, dim)).is_some())
                .count();
            assert!(served > 0, "seed {seed}: recovered entries do not serve");
        }
        survived += 1;
        let _ = fs::remove_dir_all(&work);
    }
    assert_eq!(survived, 72);
    let _ = fs::remove_dir_all(&base);
}

// ---------- property: recovered state is entry-for-entry identical ----------

#[test]
fn prop_recovered_state_matches_live_state() {
    // Random op trace (inserts across two dims, per-entry TTLs, removes,
    // clock advances, rare flushes) with a snapshot forced at a random
    // cut point; recovery under the same wall clock must reproduce the
    // live state exactly: ids, payloads, embedding bits, absolute
    // expiries, and the id allocator.
    prop_check(
        PropConfig { cases: 24, seed: 0xD0_57ED, ..Default::default() },
        "durability-state-parity",
        |g| {
            let dir = tmpdir("prop");
            let clock = Arc::new(ManualClock::new(50_000));
            let (cache, p, _) =
                Persistence::open(&pcfg(&dir), ccfg(), clock.clone(), Arc::new(Metrics::new()))
                    .map_err(|e| format!("open: {e:#}"))?;

            let n_ops = g.usize_in(5, 50);
            let snap_at = g.usize_below(n_ops);
            let mut live_ids: Vec<(usize, u64)> = Vec::new();
            for op in 0..n_ops {
                if op == snap_at {
                    p.snapshot(&cache).map_err(|e| format!("snapshot: {e:#}"))?;
                }
                match g.usize_below(10) {
                    0..=5 => {
                        let dim = *g.choose(&[6usize, 10]);
                        let emb = g.vec_f32(dim, -1.0, 1.0);
                        let ttl = match g.usize_below(3) {
                            0 => None,    // config default (immortal here)
                            1 => Some(0), // explicit immortal
                            _ => Some(g.usize_in(100, 5_000) as u64),
                        };
                        let e = entry(&g.word(), &g.word());
                        let id = cache
                            .try_insert_entry_ttl(&emb, e, ttl)
                            .map_err(|e| format!("insert: {e:#}"))?;
                        live_ids.push((dim, id));
                    }
                    6 | 7 => {
                        if !live_ids.is_empty() {
                            let (dim, id) = live_ids[g.usize_below(live_ids.len())];
                            cache.remove_entry(dim, id);
                        }
                    }
                    8 => clock.advance(g.usize_in(0, 2_000) as u64),
                    _ => {
                        if g.usize_below(4) == 0 {
                            cache.clear();
                            live_ids.clear();
                        }
                    }
                }
            }

            let before = state_image(&cache);
            drop(cache);
            drop(p);

            // Reopen at the same wall time (no downtime in this property;
            // downtime is the directed test below).
            let clock2 = Arc::new(ManualClock::new(clock.now_ms()));
            let (cache2, _p2, _rep) =
                Persistence::open(&pcfg(&dir), ccfg(), clock2, Arc::new(Metrics::new()))
                    .map_err(|e| format!("reopen: {e:#}"))?;
            let after = state_image(&cache2);
            if before != after {
                return Err(format!(
                    "recovered state diverged\n live: {} partitions, {} entries\n recovered: {} partitions, {} entries",
                    before.len(),
                    before.iter().map(|p| p.2.len()).sum::<usize>(),
                    after.len(),
                    after.iter().map(|p| p.2.len()).sum::<usize>(),
                ));
            }
            let _ = fs::remove_dir_all(&dir);
            Ok(())
        },
    );
}

// ---------- racing dependent mutations: WAL order == apply order ----------

#[test]
fn wal_order_matches_apply_order_under_racing_dependent_mutations() {
    // Regression (review, medium): apply-then-log was not atomic per
    // mutation, so a remove (observed via lookup) or a clear racing an
    // insert could log its record *before* the insert's, and replay
    // then resurrected a removed entry or dropped an acknowledged one.
    // With the journal gate, every interleaving must recover to exactly
    // the live state.
    let dir = tmpdir("order");
    let dim = 8;
    let clock = Arc::new(ManualClock::new(7_000));
    let (cache, p, _) =
        Persistence::open(&pcfg(&dir), ccfg(), clock, Arc::new(Metrics::new())).unwrap();
    let cache = Arc::new(cache);

    let mut handles = Vec::new();
    // Writers: steady stream of acknowledged inserts.
    for t in 0..3u64 {
        let c = cache.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..200u64 {
                let emb = vec_for(t * 10_000 + i, dim);
                c.try_insert(&format!("t{t}q{i}"), &emb, &format!("t{t}a{i}")).unwrap();
            }
        }));
    }
    // Reaper: the review's exact race — observe an id via lookup, then
    // remove it while its inserter may still sit between apply and log.
    {
        let c = cache.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = SplitMix64::new(0x0D_DE12);
            for _ in 0..400 {
                let key = (rng.next_u64() % 3) * 10_000 + rng.next_u64() % 200;
                if let Some(hit) = c.lookup_with_threshold(&vec_for(key, dim), 0.99) {
                    c.remove_entry(dim, hit.id);
                }
            }
        }));
    }
    // Chaos: occasional full flushes racing everything above.
    {
        let c = cache.clone();
        handles.push(std::thread::spawn(move || {
            for _ in 0..5 {
                std::thread::sleep(std::time::Duration::from_millis(2));
                c.clear();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let before = state_image(&cache);
    drop(p);
    drop(cache);
    let (cache2, _p2, _rep) = Persistence::open(
        &pcfg(&dir),
        ccfg(),
        Arc::new(ManualClock::new(7_000)),
        Arc::new(Metrics::new()),
    )
    .unwrap();
    assert_eq!(
        before,
        state_image(&cache2),
        "recovered state must be identical to live state under racing dependent mutations"
    );
    let _ = fs::remove_dir_all(&dir);
}

// ---------- directed: TTL across downtime ----------

#[test]
fn ttl_expiry_during_downtime_is_honored_and_compacted() {
    let dir = tmpdir("downtime");
    let dim = 8;

    // t = 100s: six entries with a 1s TTL, two immortal; snapshot so the
    // persisted graph carries all eight nodes.
    {
        let clock = Arc::new(ManualClock::new(100_000));
        let (cache, p, _) =
            Persistence::open(&pcfg(&dir), ccfg(), clock, Arc::new(Metrics::new())).unwrap();
        for i in 0..6 {
            cache
                .try_insert_entry_ttl(&axis(i, dim), entry(&format!("m{i}"), "mortal"), Some(1_000))
                .unwrap();
        }
        for i in 6..8 {
            cache
                .try_insert_entry_ttl(&axis(i, dim), entry(&format!("im{i}"), "forever"), Some(0))
                .unwrap();
        }
        p.snapshot(&cache).unwrap();
    }

    // 5 s of downtime (simulated: reopen under a later wall clock — no
    // sleeping). The six mortal entries died while the process was down.
    let clock = Arc::new(ManualClock::new(105_000));
    let (cache, p, rep) =
        Persistence::open(&pcfg(&dir), ccfg(), clock, Arc::new(Metrics::new())).unwrap();
    assert!(rep.snapshot_loaded);
    assert_eq!(rep.expired_during_downtime, 6);
    assert_eq!(rep.entries, 2);
    assert_eq!(cache.len(), 2);
    for i in 0..6 {
        assert!(
            cache.lookup(&axis(i, dim)).is_none(),
            "entry {i} expired during downtime and must not be served"
        );
    }
    for i in 6..8 {
        assert_eq!(cache.lookup(&axis(i, dim)).unwrap().entry.response, "forever");
    }

    // The loaded graph carried 8 nodes; the 6 dead ones are tombstones,
    // and garbage_ratio sees them without any lookup having tripped.
    let part = cache.partition_if_exists(dim).expect("partition recovered");
    assert!(
        part.garbage_ratio() > 0.70,
        "dead-during-downtime nodes must be tombstoned, ratio = {}",
        part.garbage_ratio()
    );

    // The next snapshot folds in compaction: tombstones reclaimed.
    p.snapshot(&cache).unwrap();
    assert_eq!(part.garbage_ratio(), 0.0, "snapshot must compact tombstoned nodes");

    // And the compacted snapshot round-trips clean: no re-index fallback,
    // no dead entries, survivors still served.
    drop(cache);
    drop(p);
    let clock2 = Arc::new(ManualClock::new(106_000));
    let (cache2, _p2, rep2) =
        Persistence::open(&pcfg(&dir), ccfg(), clock2, Arc::new(Metrics::new())).unwrap();
    assert_eq!(rep2.entries, 2);
    assert_eq!(rep2.reindexed_partitions, 0);
    assert_eq!(rep2.expired_during_downtime, 0);
    assert_eq!(cache2.lookup(&axis(7, dim)).unwrap().entry.response, "forever");
    let _ = fs::remove_dir_all(&dir);
}
