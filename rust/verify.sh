#!/usr/bin/env bash
# Tier-1 verification for the semcache crate, one command:
#
#   ./verify.sh            (or: make verify, from the repo root)
#
# Steps: format check, release build, unit+integration tests, doc tests,
# an HTTP loopback smoke test of the `semcached` daemon (same query
# twice over the wire -> the repeat must be a cache hit), an idle-fan-in
# smoke (32 idle keep-alive connections must not starve a fresh query on
# the default event loop), a kill-9 durability smoke (populate a
# --data-dir daemon, SIGKILL, restart <= 3s, paraphrase must still hit
# with recovered_entries > 0), a two-tenant quota-breach smoke (a
# quota-capped tenant flooding past its byte quota evicts only itself;
# the other tenant's entry survives and per-tenant metric blocks agree),
# an upstream-outage chaos smoke (flip the simulated LLM into full
# outage via `admin fault --outage`: a paraphrase must be served from
# cache as a marked *degraded* hit, a novel query must get a typed 503
# instead of hanging, and clearing the fault must restore fresh
# misses), a forced-scalar kernel arm (SEMCACHE_SCALAR_KERNELS=1 re-runs
# the unit + hot-path suites on the seed matmul / exact-scan paths), and
# a smoke run of the serving benches (SEMCACHE_BENCH_SMOKE=1 keeps each
# to a few seconds; the embed and hnsw benches append JSON-lines results
# to BENCH_embed.json / BENCH_hnsw.json). Fails fast on the first
# broken step.
set -euo pipefail
cd "$(dirname "$0")"

# Format check: reported, but non-fatal — rustfmt output differs across
# toolchain versions, and tier-1 must not flake on whitespace. Run
# `cargo fmt` locally to fix anything reported here.
if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check (advisory)"
    cargo fmt -- --check || echo "WARNING: formatting drift detected (run 'cargo fmt'); continuing"
else
    echo "==> cargo fmt unavailable in this toolchain; skipping format check"
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo test --doc -q"
cargo test --doc -q

# Forced-scalar kernel arm (ISSUE 10): SEMCACHE_SCALAR_KERNELS=1 routes
# the encoder matmul and the ANN candidate scan through the seed scalar
# paths, so both sides of every kernel dispatch stay covered. The unit
# suite plus the two hot-path integration suites re-run under it; the
# parity properties make any blocked/quantized-vs-scalar divergence a
# hard failure.
echo "==> forced-scalar kernel arm: SEMCACHE_SCALAR_KERNELS=1 cargo test (unit + hot-path suites)"
SEMCACHE_SCALAR_KERNELS=1 cargo test -q --lib
SEMCACHE_SCALAR_KERNELS=1 cargo test -q --test embed_hotpath --test proptests

echo "==> HTTP loopback smoke: semcached serve (batched query path)"
PORT_FILE="$(mktemp)"
./target/release/semcached serve --port 0 --port-file "$PORT_FILE" &
SRV_PID=$!
trap 'kill "$SRV_PID" 2>/dev/null || true' EXIT
# Ready-signal handshake, not a fixed sleep: wait for the atomically
# written port file, then poll the daemon until it answers metrics.
for _ in $(seq 1 100); do
    [ -s "$PORT_FILE" ] && break
    sleep 0.1
done
[ -s "$PORT_FILE" ] || { echo "semcached did not come up (no port file)"; exit 1; }
ADDR="$(cat "$PORT_FILE")"
READY=0
for _ in $(seq 1 100); do
    if ./target/release/semcached metrics --addr "$ADDR" >/dev/null 2>&1; then
        READY=1
        break
    fi
    sleep 0.1
done
[ "$READY" = 1 ] || { echo "semcached did not become healthy at $ADDR"; exit 1; }
echo "    daemon at $ADDR"
# Paraphrased-query hit check through the micro-batching engine (the
# default /v1/query path): miss, then the paraphrase must hit.
./target/release/semcached query --addr "$ADDR" "how do i reset my password" >/dev/null
OUT="$(./target/release/semcached query --addr "$ADDR" "how can i reset my password")"
echo "$OUT" | grep -q '"type": "hit"' \
    || { echo "loopback smoke FAILED: repeated query was not a cache hit"; echo "$OUT"; exit 1; }
METRICS="$(./target/release/semcached metrics --addr "$ADDR")"
echo "$METRICS" | grep -q '"cache_hits": 1' \
    || { echo "loopback smoke FAILED: /v1/metrics does not reflect the hit"; exit 1; }
# Batcher smoke: both queries must have flowed through the dispatcher,
# and the serving counters must satisfy the extended balance:
#   cache_hits + cache_misses + degraded_hits + rejected == requests
num() { echo "$METRICS" | sed -n "s/.*\"$1\": \([0-9][0-9]*\).*/\1/p" | head -1; }
REQS="$(num requests)"; HITS="$(num cache_hits)"; MISSES="$(num cache_misses)"
DEG="$(num degraded_hits)"; REJ="$(num rejected)"
DISPATCHES="$(num batcher_dispatches)"
[ -n "$REQS" ] && [ -n "$HITS" ] && [ -n "$MISSES" ] && [ -n "$DEG" ] && [ -n "$REJ" ] \
    || { echo "batcher smoke FAILED: could not parse metrics"; echo "$METRICS"; exit 1; }
[ "$((HITS + MISSES + DEG + REJ))" -eq "$REQS" ] \
    || { echo "batcher smoke FAILED: hits($HITS)+misses($MISSES)+degraded($DEG)+rejected($REJ) != requests($REQS)"; exit 1; }
[ "${DISPATCHES:-0}" -ge 1 ] \
    || { echo "batcher smoke FAILED: /v1/query did not go through the batcher"; echo "$METRICS"; exit 1; }
echo "    loopback smoke OK (miss -> paraphrase hit via the batcher; metrics consistent: $HITS+$MISSES+$DEG+$REJ == $REQS, $DISPATCHES dispatches)"

# Idle-fan-in smoke (ISSUE 5): hold 8x more idle keep-alive connections
# than the daemon has request workers (4), then a fresh query must still
# answer promptly — the thread-per-connection design fails exactly this
# shape; the default event loop must not.
echo "==> HTTP loopback smoke: 32 idle keep-alive connections vs a fresh query (event loop)"
./target/release/semcached stress-idle --addr "$ADDR" --conns 32 --hold-ms 15000 &
IDLE_PID=$!
sleep 0.5
T0=$(date +%s)
./target/release/semcached query --addr "$ADDR" "does idle fan-in starve the event loop" >/dev/null \
    || { echo "idle-fan-in smoke FAILED: query errored under idle fan-in"; kill "$IDLE_PID" 2>/dev/null || true; exit 1; }
T1=$(date +%s)
[ $((T1 - T0)) -le 3 ] \
    || { echo "idle-fan-in smoke FAILED: query took $((T1 - T0))s behind 32 idle connections"; kill "$IDLE_PID" 2>/dev/null || true; exit 1; }
METRICS="$(./target/release/semcached metrics --addr "$ADDR")"
OPEN="$(num open_connections)"
[ "${OPEN:-0}" -ge 32 ] \
    || { echo "idle-fan-in smoke FAILED: open_connections gauge shows ${OPEN:-0} < 32"; echo "$METRICS"; kill "$IDLE_PID" 2>/dev/null || true; exit 1; }
kill "$IDLE_PID" 2>/dev/null || true
wait "$IDLE_PID" 2>/dev/null || true
echo "    idle-fan-in smoke OK (query answered in $((T1 - T0))s behind $OPEN open connections)"

kill "$SRV_PID" 2>/dev/null || true
wait "$SRV_PID" 2>/dev/null || true
trap - EXIT

# Multi-reactor smoke (PR 8): a 4-reactor daemon under a 32-idle-conn
# fleet must answer a fresh query within 3 s, and the per-reactor
# `reactors` blocks on /v1/metrics must sum to the aggregate gauges
# (the blocks use the short key `open`, which appears nowhere else in
# the JSON, so a flat scrape-and-sum is unambiguous).
echo "==> multi-reactor smoke: --reactors 4 under 32 idle connections"
PORT_FILE="$(mktemp)"
./target/release/semcached serve --port 0 --port-file "$PORT_FILE" --reactors 4 --dispatchers 2 &
SRV_PID=$!
trap 'kill "$SRV_PID" 2>/dev/null || true' EXIT
for _ in $(seq 1 100); do
    [ -s "$PORT_FILE" ] && break
    sleep 0.1
done
[ -s "$PORT_FILE" ] || { echo "multi-reactor semcached did not come up (no port file)"; exit 1; }
ADDR="$(cat "$PORT_FILE")"
for _ in $(seq 1 100); do
    ./target/release/semcached metrics --addr "$ADDR" >/dev/null 2>&1 && break
    sleep 0.1
done
./target/release/semcached stress-idle --addr "$ADDR" --conns 32 --hold-ms 15000 &
IDLE_PID=$!
sleep 0.5
T0=$(date +%s)
./target/release/semcached query --addr "$ADDR" "does the sharded reactor fleet starve a fresh query" >/dev/null \
    || { echo "multi-reactor smoke FAILED: query errored under idle fan-in"; kill "$IDLE_PID" 2>/dev/null || true; exit 1; }
T1=$(date +%s)
[ $((T1 - T0)) -le 3 ] \
    || { echo "multi-reactor smoke FAILED: query took $((T1 - T0))s behind 32 idle connections"; kill "$IDLE_PID" 2>/dev/null || true; exit 1; }
METRICS="$(./target/release/semcached metrics --addr "$ADDR")"
OPEN="$(num open_connections)"
ACCEPTED="$(num conns_accepted)"
REACTOR_BLOCKS="$(echo "$METRICS" | grep -c '"stalls":' || true)"
ROPEN_SUM="$(echo "$METRICS" | sed -n 's/.*"open": \([0-9][0-9]*\).*/\1/p' | awk '{s+=$1} END {print s+0}')"
RACCEPTED_SUM="$(echo "$METRICS" | sed -n 's/.*"accepted": \([0-9][0-9]*\).*/\1/p' | awk '{s+=$1} END {print s+0}')"
[ "${REACTOR_BLOCKS:-0}" -eq 4 ] \
    || { echo "multi-reactor smoke FAILED: expected 4 per-reactor blocks, got ${REACTOR_BLOCKS:-0}"; echo "$METRICS"; kill "$IDLE_PID" 2>/dev/null || true; exit 1; }
[ "${ROPEN_SUM:-0}" -eq "${OPEN:-1}" ] \
    || { echo "multi-reactor smoke FAILED: per-reactor open sum $ROPEN_SUM != open_connections $OPEN"; echo "$METRICS"; kill "$IDLE_PID" 2>/dev/null || true; exit 1; }
[ "${RACCEPTED_SUM:-0}" -eq "${ACCEPTED:-1}" ] \
    || { echo "multi-reactor smoke FAILED: per-reactor accepted sum $RACCEPTED_SUM != conns_accepted $ACCEPTED"; echo "$METRICS"; kill "$IDLE_PID" 2>/dev/null || true; exit 1; }
[ "${OPEN:-0}" -ge 32 ] \
    || { echo "multi-reactor smoke FAILED: open_connections gauge shows ${OPEN:-0} < 32"; echo "$METRICS"; kill "$IDLE_PID" 2>/dev/null || true; exit 1; }
kill "$IDLE_PID" 2>/dev/null || true
wait "$IDLE_PID" 2>/dev/null || true
kill "$SRV_PID" 2>/dev/null || true
wait "$SRV_PID" 2>/dev/null || true
trap - EXIT
echo "    multi-reactor smoke OK (fresh query in $((T1 - T0))s; 4 reactor blocks sum to aggregates: open $ROPEN_SUM == $OPEN, accepted $RACCEPTED_SUM == $ACCEPTED)"

# Kill-9 durability smoke (ISSUE 6): populate a daemon serving with a
# data dir, SIGKILL it (no graceful shutdown of any kind), restart on
# the same dir, and the pre-crash entry must still answer — including
# via a paraphrase (the recovered ANN graph, not just exact bytes) —
# with /v1/metrics reporting the recovery. The restart-to-ready window
# is bounded at 3 s: warm restarts must be fast enough to roll through.
echo "==> kill-9 durability smoke: populate -> SIGKILL -> warm restart -> paraphrase hit"
DATA_DIR="$(mktemp -d)"
PORT_FILE="$(mktemp)"
./target/release/semcached serve --port 0 --port-file "$PORT_FILE" --data-dir "$DATA_DIR" &
SRV_PID=$!
trap 'kill -9 "$SRV_PID" 2>/dev/null || true; rm -rf "$DATA_DIR"' EXIT
for _ in $(seq 1 100); do
    [ -s "$PORT_FILE" ] && break
    sleep 0.1
done
[ -s "$PORT_FILE" ] || { echo "durable semcached did not come up (no port file)"; exit 1; }
ADDR="$(cat "$PORT_FILE")"
for _ in $(seq 1 100); do
    ./target/release/semcached metrics --addr "$ADDR" >/dev/null 2>&1 && break
    sleep 0.1
done
./target/release/semcached query --addr "$ADDR" "how do i reset my password" >/dev/null
ORIG="$(./target/release/semcached query --addr "$ADDR" "how do i reset my password")"
kill -9 "$SRV_PID" 2>/dev/null || true
wait "$SRV_PID" 2>/dev/null || true
: > "$PORT_FILE"
T0=$(date +%s)
./target/release/semcached serve --port 0 --port-file "$PORT_FILE" --data-dir "$DATA_DIR" &
SRV_PID=$!
trap 'kill -9 "$SRV_PID" 2>/dev/null || true; rm -rf "$DATA_DIR"' EXIT
READY=0
for _ in $(seq 1 100); do
    if [ -s "$PORT_FILE" ] \
        && ./target/release/semcached metrics --addr "$(cat "$PORT_FILE")" >/dev/null 2>&1; then
        READY=1
        break
    fi
    sleep 0.1
done
T1=$(date +%s)
[ "$READY" = 1 ] || { echo "durability smoke FAILED: daemon did not restart"; exit 1; }
[ $((T1 - T0)) -le 3 ] \
    || { echo "durability smoke FAILED: warm restart took $((T1 - T0))s (> 3s)"; exit 1; }
ADDR="$(cat "$PORT_FILE")"
OUT="$(./target/release/semcached query --addr "$ADDR" "how can i reset my password")"
echo "$OUT" | grep -q '"type": "hit"' \
    || { echo "durability smoke FAILED: paraphrase did not hit after SIGKILL restart"; echo "$OUT"; exit 1; }
echo "$ORIG" | grep -qF "$(echo "$OUT" | sed -n 's/.*"response": "\([^"]*\)".*/\1/p')" \
    || { echo "durability smoke FAILED: recovered response differs from the pre-crash one"; exit 1; }
METRICS="$(./target/release/semcached metrics --addr "$ADDR")"
RECOVERED="$(num recovered_entries)"
[ "${RECOVERED:-0}" -ge 1 ] \
    || { echo "durability smoke FAILED: recovered_entries shows ${RECOVERED:-0}"; echo "$METRICS"; exit 1; }
kill -9 "$SRV_PID" 2>/dev/null || true
wait "$SRV_PID" 2>/dev/null || true
rm -rf "$DATA_DIR"
trap - EXIT
echo "    durability smoke OK (SIGKILL -> restart in $((T1 - T0))s, $RECOVERED entries recovered, paraphrase hit)"

# Two-tenant quota-breach smoke (ISSUE 7): tenant "small" gets an 8 KiB
# byte quota (~2 entries at the default 384-d encoder geometry) and
# floods 8 distinct queries past it; tenant "big" parks one entry first.
# The quota pressure must evict only small's own entries — big's entry
# survives verbatim, big's eviction counter stays 0, and the per-tenant
# metric blocks on /v1/metrics tell the story.
echo "==> two-tenant quota-breach smoke: per-tenant byte quotas over HTTP"
PORT_FILE="$(mktemp)"
./target/release/semcached serve --port 0 --port-file "$PORT_FILE" \
    --max_bytes 262144 --tenant.small.quota_bytes 8192 &
SRV_PID=$!
trap 'kill "$SRV_PID" 2>/dev/null || true' EXIT
for _ in $(seq 1 100); do
    [ -s "$PORT_FILE" ] && break
    sleep 0.1
done
[ -s "$PORT_FILE" ] || { echo "tenant-smoke semcached did not come up (no port file)"; exit 1; }
ADDR="$(cat "$PORT_FILE")"
for _ in $(seq 1 100); do
    ./target/release/semcached metrics --addr "$ADDR" >/dev/null 2>&1 && break
    sleep 0.1
done
./target/release/semcached query --addr "$ADDR" --tag big \
    "what is the refund policy for the pro plan" >/dev/null
for i in $(seq 1 8); do
    # --threshold 0.9999 forces each distinct flood text to miss (and
    # insert) instead of hitting a semantic neighbor.
    ./target/release/semcached query --addr "$ADDR" --tag small --threshold 0.9999 \
        "small tenant flood query number $i with unique marker $((i * 31 + 7))" >/dev/null
done
METRICS="$(./target/release/semcached metrics --addr "$ADDR")"
# Scope a counter to one tenant's block in the pretty-printed JSON.
tnum() { echo "$METRICS" | sed -n "/\"$1\": {/,/}/p" | sed -n "s/.*\"$2\": \([0-9][0-9]*\).*/\1/p" | head -1; }
SMALL_EVICTS="$(tnum small evictions)"; BIG_EVICTS="$(tnum big evictions)"
SMALL_BYTES="$(tnum small bytes)"; SMALL_QUOTA="$(tnum small quota_bytes)"
[ -n "$SMALL_EVICTS" ] && [ -n "$BIG_EVICTS" ] && [ -n "$SMALL_BYTES" ] \
    || { echo "tenant smoke FAILED: per-tenant metric blocks missing"; echo "$METRICS"; exit 1; }
[ "$SMALL_QUOTA" = 8192 ] \
    || { echo "tenant smoke FAILED: --tenant.small.quota_bytes did not reach the tenant (got ${SMALL_QUOTA:-none})"; exit 1; }
[ "$SMALL_EVICTS" -ge 1 ] \
    || { echo "tenant smoke FAILED: flooding past an 8 KiB quota evicted nothing"; echo "$METRICS"; exit 1; }
[ "$BIG_EVICTS" -eq 0 ] \
    || { echo "tenant smoke FAILED: small's quota pressure evicted big's entries ($BIG_EVICTS)"; echo "$METRICS"; exit 1; }
[ "$SMALL_BYTES" -le 8192 ] \
    || { echo "tenant smoke FAILED: small holds $SMALL_BYTES B > 8192 B quota at rest"; exit 1; }
OUT="$(./target/release/semcached query --addr "$ADDR" --tag big "what is the refund policy for the pro plan")"
echo "$OUT" | grep -q '"type": "hit"' \
    || { echo "tenant smoke FAILED: big's entry lost under small's quota pressure"; echo "$OUT"; exit 1; }
kill "$SRV_PID" 2>/dev/null || true
wait "$SRV_PID" 2>/dev/null || true
trap - EXIT
echo "    tenant smoke OK (small: $SMALL_EVICTS self-evictions, $SMALL_BYTES B <= 8192 B quota; big untouched and still hitting)"

# Upstream-outage chaos smoke (ISSUE 9): park one entry, then flip the
# simulated LLM into full outage through the live `admin fault` verb.
# The daemon must degrade instead of dying: a paraphrase pushed past
# the strict gate (--threshold 0.9999) is answered from cache as a
# *degraded* hit carrying the pre-outage response verbatim, a novel
# query is refused promptly with a typed upstream-unavailable 503
# (the CLI exits nonzero on it, body still printed), and clearing the
# fault restores fresh misses — with the extended balance holding
# across the whole episode. Retries are off and the breaker-trip bar
# is set unreachably high so every step is deterministic and instant.
echo "==> chaos smoke: admin fault outage -> degraded hit -> typed 503 -> recovery"
PORT_FILE="$(mktemp)"
./target/release/semcached serve --port 0 --port-file "$PORT_FILE" \
    --upstream_max_retries 0 --upstream_deadline_ms 2000 \
    --upstream_breaker_failures 1000000 &
SRV_PID=$!
trap 'kill "$SRV_PID" 2>/dev/null || true' EXIT
for _ in $(seq 1 100); do
    [ -s "$PORT_FILE" ] && break
    sleep 0.1
done
[ -s "$PORT_FILE" ] || { echo "chaos semcached did not come up (no port file)"; exit 1; }
ADDR="$(cat "$PORT_FILE")"
for _ in $(seq 1 100); do
    ./target/release/semcached metrics --addr "$ADDR" >/dev/null 2>&1 && break
    sleep 0.1
done
ORIG="$(./target/release/semcached query --addr "$ADDR" "how do i reset my password")"
./target/release/semcached admin fault --addr "$ADDR" --outage >/dev/null \
    || { echo "chaos smoke FAILED: admin fault --outage was refused"; exit 1; }
T0=$(date +%s)
OUT="$(./target/release/semcached query --addr "$ADDR" --threshold 0.9999 \
    "how can i reset my password")" \
    || { echo "chaos smoke FAILED: degraded-path query errored"; echo "$OUT"; exit 1; }
T1=$(date +%s)
echo "$OUT" | grep -q '"type": "degraded"' \
    || { echo "chaos smoke FAILED: outage paraphrase was not a degraded hit"; echo "$OUT"; exit 1; }
echo "$OUT" | grep -q '"degraded": true' \
    || { echo "chaos smoke FAILED: degraded hit not marked in the latency breakdown"; echo "$OUT"; exit 1; }
echo "$ORIG" | grep -qF "$(echo "$OUT" | sed -n 's/.*"response": "\([^"]*\)".*/\1/p')" \
    || { echo "chaos smoke FAILED: degraded response differs from the cached one"; exit 1; }
[ $((T1 - T0)) -le 5 ] \
    || { echo "chaos smoke FAILED: degraded hit took $((T1 - T0))s during the outage"; exit 1; }
T0=$(date +%s)
REJOUT="$(./target/release/semcached query --addr "$ADDR" --deadline-ms 500 \
    "a question the dead upstream cannot answer" || true)"
T1=$(date +%s)
echo "$REJOUT" | grep -q '"type": "rejected"' \
    || { echo "chaos smoke FAILED: novel query during outage was not rejected"; echo "$REJOUT"; exit 1; }
echo "$REJOUT" | grep -q 'upstream unavailable' \
    || { echo "chaos smoke FAILED: rejection reason is not typed upstream-unavailable"; echo "$REJOUT"; exit 1; }
[ $((T1 - T0)) -le 5 ] \
    || { echo "chaos smoke FAILED: outage rejection took $((T1 - T0))s (unbounded?)"; exit 1; }
./target/release/semcached admin fault --addr "$ADDR" >/dev/null \
    || { echo "chaos smoke FAILED: clearing the fault plan was refused"; exit 1; }
OUT="$(./target/release/semcached query --addr "$ADDR" "an entirely new topic after recovery")"
echo "$OUT" | grep -q '"type": "miss"' \
    || { echo "chaos smoke FAILED: fresh miss did not resume after the fault cleared"; echo "$OUT"; exit 1; }
METRICS="$(./target/release/semcached metrics --addr "$ADDR")"
REQS="$(num requests)"; HITS="$(num cache_hits)"; MISSES="$(num cache_misses)"
DEG="$(num degraded_hits)"; REJ="$(num rejected)"; UPERR="$(num upstream_errors)"
[ "${DEG:-0}" -eq 1 ] \
    || { echo "chaos smoke FAILED: degraded_hits shows ${DEG:-0}, want 1"; echo "$METRICS"; exit 1; }
[ "${REJ:-0}" -eq 1 ] \
    || { echo "chaos smoke FAILED: rejected shows ${REJ:-0}, want 1"; echo "$METRICS"; exit 1; }
[ "${UPERR:-0}" -ge 1 ] \
    || { echo "chaos smoke FAILED: upstream_errors shows ${UPERR:-0} after a full outage"; echo "$METRICS"; exit 1; }
[ "$((HITS + MISSES + DEG + REJ))" -eq "$REQS" ] \
    || { echo "chaos smoke FAILED: hits($HITS)+misses($MISSES)+degraded($DEG)+rejected($REJ) != requests($REQS)"; echo "$METRICS"; exit 1; }
kill "$SRV_PID" 2>/dev/null || true
wait "$SRV_PID" 2>/dev/null || true
trap - EXIT
echo "    chaos smoke OK (degraded hit in $((T1 - T0))s-bounded outage, typed 503, recovery miss; balance $HITS+$MISSES+$DEG+$REJ == $REQS)"

echo "==> smoke bench: bench_batch_throughput (SEMCACHE_BENCH_SMOKE=1)"
SEMCACHE_BENCH_SMOKE=1 cargo bench --bench bench_batch_throughput

# Enforced: the batching (1.5x), idle fan-in (0.8x), reactor-scaling
# (2x with >= 4 cores, else a 0.6x non-regression floor with a printed
# waiver), and massive-idle fresh-query (<= 3 s) floors all gate.
echo "==> smoke bench: bench_http_loopback (SEMCACHE_BENCH_SMOKE=1, enforced)"
SEMCACHE_BENCH_SMOKE=1 SEMCACHE_BENCH_ENFORCE=1 cargo bench --bench bench_http_loopback

# The embed and hnsw benches also append machine-readable results
# (JSON lines) so perf floors become a tracked trajectory across PRs.
echo "==> smoke bench: bench_embed_throughput (SEMCACHE_BENCH_SMOKE=1, json -> BENCH_embed.json)"
: > BENCH_embed.json
SEMCACHE_BENCH_SMOKE=1 SEMCACHE_BENCH_JSON=BENCH_embed.json cargo bench --bench bench_embed_throughput

echo "==> smoke bench: bench_hnsw_scaling (SEMCACHE_BENCH_SMOKE=1, json -> BENCH_hnsw.json)"
: > BENCH_hnsw.json
SEMCACHE_BENCH_SMOKE=1 SEMCACHE_BENCH_JSON=BENCH_hnsw.json cargo bench --bench bench_hnsw_scaling

echo "==> smoke bench: bench_persist_restart (SEMCACHE_BENCH_SMOKE=1)"
SEMCACHE_BENCH_SMOKE=1 cargo bench --bench bench_persist_restart

echo "==> smoke bench: bench_eviction (SEMCACHE_BENCH_SMOKE=1, enforced)"
SEMCACHE_BENCH_SMOKE=1 SEMCACHE_BENCH_ENFORCE=1 cargo bench --bench bench_eviction

echo "==> verify OK"
