#!/usr/bin/env bash
# Tier-1 verification for the semcache crate, one command:
#
#   ./verify.sh            (or: make verify, from the repo root)
#
# Steps: release build, unit+integration tests, doc tests, and a smoke
# run of the batch-throughput bench (SEMCACHE_BENCH_SMOKE=1 keeps it to
# a few seconds). Fails fast on the first broken step.
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo test --doc -q"
cargo test --doc -q

echo "==> smoke bench: bench_batch_throughput (SEMCACHE_BENCH_SMOKE=1)"
SEMCACHE_BENCH_SMOKE=1 cargo bench --bench bench_batch_throughput

echo "==> verify OK"
