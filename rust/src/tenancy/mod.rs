//! Per-tenant namespaces, quotas, and metrics.
//!
//! Every query carries an optional `client_tag`; the cache maps it to a
//! tenant namespace ([`normalize_tag`]: untagged traffic shares the
//! `"default"` tenant). A tenant owns its own dimension-partitioned
//! index/store set, so lookups structurally cannot cross tenant
//! boundaries, and byte-budget pressure is *inserter-pays*: whichever
//! tenant's insert pushed a budget (its own quota or the global
//! `max_bytes`) over the line is the only tenant whose entries are
//! evicted to bring it back. A hot tenant can therefore never evict a
//! cold tenant's working set (see `tests/tenancy.rs`).
//!
//! Per-tenant configuration rides the `tenant.<name>.*` config keys
//! (quota, similarity-threshold override); per-tenant serving counters
//! are snapshotted into the `tenants` block of `/v1/metrics`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::cache::Partition;
use crate::json::{obj, Value};

/// The namespace untagged requests share.
pub const DEFAULT_TENANT: &str = "default";

/// Map a request's `client_tag` to its tenant name: `None` and
/// whitespace-only tags land on [`DEFAULT_TENANT`].
pub fn normalize_tag(tag: Option<&str>) -> &str {
    match tag {
        Some(t) if !t.trim().is_empty() => t,
        _ => DEFAULT_TENANT,
    }
}

/// Per-tenant configuration overrides (the `[tenant.<name>]` config
/// table). `None` = inherit the global setting.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TenantOverrides {
    /// Byte quota for this tenant (`0` = unlimited, like the global
    /// default `tenant_quota_bytes`).
    pub quota_bytes: Option<u64>,
    /// Similarity-threshold override for this tenant's lookups (a
    /// per-request `threshold` still wins).
    pub similarity_threshold: Option<f32>,
}

/// One tenant's live state: its partition set, byte ledger, resolved
/// quota/threshold, and serving counters.
pub struct TenantState {
    name: String,
    /// This tenant's dimension-partitioned caches (same shape as the
    /// pre-tenancy global map, one per tenant).
    pub(crate) partitions: RwLock<HashMap<usize, Arc<Partition>>>,
    /// Bytes resident for this tenant (shared with its partitions'
    /// stores, which charge it on every insert/remove/expiry/evict).
    pub(crate) bytes: Arc<AtomicU64>,
    /// Resolved byte quota (0 = unlimited).
    pub(crate) quota_bytes: u64,
    /// Resolved similarity-threshold override.
    pub(crate) threshold: Option<f32>,
    pub(crate) hits: AtomicU64,
    pub(crate) misses: AtomicU64,
    pub(crate) inserts: AtomicU64,
    pub(crate) evictions: AtomicU64,
    pub(crate) quota_rejections: AtomicU64,
}

impl TenantState {
    pub(crate) fn new(name: &str, quota_bytes: u64, threshold: Option<f32>) -> Self {
        Self {
            name: name.to_string(),
            partitions: RwLock::new(HashMap::new()),
            bytes: Arc::new(AtomicU64::new(0)),
            quota_bytes,
            threshold,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            quota_rejections: AtomicU64::new(0),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Bytes currently charged to this tenant.
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// This tenant's byte quota (0 = unlimited).
    pub fn quota_bytes(&self) -> u64 {
        self.quota_bytes
    }

    /// The tenant's similarity-threshold override, if configured.
    pub fn threshold(&self) -> Option<f32> {
        self.threshold
    }

    /// The ledger partitions charge this tenant's bytes to.
    pub(crate) fn bytes_ledger(&self) -> Arc<AtomicU64> {
        self.bytes.clone()
    }

    /// Zero the byte ledger (admin flush drops every partition at once,
    /// bypassing the per-mutation charge path).
    pub(crate) fn reset_bytes(&self) {
        self.bytes.store(0, Ordering::Relaxed);
    }

    pub(crate) fn record_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_insert(&self) {
        self.inserts.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_evictions(&self, n: u64) {
        if n > 0 {
            self.evictions.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub(crate) fn record_quota_rejection(&self) {
        self.quota_rejections.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time counters for the `/v1/metrics` tenants block.
    pub fn stats(&self) -> TenantStats {
        let entries =
            self.partitions.read().unwrap().values().map(|p| p.len()).sum();
        TenantStats {
            name: self.name.clone(),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            quota_rejections: self.quota_rejections.load(Ordering::Relaxed),
            bytes: self.bytes(),
            quota_bytes: self.quota_bytes,
            entries,
        }
    }
}

/// Point-in-time snapshot of one tenant's serving counters.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantStats {
    pub name: String,
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub quota_rejections: u64,
    pub bytes: u64,
    pub quota_bytes: u64,
    pub entries: usize,
}

impl TenantStats {
    pub fn to_json(&self) -> Value {
        obj([
            ("hits", self.hits.into()),
            ("misses", self.misses.into()),
            ("inserts", self.inserts.into()),
            ("evictions", self.evictions.into()),
            ("quota_rejections", self.quota_rejections.into()),
            ("bytes", self.bytes.into()),
            ("quota_bytes", self.quota_bytes.into()),
            ("entries", self.entries.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_normalization_defaults_blank_and_missing() {
        assert_eq!(normalize_tag(None), DEFAULT_TENANT);
        assert_eq!(normalize_tag(Some("")), DEFAULT_TENANT);
        assert_eq!(normalize_tag(Some("   ")), DEFAULT_TENANT);
        assert_eq!(normalize_tag(Some("bot-7")), "bot-7");
    }

    #[test]
    fn stats_snapshot_reflects_counters() {
        let t = TenantState::new("alice", 4096, Some(0.9));
        t.hits.fetch_add(3, Ordering::Relaxed);
        t.quota_rejections.fetch_add(1, Ordering::Relaxed);
        t.bytes.fetch_add(512, Ordering::Relaxed);
        let s = t.stats();
        assert_eq!(s.name, "alice");
        assert_eq!((s.hits, s.quota_rejections, s.bytes, s.quota_bytes), (3, 1, 512, 4096));
        assert_eq!(t.threshold(), Some(0.9));
        let j = s.to_json();
        assert_eq!(j.get("hits").as_u64(), Some(3));
        assert_eq!(j.get("bytes").as_u64(), Some(512));
    }
}
