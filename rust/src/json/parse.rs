//! Recursive-descent JSON parser.

use std::collections::BTreeMap;
use std::fmt;

use super::Value;

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(src: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Handle surrogate pairs for non-BMP chars.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    out.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| self.err("bad surrogate pair"))?,
                                    );
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                out.push(
                                    char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (may be multi-byte).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("bad \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_trailing() {
        assert!(parse("1 2").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"abc", "{\"a\" 1}", "tru", "01x", "[,]", "--1"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(parse("2E-2").unwrap().as_f64(), Some(0.02));
    }

    #[test]
    fn deep_nesting() {
        let src = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        let mut v = parse(&src).unwrap();
        for _ in 0..64 {
            v = v.at(0).clone();
        }
        assert_eq!(v.as_f64(), Some(1.0));
    }
}
