//! Minimal JSON parser/serializer.
//!
//! The offline build has no `serde`, so the crate carries its own small
//! JSON implementation. It is used for the artifact manifest, dataset
//! files, experiment reports, and the wire format of the HTTP-ish demo
//! server. Supports the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null); numbers are kept as `f64` which is
//! sufficient for every use in this crate.

mod parse;
mod write;

pub use parse::{parse, ParseError};
pub use write::to_string_pretty;

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization
/// is deterministic — important for artifact manifests diffed in CI.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|f| if f.fract() == 0.0 { Some(f as i64) } else { None })
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns `Value::Null` when absent.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Object(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Index into an array; returns `Value::Null` when out of bounds.
    pub fn at(&self, idx: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Array(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&write::to_string(self))
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}

/// Build a `Value::Object` tersely: `obj([("k", v.into()), ...])`.
pub fn obj<const N: usize>(pairs: [(&str, Value); N]) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar() {
        for src in ["null", "true", "false", "3.5", "-2", "\"hi\\n\""] {
            let v = parse(src).unwrap();
            let back = parse(&write::to_string(&v)).unwrap();
            assert_eq!(v, back, "roundtrip {src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x", "c": null}], "d": true}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").at(2).get("b").as_str(), Some("x"));
        assert_eq!(v.get("d").as_bool(), Some(true));
        let back = parse(&write::to_string(&v)).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn accessors_on_wrong_types_are_none() {
        let v = parse("[1]").unwrap();
        assert!(v.get("missing").is_null());
        assert!(v.at(5).is_null());
        assert_eq!(v.at(0).as_usize(), Some(1));
        assert_eq!(v.at(0).as_str(), None);
    }

    #[test]
    fn negative_usize_rejected() {
        let v = parse("-3").unwrap();
        assert_eq!(v.as_usize(), None);
        assert_eq!(v.as_i64(), Some(-3));
    }
}
