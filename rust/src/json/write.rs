//! JSON serialization (compact and pretty).

use super::Value;

/// Compact serialization (no extra whitespace).
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

/// Pretty serialization with 2-space indentation — used for manifests and
/// experiment reports meant to be read by humans.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(2), 0);
    out.push('\n');
    out
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_str(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_str(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * depth) {
            out.push(' ');
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; null is the least-bad encoding.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::{obj, parse, Value};
    use super::*;

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(to_string(&Value::Num(3.0)), "3");
        assert_eq!(to_string(&Value::Num(3.5)), "3.5");
        assert_eq!(to_string(&Value::Num(-0.0)), "0");
    }

    #[test]
    fn control_chars_escaped() {
        let s = to_string(&Value::Str("a\u{0001}b\"c".into()));
        assert_eq!(s, "\"a\\u0001b\\\"c\"");
        assert_eq!(parse(&s).unwrap().as_str(), Some("a\u{0001}b\"c"));
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(to_string(&Value::Num(f64::NAN)), "null");
        assert_eq!(to_string(&Value::Num(f64::INFINITY)), "null");
    }

    #[test]
    fn pretty_parses_back() {
        let v = obj([("a", vec![1u64, 2, 3].into()), ("b", "x".into())]);
        let pretty = to_string_pretty(&v);
        assert!(pretty.contains("\n  \"a\""));
        assert_eq!(parse(&pretty).unwrap(), v);
    }
}
