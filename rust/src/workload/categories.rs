//! The four evaluation categories and their template families.
//!
//! Slot markers `{0}` / `{1}` / `{2}` are substituted from the family's
//! slot vocabularies. `templates[0]` is the canonical surface stored in
//! the cache; the remaining templates are the paraphrase pool for test
//! queries.
//!
//! ## Geometry rules (what makes the evaluation reproduce the paper)
//!
//! The encoder's similarity is (approximately) monotone in lexical
//! overlap, so the dataset controls where queries land relative to the
//! 0.8 threshold:
//!
//! * **paraphrases must out-score siblings** — paraphrase templates
//!   differ from the canonical by only 1–2 filler words (cosine ≈
//!   0.85–0.95), while *slot values are multi-word distinctive phrases*
//!   so that same-family clusters differing in one slot are 2–4 content
//!   words apart (cosine ≈ 0.6–0.8);
//! * a controlled minority of families keeps single-word slots
//!   (python error names, shipping countries) — their siblings land just
//!   above the threshold and produce the paper's 3–7% *negative* hits,
//!   spread unevenly to match the per-category positive-rate band
//!   (python lowest at ~92%, network highest at ~97%);
//! * per-category `novelty` (fraction of test queries whose cluster is
//!   not cached) calibrates the hit-rate band (shopping lowest at ~62%,
//!   shipping highest at ~69%).

/// Evaluation category (paper Table 1 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    PythonBasics,
    NetworkSupport,
    OrderShipping,
    ShoppingQa,
}

pub const ALL_CATEGORIES: [Category; 4] = [
    Category::PythonBasics,
    Category::NetworkSupport,
    Category::OrderShipping,
    Category::ShoppingQa,
];

impl Category {
    /// Paper row label.
    pub fn label(&self) -> &'static str {
        match self {
            Category::PythonBasics => "Basics of Python Programming",
            Category::NetworkSupport => "Technical Support Related to Network",
            Category::OrderShipping => "Questions Related to Order and Shipping",
            Category::ShoppingQa => "Customer Shopping QA",
        }
    }

    /// Short machine key (JSON exports, CLI).
    pub fn key(&self) -> &'static str {
        match self {
            Category::PythonBasics => "python",
            Category::NetworkSupport => "network",
            Category::OrderShipping => "shipping",
            Category::ShoppingQa => "shopping",
        }
    }

    pub fn from_key(k: &str) -> Option<Self> {
        ALL_CATEGORIES.into_iter().find(|c| c.key() == k)
    }
}

/// A template family: canonical + paraphrase surfaces over slot vocabularies.
pub struct Family {
    pub templates: &'static [&'static str],
    pub slots: &'static [&'static [&'static str]],
    /// Novel-only families are never cached; their clusters model the
    /// genuinely-new questions of the paper's test set (topic-disjoint
    /// from the cached families, so they miss cleanly).
    pub novel_only: bool,
    /// Which slots determine the *answer*. Clusters agreeing on these
    /// slots share an answer group: the judge (like the paper's LLM
    /// judge, which asks "is the cached response accurate for this
    /// query?") counts a hit positive iff answer groups match. `None`
    /// means every slot is answer-determining.
    pub answer_slots: Option<&'static [usize]>,
}

/// Per-category generation spec.
pub struct CategorySpec {
    pub category: Category,
    pub families: &'static [Family],
    /// Fraction of test queries drawn from clusters NOT in the cache.
    pub novelty: f64,
    /// Fraction of the novel queries that are *siblings* of cached
    /// clusters (held-out slot combos of cached families). These land
    /// near the threshold and produce the paper's negative hits; the
    /// remainder come from `novel_only` families and miss cleanly.
    pub sibling_novel_frac: f64,
}

macro_rules! fam {
    ([$($t:expr),+ $(,)?], [$($s:expr),* $(,)?]) => {
        Family { templates: &[$($t),+], slots: &[$(&$s),*], novel_only: false,
                 answer_slots: None }
    };
    ([$($t:expr),+ $(,)?], [$($s:expr),* $(,)?], answer = $a:expr) => {
        Family { templates: &[$($t),+], slots: &[$(&$s),*], novel_only: false,
                 answer_slots: Some(&$a) }
    };
}

/// A family whose clusters only ever appear as novel test queries.
macro_rules! novel_fam {
    ([$($t:expr),+ $(,)?], [$($s:expr),* $(,)?]) => {
        Family { templates: &[$($t),+], slots: &[$(&$s),*], novel_only: true,
                 answer_slots: None }
    };
    ([$($t:expr),+ $(,)?], [$($s:expr),* $(,)?], answer = $a:expr) => {
        Family { templates: &[$($t),+], slots: &[$(&$s),*], novel_only: true,
                 answer_slots: Some(&$a) }
    };
}

// ---------------------------------------------------------------- python
//
// Slot-space rule: no two families share a multi-slot vocabulary
// subspace, otherwise cross-family near-duplicates ("convert X into Y"
// vs "difference between X and Y") dominate the negative-hit budget.
// Action vocabularies are therefore split disjointly across families.

/// Action phrases for the 3-slot family only.
const PY_ACTIONS_A: [&str; 12] = [
    "reverse the order of",
    "sort the elements of",
    "remove duplicates from",
    "flatten the nesting of",
    "randomly shuffle the items of",
    "take a slice from",
    "make a deep copy of",
    "iterate backwards over",
    "count the occurrences in",
    "find the largest value in",
    "compute the total sum of",
    "check the emptiness of",
];
/// Action phrases for the "how do i" 2-slot family only.
const PY_ACTIONS_B: [&str; 8] = [
    "serialize to json",
    "binary search through",
    "merge two instances of",
    "split apart the contents of",
    "pretty print the contents of",
    "measure the memory size of",
    "clear out the contents of",
    "swap two entries of",
];
/// Action phrases for the "write a function" family only.
const PY_ACTIONS_C: [&str; 8] = [
    "validate the schema of",
    "compress the contents of",
    "hash the contents of",
    "rotate the elements of",
    "interleave two copies of",
    "chunk up the contents of",
    "sample three items from",
    "zip together two of",
];
/// Multi-word container phrases (shared across action families is fine:
/// one shared slot + disjoint actions keeps siblings 3+ tokens apart).
const PY_TYPES: [&str; 16] = [
    "a linked list",
    "a character string",
    "a lookup dictionary",
    "an immutable tuple",
    "a hash set",
    "a pandas dataframe",
    "a numpy array",
    "a lazy generator",
    "a deeply nested list",
    "a raw byte buffer",
    "a priority queue",
    "a named tuple",
    "a frozen set",
    "a sorted list",
    "a default dictionary",
    "an ordered dictionary",
];
/// Source/target formats for the convert family (disjoint from PY_TYPES).
const PY_FORMATS: [&str; 10] = [
    "a json string",
    "a csv row",
    "an iso date",
    "a hex string",
    "a unicode string",
    "an integer id",
    "a float value",
    "a boolean flag",
    "a base64 blob",
    "a utc timestamp",
];
/// Multi-word context phrases for the 3-slot family.
const PY_CONTEXTS: [&str; 10] = [
    "a command line script",
    "a recursive helper function",
    "a tight inner loop",
    "a flask web handler",
    "a pytest test suite",
    "a jupyter notebook",
    "an async coroutine",
    "a class constructor",
    "a background worker thread",
    "a database migration script",
];
/// Single-word error names — the *intentional* ambiguity source that
/// drags python's positive rate to the bottom of the paper's band.
const PY_ERRORS: [&str; 12] = [
    "indexerror", "keyerror", "typeerror", "valueerror", "importerror",
    "attributeerror", "zerodivisionerror", "indentationerror",
    "recursionerror", "unicodedecodeerror", "modulenotfounderror",
    "filenotfounderror",
];
const PY_LIBS: [&str; 12] = [
    "the requests http library",
    "the numpy math library",
    "the pandas data library",
    "the matplotlib plotting library",
    "the pytest testing framework",
    "the flask web framework",
    "the sqlalchemy orm toolkit",
    "the pillow imaging library",
    "the beautifulsoup parsing library",
    "the click cli toolkit",
    "the rich terminal library",
    "the pydantic validation library",
];
const PY_FILES: [&str; 10] = [
    "a comma separated csv file",
    "a structured json file",
    "a plain text file",
    "a packed binary file",
    "a yaml configuration file",
    "an excel spreadsheet",
    "a compressed zip archive",
    "a rotating log file",
    "a parquet data file",
    "an ini settings file",
];

static PYTHON_FAMILIES: [Family; 12] = [
    // Large 3-slot family (phrase slots keep siblings >= 3 tokens apart).
    fam!(
        [
            "how do i {0} {1} inside {2} in python",
            "how can i {0} {1} inside {2} in python",
            "how would i {0} {1} inside {2} in python",
            "how do you {0} {1} inside {2} in python",
        ],
        [PY_ACTIONS_A, PY_TYPES, PY_CONTEXTS],
        answer = [0usize, 1]
    ),
    fam!(
        [
            "how do i {0} {1} in python",
            "how can i {0} {1} in python",
            "how do you {0} {1} in python",
            "what is the way to {0} {1} in python",
            "show me how to {0} {1} in python",
        ],
        [PY_ACTIONS_B, PY_TYPES]
    ),
    fam!(
        [
            "write a python function to {0} {1}",
            "write me a python function to {0} {1}",
            "implement a python function to {0} {1}",
            "give a python function that will {0} {1}",
        ],
        [PY_ACTIONS_C, PY_TYPES]
    ),
    fam!(
        [
            "can you explain {0} in python",
            "could you explain {0} in python",
            "please explain {0} in python simply",
            "help me understand {0} in python",
        ],
        [["function decorators", "generator expressions", "list comprehensions", "lambda functions", "context managers", "static type hints", "formatted f strings", "virtual environments", "the asyncio event loop", "frozen dataclasses", "multiple inheritance", "variable closures", "abstract base classes", "the walrus operator", "structural pattern matching", "the global interpreter lock"]]
    ),
    fam!(
        [
            "why am i getting a {0} in my python script",
            "why am i seeing a {0} in my python script",
            "why do i keep getting a {0} in my python script",
            "what causes a {0} in my python script",
        ],
        [PY_ERRORS]
    ),
    fam!(
        [
            "how do i install {0} for python",
            "how can i install {0} for python",
            "what is the command to install {0} for python",
            "help me install {0} for my python setup",
        ],
        [PY_LIBS]
    ),
    fam!(
        [
            "how do i read {0} in python",
            "how can i read {0} in python",
            "what is the way to read {0} in python",
            "show me how i can read {0} in python",
        ],
        [PY_FILES]
    ),
    fam!(
        [
            "how do i write data to {0} in python",
            "how can i write data to {0} in python",
            "what is the way to write data to {0} in python",
            "show me how i can write data to {0} in python",
        ],
        [PY_FILES]
    ),
    fam!(
        [
            "what is the difference between {0} and {1} in python",
            "what are the differences between {0} and {1} in python",
            "can you compare {0} and {1} in python",
            "when should i pick {0} over {1} in python",
        ],
        [PY_TYPES, PY_TYPES]
    ),
    fam!(
        [
            "how do i convert {0} into {1} in python",
            "how can i convert {0} into {1} in python",
            "what is the cleanest way to convert {0} into {1} in python",
            "show me how to turn {0} into {1} in python",
        ],
        [PY_FORMATS, PY_FORMATS]
    ),

    // ---- novel-only families (topic-disjoint from the cached set) ----
    novel_fam!(
        [
            "advice on handling {0} in {1} python codebases",
            "any advice on handling {0} in {1} python codebases",
            "need advice on handling {0} in {1} python codebases",
        ],
        [
            ["intermittent configuration drift", "randomly flaky tests", "painfully slow imports", "gradual memory leaks", "subtle race conditions", "tangled circular imports", "confusing type mismatches", "broken unicode handling", "conflicting dependency versions", "unpredictable api timeouts", "noisy deprecation warnings", "leaking file descriptors", "stale cache invalidation", "brittle date parsing"],
            ["sprawling legacy", "async heavy", "data science", "tiny hobby", "enterprise web", "cli oriented", "machine learning", "monorepo style"]
        ],
        answer = [0usize]
    ),
    novel_fam!(
        [
            "deploying my python {0} onto {1}",
            "help deploying my python {0} onto {1}",
            "guidance deploying my python {0} onto {1}",
        ],
        [
            ["streaming web service", "background task worker", "nightly cron job", "public rest api", "batch data pipeline", "support chat bot", "news web scraper", "metrics dashboard app", "image resize service", "email digest sender", "log ingestion daemon", "feature flag service"],
            ["a docker swarm container", "a managed kubernetes cluster", "an aws lambda function", "a bare metal vps", "a heroku dyno plan", "a raspberry pi at home", "an on premises server rack", "the google cloud run platform", "an azure functions app", "a shared ci runner pool"]
        ],
        answer = [1usize]
    ),
];

// --------------------------------------------------------------- network

const NET_DEVICES: [&str; 14] = [
    "wireless router",
    "cable modem",
    "ethernet switch",
    "wifi access point",
    "hardware firewall",
    "work laptop",
    "desktop computer",
    "network printer",
    "smart tv",
    "vpn gateway",
    "mesh wifi node",
    "security camera",
    "game console",
    "voip phone",
];
/// Issue phrases for the 3-slot family only.
const NET_ISSUES_A: [&str; 10] = [
    "keeps disconnecting every few minutes",
    "is painfully slow during the evening",
    "refuses to connect at all",
    "drops packets under heavy load",
    "shows limited connectivity warnings",
    "has very high ping in games",
    "randomly restarts itself",
    "cannot obtain an ip address",
    "fails every speed test badly",
    "times out on every request",
];
/// Issue phrases for the 2-slot family only (disjoint from A).
const NET_ISSUES_B: [&str; 8] = [
    "blocks a website i need",
    "loses its signal at night",
    "shows a blinking red light",
    "keeps asking for the password",
    "is stuck in a reboot loop",
    "will not accept new devices",
    "gets extremely hot to the touch",
    "makes a loud clicking noise",
];
const NET_PLACES: [&str; 16] = [
    "upstairs bedroom", "finished basement", "detached garage", "home office",
    "back patio", "kitchen corner", "second floor landing", "living room",
    "conference room", "warehouse floor", "front lobby", "server closet",
    "guest bedroom", "rooftop deck", "studio apartment", "retail backroom",
];
const NET_PROTOCOLS: [&str; 12] = [
    "tcp", "udp", "dns", "dhcp", "http", "https", "ftp", "ssh", "smtp",
    "ipv6", "icmp", "tls",
];
const NET_SETTINGS: [&str; 8] = [
    "port forwarding rules",
    "a static ip address",
    "custom dns servers",
    "a guest wifi network",
    "parental control filters",
    "the wifi channel width",
    "mac address filtering",
    "qos traffic priority",
];

static NETWORK_FAMILIES: [Family; 10] = [
    // Large 3-slot family.
    fam!(
        [
            "the {0} in the {1} {2} what should i check",
            "the {0} in the {1} {2} what can i check",
            "the {0} in the {1} {2} how should i troubleshoot",
            "the {0} in the {1} {2} please advise",
        ],
        [NET_DEVICES, NET_PLACES, NET_ISSUES_A],
        answer = [0usize, 2]
    ),
    fam!(
        [
            "my {0} {1} what should i do",
            "my {0} {1} what can i do",
            "my {0} {1} how do i fix it",
            "my {0} {1} what do i do",
        ],
        [NET_DEVICES, NET_ISSUES_B]
    ),
    fam!(
        [
            "what is the proper way to restart my {0}",
            "what is the right way to restart my {0}",
            "what is the safest way to restart my {0}",
            "what is the recommended way to restart my {0}",
        ],
        [NET_DEVICES]
    ),
    fam!(
        [
            "what is the {0} protocol used for in networking",
            "what is the {0} protocol actually used for in networking",
            "what is the purpose of the {0} protocol in networking",
            "can you explain what the {0} protocol is used for in networking",
        ],
        [NET_PROTOCOLS]
    ),
    fam!(
        [
            "how do i configure {0} on my {1}",
            "how can i configure {0} on my {1}",
            "where do i set up {0} on my {1}",
            "what is the way to configure {0} on my {1}",
        ],
        [NET_SETTINGS, NET_DEVICES]
    ),
    fam!(
        [
            "how do i update the firmware on my {0}",
            "how can i update the firmware on my {0}",
            "what are the steps to update the firmware on my {0}",
            "how should i update the firmware on my {0}",
        ],
        [NET_DEVICES]
    ),
    fam!(
        [
            "how can i improve the weak wifi signal in my {0}",
            "how do i improve the weak wifi signal in my {0}",
            "what can i do about the weak wifi signal in my {0}",
            "what helps with the weak wifi signal in my {0}",
        ],
        [NET_PLACES]
    ),
    fam!(
        [
            "how do i find the {0} of my computer",
            "how can i find the {0} of my computer",
            "where can i see the {0} of my computer",
            "how do i look up the {0} of my computer",
        ],
        [["local ip address", "hardware mac address", "default gateway address", "subnet mask value", "active dns server", "network hostname", "open listening ports", "adapter driver version"]]
    ),

    // ---- novel-only families ----
    novel_fam!(
        [
            "safety of {0} over {1} wifi",
            "the safety of {0} over {1} wifi",
            "how safe is {0} over {1} wifi",
        ],
        [
            ["checking my bank account balance", "entering my card details", "joining an encrypted video call", "downloading large torrent files", "reading my work email", "streaming paid video content", "rotating my master password", "syncing my cloud backups", "using a remote desktop", "sending signed tax documents", "uploading medical records", "approving wire transfers", "editing shared spreadsheets", "renewing digital certificates"],
            ["busy international airport", "shared hotel lobby", "crowded coffee shop", "public library branch", "open university campus", "cramped airplane cabin", "packed conference center", "hospital waiting room"]
        ],
        answer = [0usize]
    ),
    novel_fam!(
        [
            "internet plan sizing for {0} with {1}",
            "help with internet plan sizing for {0} with {1}",
            "need internet plan sizing for {0} with {1}",
        ],
        [
            ["daily casual web browsing", "constant remote work calls", "competitive online gaming", "nightly streaming in 4k", "frequent large video uploads", "dozens of smart home devices", "always on security cameras", "full time home schooling", "cloud based music production", "self hosting a game server", "daily large photo backups", "live streaming my hobby channel", "frequent virtual classrooms", "constant cctv cloud uploads"],
            ["two flatmates sharing", "a family of three", "a family of five", "four remote workers", "six heavy streamers", "seven connected teenagers", "eight device hoarders", "a dozen office guests"]
        ]
    ),
];

// -------------------------------------------------------------- shipping

const SHIP_ITEMS: [&str; 18] = [
    "online order",
    "delivery package",
    "small parcel",
    "replacement item",
    "birthday gift order",
    "game preorder",
    "backordered item",
    "bulk supply order",
    "express shipment",
    "international order",
    "monthly subscription box",
    "return shipment",
    "furniture delivery",
    "grocery delivery",
    "electronics order",
    "clothing order",
    "book order",
    "appliance delivery",
];
/// Event phrases for the 3-slot family only.
const SHIP_EVENTS_A: [&str; 9] = [
    "has not arrived yet",
    "is several days late",
    "was marked delivered but is missing",
    "arrived visibly damaged",
    "is stuck in transit",
    "went to the wrong address",
    "is missing several items",
    "shows no tracking updates",
    "was returned to the sender",
];
/// Event phrases for the 2-slot family only (disjoint from A).
const SHIP_EVENTS_B: [&str; 8] = [
    "arrived already opened",
    "was charged twice on my card",
    "needs a signature i cannot provide",
    "was left in the rain outside",
    "has the wrong items inside",
    "arrived with a torn label",
    "was delivered to my old address",
    "came without the invoice",
];
/// Multi-word carrier phrases for the large 3-slot family.
const SHIP_CARRIERS: [&str; 14] = [
    "the standard ground carrier",
    "the express air courier",
    "the overnight priority service",
    "the economy postal service",
    "the regional freight line",
    "the same day bike courier",
    "the two day premium service",
    "the international air mail",
    "the tracked signature service",
    "the oversized freight carrier",
    "the refrigerated transport service",
    "the weekend delivery service",
    "the locker pickup network",
    "the neighborhood drop service",
];
/// Single-word countries — the controlled ambiguity source for shipping
/// (different destination => different answer, but high lexical overlap).
const SHIP_COUNTRIES: [&str; 12] = [
    "canada", "mexico", "germany", "japan", "australia", "brazil", "india",
    "france", "spain", "italy", "korea", "singapore",
];
const SHIP_FIELDS: [&str; 8] = [
    "shipping address",
    "delivery date window",
    "billing address",
    "contact phone number",
    "gift message text",
    "delivery instructions note",
    "recipient name spelling",
    "shipping speed tier",
];

static SHIPPING_FAMILIES: [Family; 11] = [
    // Large 3-slot family.
    fam!(
        [
            "my {0} shipped with {1} {2} what should i do",
            "my {0} shipped with {1} {2} what can i do",
            "my {0} shipped with {1} {2} who do i contact",
            "my {0} shipped with {1} {2} please advise",
        ],
        [SHIP_ITEMS, SHIP_CARRIERS, SHIP_EVENTS_A],
        answer = [0usize, 2]
    ),
    fam!(
        [
            "my {0} {1} what should i do",
            "my {0} {1} what can i do",
            "my {0} {1} what should i try",
            "my {0} {1} what are my options",
        ],
        [SHIP_ITEMS, SHIP_EVENTS_B]
    ),
    fam!(
        [
            "how do i track my {0}",
            "how can i track my {0}",
            "where do i track my {0}",
            "where can i go to track my {0}",
        ],
        [SHIP_ITEMS]
    ),
    fam!(
        [
            "how long does standard shipping to {0} take",
            "how long will standard shipping to {0} take",
            "how many days does standard shipping to {0} take",
            "what is the usual time standard shipping to {0} takes",
        ],
        [SHIP_COUNTRIES]
    ),
    fam!(
        [
            "how much does standard shipping to {0} cost",
            "how much will standard shipping to {0} cost",
            "what does standard shipping to {0} cost",
            "how much are the fees standard shipping to {0} costs",
        ],
        [SHIP_COUNTRIES]
    ),
    fam!(
        [
            "how do i change the {0} on my existing order",
            "how can i change the {0} on my existing order",
            "is it possible to change the {0} on my existing order",
            "i want to change the {0} on my existing order how",
        ],
        [SHIP_FIELDS]
    ),
    fam!(
        [
            "how do i cancel my {0} before it ships",
            "how can i cancel my {0} before it ships",
            "am i able to cancel my {0} before it ships",
            "what is the way to cancel my {0} before it ships",
        ],
        [SHIP_ITEMS]
    ),
    fam!(
        [
            "how do i return my {0} for a refund",
            "how can i return my {0} for a refund",
            "what is the process to return my {0} for a refund",
            "what is the way to return my {0} for a refund",
        ],
        [SHIP_ITEMS]
    ),
    fam!(
        [
            "when will the refund for my {0} be processed",
            "when will the refund for my {0} arrive",
            "how soon will the refund for my {0} be processed",
            "when will the refund for my {0} show up",
        ],
        [SHIP_ITEMS]
    ),

    // ---- novel-only families ----
    novel_fam!(
        [
            "delivery of {0} to {1}",
            "about delivery of {0} to {1}",
            "asking about delivery of {0} to {1}",
        ],
        [
            ["oversized palletized freight", "fragile antique glassware", "temperature controlled frozen goods", "live potted plants", "loose lithium batteries", "heavy industrial machinery", "original framed artwork", "regulated medical supplies", "licensed alcohol purchases", "pressurized aerosol products", "bulk construction materials", "perishable bakery goods", "high value jewelry", "certified legal documents"],
            ["a locked po box", "an overseas military base", "a remote rural farm", "a small island address", "a hotel front desk", "an active construction site", "a university dorm room", "a hospital reception ward"]
        ],
        answer = [0usize]
    ),
    novel_fam!(
        [
            "delivery handling during {0} in {1}",
            "about delivery handling during {0} in {1}",
            "question on delivery handling during {0} in {1}",
        ],
        [
            ["a national public holiday", "a prolonged postal strike", "severe winter weather", "a customs clearance backlog", "the peak gifting season", "a regional courier lockdown", "a major carrier outage", "an unresolved address dispute", "a warehouse relocation move", "a full inventory audit", "a border customs dispute", "a fuel surcharge change", "a port worker shortage", "a routing system migration"],
            ["late december", "early january", "the spring rush", "the summer heat", "the autumn season", "mid february", "late november", "the july sales"]
        ],
        answer = [0usize]
    ),
];

// -------------------------------------------------------------- shopping

const SHOP_PRODUCTS: [&str; 16] = [
    "android smartphone",
    "gaming laptop",
    "wireless headphones",
    "fitness smartwatch",
    "drawing tablet",
    "mirrorless camera",
    "kitchen blender",
    "robot vacuum",
    "espresso machine",
    "digital air fryer",
    "curved monitor",
    "mechanical keyboard",
    "handheld game console",
    "smart doorbell",
    "electric kettle",
    "portable projector",
];
/// Feature phrases for the 3-slot (brand) family only.
const SHOP_FEATURES_A: [&str; 10] = [
    "a dual lens camera",
    "full water resistance",
    "wireless charging support",
    "active noise cancellation",
    "an extended warranty option",
    "bluetooth five support",
    "an hdmi output port",
    "expandable sd storage",
    "fast usb c charging",
    "a user replaceable battery",
];
/// Feature phrases for the 2-slot family only (disjoint from A).
const SHOP_FEATURES_B: [&str; 8] = [
    "voice assistant control",
    "an energy saving mode",
    "a backlit display panel",
    "a detachable power cord",
    "an automatic shutoff timer",
    "a companion mobile app",
    "a travel carrying case",
    "a two year service plan",
];
const SHOP_BRANDS: [&str; 12] = [
    "acme prime", "nordwind air", "zenbrook go", "calypso neo",
    "vertexa pro", "lumina max", "pinewood duo", "orbitek plus",
    "kestrel ultra", "bluefin core", "halcyon one", "redoak edge",
];
const SHOP_TOPICS: [&str; 10] = [
    "student discount program",
    "price match guarantee",
    "gift wrapping service",
    "loyalty points program",
    "extended warranty plan",
    "seasonal promo code",
    "device trade in program",
    "monthly financing options",
    "bulk order discount",
    "newsletter signup coupon",
];

static SHOPPING_FAMILIES: [Family; 10] = [
    // Large 3-slot family (brand + product + feature).
    fam!(
        [
            "does the {0} {1} come with {2}",
            "does the {0} {1} ship with {2}",
            "does the new {0} {1} come with {2}",
            "does the {0} {1} also come with {2}",
        ],
        [SHOP_BRANDS, SHOP_PRODUCTS, SHOP_FEATURES_A]
    ),
    fam!(
        [
            "does this {0} have {1}",
            "does the {0} have {1}",
            "does this particular {0} have {1}",
            "does this specific {0} have {1}",
        ],
        [SHOP_PRODUCTS, SHOP_FEATURES_B]
    ),
    fam!(
        [
            "what are the main features of this {0}",
            "what are the key features of this {0}",
            "what are the main features of the {0}",
            "what are all the main features of this {0}",
        ],
        [SHOP_PRODUCTS]
    ),
    fam!(
        [
            "is the {0} currently in stock",
            "is the {0} in stock right now",
            "is the {0} currently in stock online",
            "is this {0} currently in stock",
        ],
        [SHOP_PRODUCTS]
    ),
    fam!(
        [
            "do you offer a {0} and how does it work",
            "do you have a {0} and how does it work",
            "do you offer a {0} and how would it work",
            "do you offer any {0} and how does it work",
        ],
        [SHOP_TOPICS]
    ),
    fam!(
        [
            "which {0} do you recommend for {1}",
            "what {0} do you recommend for {1}",
            "which {0} would you recommend for {1}",
            "which {0} do you most recommend for {1}",
        ],
        [SHOP_PRODUCTS, ["frequent travel", "college students", "competitive gaming", "a small kitchen", "absolute beginners", "professional work", "young kids", "a holiday gift", "everyday use", "a home office"]]
    ),
    fam!(
        [
            "what is the difference between the {0} and the {1}",
            "what are the differences between the {0} and the {1}",
            "what is the real difference between the {0} and the {1}",
            "what is different between the {0} and the {1}",
        ],
        [SHOP_PRODUCTS, SHOP_PRODUCTS]
    ),
    fam!(
        [
            "how do i redeem a {0} at checkout",
            "how can i redeem a {0} at checkout",
            "where do i redeem a {0} at checkout",
            "how do i use a {0} at checkout",
        ],
        [SHOP_TOPICS]
    ),

    // ---- novel-only families ----
    novel_fam!(
        [
            "paying with {0} plus {1}",
            "about paying with {0} plus {1}",
            "question on paying with {0} plus {1}",
        ],
        [
            ["a reloadable prepaid visa card", "my accumulated store credit balance", "a personal cryptocurrency wallet", "my linked paypal account", "apple pay on my phone", "a corporate purchase order", "an international debit card", "a direct bank transfer", "cash paid on delivery", "a mobile digital wallet app", "a single use virtual card", "a certified money order", "my campus meal card", "a health spending account", "a travel rewards credit card", "a monthly installment plan"],
            ["a physical gift card", "accumulated loyalty points", "a seasonal promo code", "an employee discount code", "a mail in rebate voucher", "printed store coupons", "a referral bonus credit", "a price adjustment credit"]
        ],
        answer = [0usize]
    ),
    novel_fam!(
        [
            "your policy on {0} for items bought {1}",
            "the policy on {0} for items bought {1}",
            "store policy on {0} for items bought {1}",
        ],
        [
            ["sudden price drops after purchase", "open box return requests", "missing accessory replacement claims", "cosmetic damage refund claims", "manufacturer warranty transfers", "digital software refund requests", "officially recalled products", "suspected counterfeit reports", "duplicate payment charges", "repeatedly late deliveries", "gift receipt only exchanges", "loyalty point balance disputes", "expired coupon code honoring", "bundled item partial returns", "damaged outer packaging refunds", "prepaid subscription cancellations"],
            ["online last month", "in a physical store", "during a flash sale", "with loyalty points", "as holiday gifts", "during final clearance", "from marketplace sellers", "with monthly financing"]
        ],
        answer = [0usize]
    ),
];

/// Spec for one category. Novelty fractions are the calibrated knobs that
/// land the measured hit rates in the paper's per-category band.
pub fn category_spec(c: Category) -> CategorySpec {
    match c {
        Category::PythonBasics => CategorySpec {
            category: c,
            families: &PYTHON_FAMILIES,
            novelty: 0.38,
            sibling_novel_frac: 0.08,
        },
        Category::NetworkSupport => CategorySpec {
            category: c,
            families: &NETWORK_FAMILIES,
            novelty: 0.36,
            sibling_novel_frac: 0.05,
        },
        Category::OrderShipping => CategorySpec {
            category: c,
            families: &SHIPPING_FAMILIES,
            novelty: 0.36,
            sibling_novel_frac: 0.07,
        },
        Category::ShoppingQa => CategorySpec {
            category: c,
            families: &SHOPPING_FAMILIES,
            novelty: 0.42,
            sibling_novel_frac: 0.045,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_match_paper() {
        assert_eq!(Category::PythonBasics.label(), "Basics of Python Programming");
        assert_eq!(Category::from_key("shipping"), Some(Category::OrderShipping));
        assert_eq!(Category::from_key("nope"), None);
    }

    #[test]
    fn every_family_has_enough_clusters_and_paraphrases() {
        for c in ALL_CATEGORIES {
            let spec = category_spec(c);
            let mut total = 0usize;
            for f in spec.families {
                assert!(f.templates.len() >= 3, "{c:?}: need paraphrase variants");
                let combos: usize = f.slots.iter().map(|s| s.len()).product::<usize>().max(1);
                total += combos;
                // Every template must reference every slot index.
                for (i, _) in f.slots.iter().enumerate() {
                    let marker = format!("{{{i}}}");
                    for t in f.templates {
                        assert!(t.contains(&marker as &str), "{c:?} template '{t}' missing {marker}");
                    }
                }
            }
            // 2000 base + novel pool must fit.
            assert!(total >= 2_300, "{c:?} only {total} possible clusters");
        }
    }

    #[test]
    fn paraphrase_templates_stay_close_to_canonical() {
        // Geometry rule: paraphrases must (mostly) out-score siblings, so
        // each one must share a healthy fraction of words with the
        // canonical template. A minority of "far" paraphrases is allowed
        // by design — they create the paraphrase-miss tail that keeps hit
        // rates below 100% — but the family *mean* must stay high.
        for c in ALL_CATEGORIES {
            for (fi, f) in category_spec(c).families.iter().enumerate() {
                let canon: std::collections::HashSet<&str> =
                    f.templates[0].split_whitespace().collect();
                let mut fracs = Vec::new();
                for t in &f.templates[1..] {
                    let words: Vec<&str> = t.split_whitespace().collect();
                    let shared = words.iter().filter(|w| canon.contains(*w)).count();
                    let frac = shared as f64 / words.len() as f64;
                    assert!(
                        frac >= 0.40,
                        "{c:?} family {fi}: paraphrase '{t}' only shares {frac:.2} with canonical"
                    );
                    fracs.push(frac);
                }
                let mean = fracs.iter().sum::<f64>() / fracs.len() as f64;
                assert!(
                    mean >= 0.60,
                    "{c:?} family {fi}: paraphrase pool too far from canonical (mean {mean:.2})"
                );
            }
        }
    }

    #[test]
    fn novelty_in_range() {
        for c in ALL_CATEGORIES {
            let n = category_spec(c).novelty;
            assert!((0.0..1.0).contains(&n));
        }
    }
}
