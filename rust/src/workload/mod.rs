//! Synthetic workload — the substitute for the paper's test dataset
//! (DESIGN.md §3). Reproduces the *statistical* structure of the
//! evaluation in §3.1–§3.2 of the paper:
//!
//! * 4 query categories (python basics, network support, order &
//!   shipping, shopping QA), 2,000 cached QA pairs each (8,000 total);
//! * 500 test queries per category (2,000 total), a per-category mix of
//!   **paraphrases** of cached questions (should hit) and **novel**
//!   questions (should miss);
//! * QA pairs come from template *families* with slot vocabularies; a
//!   `(family, slots)` binding is a **cluster** — the ground-truth
//!   identity used by the judge to label hits positive/negative. False
//!   positives arise *naturally* from same-family clusters that differ
//!   in one slot word (e.g. "reverse a list" vs "reverse a string"),
//!   exactly the near-duplicate ambiguity the paper attributes its
//!   <100% positive rates to.

mod categories;
mod generator;

pub use categories::{category_spec, Category, ALL_CATEGORIES};
pub use generator::{DatasetConfig, WorkloadGenerator};

use crate::json::{obj, Value};

/// One cached question-answer pair (a unique cluster).
#[derive(Debug, Clone)]
pub struct QaPair {
    /// Ground-truth cluster id (stable hash of family + slots).
    pub cluster: u64,
    /// Answer-equivalence group (hash of family + answer-determining
    /// slots); clusters in one group genuinely share their answer text.
    pub answer_group: u64,
    pub category: Category,
    pub question: String,
    pub answer: String,
}

/// One test query.
#[derive(Debug, Clone)]
pub struct TestQuery {
    pub text: String,
    /// Cluster this query *means* (for novel queries: its own new cluster).
    pub cluster: u64,
    /// Answer-equivalence group of the cluster (see [`QaPair`]).
    pub answer_group: u64,
    pub category: Category,
    /// True when the cluster is not in the cached base set.
    pub novel: bool,
}

/// The full evaluation workload.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub base: Vec<QaPair>,
    pub tests: Vec<TestQuery>,
}

impl Dataset {
    pub fn base_for(&self, c: Category) -> impl Iterator<Item = &QaPair> {
        self.base.iter().filter(move |p| p.category == c)
    }

    pub fn tests_for(&self, c: Category) -> impl Iterator<Item = &TestQuery> {
        self.tests.iter().filter(move |q| q.category == c)
    }

    pub fn to_json(&self) -> Value {
        let base: Vec<Value> = self
            .base
            .iter()
            .map(|p| {
                obj([
                    ("cluster", p.cluster.into()),
                    ("category", p.category.key().into()),
                    ("question", p.question.as_str().into()),
                    ("answer", p.answer.as_str().into()),
                ])
            })
            .collect();
        let tests: Vec<Value> = self
            .tests
            .iter()
            .map(|q| {
                obj([
                    ("cluster", q.cluster.into()),
                    ("category", q.category.key().into()),
                    ("text", q.text.as_str().into()),
                    ("novel", q.novel.into()),
                ])
            })
            .collect();
        obj([("base", Value::Array(base)), ("tests", Value::Array(tests))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_dataset() {
        let ds = WorkloadGenerator::new(42).generate(&DatasetConfig::paper());
        assert_eq!(ds.base.len(), 8_000);
        assert_eq!(ds.tests.len(), 2_000);
        for c in ALL_CATEGORIES {
            assert_eq!(ds.base_for(c).count(), 2_000, "{c:?} base");
            assert_eq!(ds.tests_for(c).count(), 500, "{c:?} tests");
        }
    }

    #[test]
    fn base_clusters_unique() {
        let ds = WorkloadGenerator::new(1).generate(&DatasetConfig::small());
        let mut ids: Vec<u64> = ds.base.iter().map(|p| p.cluster).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "cluster ids must be unique in the base set");
    }

    #[test]
    fn paraphrase_queries_reference_cached_clusters() {
        let ds = WorkloadGenerator::new(2).generate(&DatasetConfig::small());
        let cached: std::collections::HashSet<u64> =
            ds.base.iter().map(|p| p.cluster).collect();
        for q in &ds.tests {
            if q.novel {
                assert!(!cached.contains(&q.cluster), "novel query in cache: {}", q.text);
            } else {
                assert!(cached.contains(&q.cluster), "paraphrase not in cache: {}", q.text);
            }
        }
    }

    #[test]
    fn paraphrases_differ_from_cached_surface() {
        let ds = WorkloadGenerator::new(3).generate(&DatasetConfig::small());
        let by_cluster: std::collections::HashMap<u64, &str> =
            ds.base.iter().map(|p| (p.cluster, p.question.as_str())).collect();
        let mut same = 0;
        let mut total = 0;
        for q in ds.tests.iter().filter(|q| !q.novel) {
            total += 1;
            if by_cluster[&q.cluster] == q.text {
                same += 1;
            }
        }
        // Paraphrase engine may occasionally emit the cached surface; it
        // must be rare (< 20%) so the hit metric measures semantics, not
        // string equality.
        assert!(
            (same as f64) < (total as f64) * 0.2,
            "{same}/{total} paraphrases identical to cached question"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = WorkloadGenerator::new(7).generate(&DatasetConfig::small());
        let b = WorkloadGenerator::new(7).generate(&DatasetConfig::small());
        assert_eq!(a.base.len(), b.base.len());
        for (x, y) in a.base.iter().zip(&b.base) {
            assert_eq!(x.question, y.question);
            assert_eq!(x.cluster, y.cluster);
        }
        for (x, y) in a.tests.iter().zip(&b.tests) {
            assert_eq!(x.text, y.text);
        }
    }

    #[test]
    fn json_export_shape() {
        let ds = WorkloadGenerator::new(4).generate(&DatasetConfig::tiny());
        let j = ds.to_json();
        assert_eq!(j.get("base").as_array().unwrap().len(), ds.base.len());
        assert_eq!(j.get("tests").as_array().unwrap().len(), ds.tests.len());
        let q = j.get("base").at(0);
        assert!(q.get("question").as_str().is_some());
    }
}
