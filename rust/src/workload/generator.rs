//! Dataset construction: enumerate clusters, sample the cached base set,
//! render paraphrase and novel test queries, synthesize answers, and
//! (optionally) a Poisson arrival trace.

use crate::tokenizer::fnv1a64;
use crate::util::Rng;

use super::categories::{category_spec, Category, Family, ALL_CATEGORIES};
use super::{Dataset, QaPair, TestQuery};

/// Dataset sizing.
#[derive(Debug, Clone)]
pub struct DatasetConfig {
    /// Cached QA pairs per category.
    pub base_per_category: usize,
    /// Test queries per category.
    pub tests_per_category: usize,
}

impl DatasetConfig {
    /// The paper's evaluation scale (§3.1–3.2): 8,000 base / 2,000 tests.
    pub fn paper() -> Self {
        Self { base_per_category: 2_000, tests_per_category: 500 }
    }

    /// Fast configuration for integration tests.
    pub fn small() -> Self {
        Self { base_per_category: 300, tests_per_category: 80 }
    }

    /// Minimal configuration for unit tests.
    pub fn tiny() -> Self {
        Self { base_per_category: 40, tests_per_category: 10 }
    }
}

/// Deterministic dataset generator.
pub struct WorkloadGenerator {
    seed: u64,
}

/// A fully-specified cluster: family index + slot choices.
#[derive(Debug, Clone)]
struct Cluster {
    family: usize,
    slot_choice: Vec<usize>,
}

impl WorkloadGenerator {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    pub fn generate(&self, cfg: &DatasetConfig) -> Dataset {
        let mut base = Vec::new();
        let mut tests = Vec::new();
        for c in ALL_CATEGORIES {
            self.generate_category(c, cfg, &mut base, &mut tests);
        }
        Dataset { base, tests }
    }

    fn generate_category(
        &self,
        c: Category,
        cfg: &DatasetConfig,
        base: &mut Vec<QaPair>,
        tests: &mut Vec<TestQuery>,
    ) {
        let spec = category_spec(c);
        let mut rng = Rng::new(self.seed ^ fnv1a64(c.key().as_bytes()));

        // Enumerate every possible cluster of the cached-eligible and the
        // novel-only families separately, shuffling each deterministically.
        let mut clusters = Vec::new();
        let mut novel_clusters = Vec::new();
        for (fi, fam) in spec.families.iter().enumerate() {
            let out = if fam.novel_only { &mut novel_clusters } else { &mut clusters };
            let mut idx = vec![0usize; fam.slots.len()];
            loop {
                out.push(Cluster { family: fi, slot_choice: idx.clone() });
                // Odometer increment.
                let mut pos = idx.len();
                loop {
                    if pos == 0 {
                        break;
                    }
                    pos -= 1;
                    idx[pos] += 1;
                    if idx[pos] < fam.slots[pos].len() {
                        break;
                    }
                    idx[pos] = 0;
                    if pos == 0 {
                        break;
                    }
                }
                if idx.iter().all(|&i| i == 0) {
                    break;
                }
                if fam.slots.is_empty() {
                    break; // no slots: single cluster
                }
            }
        }
        rng.shuffle(&mut clusters);
        rng.shuffle(&mut novel_clusters);

        // Novel split: clean (novel-only families, miss cleanly) vs
        // sibling (held-out combos of cached families, land near the
        // threshold and produce the paper's negative hits).
        let need_novel = (cfg.tests_per_category as f64 * spec.novelty).round() as usize;
        let need_sibling =
            (need_novel as f64 * spec.sibling_novel_frac).round() as usize;
        let need_clean = need_novel - need_sibling;
        assert!(
            clusters.len() >= cfg.base_per_category + need_sibling,
            "{c:?}: {} cached-eligible clusters < base {} + sibling-novel {}",
            clusters.len(),
            cfg.base_per_category,
            need_sibling
        );
        assert!(
            novel_clusters.len() >= need_clean,
            "{c:?}: {} novel-only clusters < {}",
            novel_clusters.len(),
            need_clean
        );
        let (cached, rest) = clusters.split_at(cfg.base_per_category);
        let novel_pool: Vec<&Cluster> = rest[..need_sibling]
            .iter()
            .chain(novel_clusters[..need_clean].iter())
            .collect();

        // Base set: canonical surface (template 0) + synthesized answer.
        // Answers are keyed by *answer group*, so clusters that agree on
        // every answer-determining slot share their answer text.
        let mut group_answers: std::collections::HashMap<u64, String> =
            std::collections::HashMap::new();
        for cl in cached {
            let fam = &spec.families[cl.family];
            let question = render(fam, 0, &cl.slot_choice);
            let cluster = cluster_id(c, cl, fam);
            let answer_group = answer_group_id(c, cl, fam);
            let answer = group_answers
                .entry(answer_group)
                .or_insert_with(|| synth_answer(c, answer_group, &question, &mut rng))
                .clone();
            base.push(QaPair { cluster, answer_group, category: c, question, answer });
        }

        // Test queries: paraphrases of cached clusters + novel clusters.
        let n_para = cfg.tests_per_category - need_novel;
        for _ in 0..n_para {
            let cl = &cached[rng.below(cached.len())];
            let fam = &spec.families[cl.family];
            // Pick any non-canonical template (paraphrase pool).
            let t = 1 + rng.below(fam.templates.len() - 1);
            tests.push(TestQuery {
                text: render(fam, t, &cl.slot_choice),
                cluster: cluster_id(c, cl, fam),
                answer_group: answer_group_id(c, cl, fam),
                category: c,
                novel: false,
            });
        }
        for cl in novel_pool {
            let fam = &spec.families[cl.family];
            let t = rng.below(fam.templates.len());
            tests.push(TestQuery {
                text: render(fam, t, &cl.slot_choice),
                cluster: cluster_id(c, cl, fam),
                answer_group: answer_group_id(c, cl, fam),
                category: c,
                novel: true,
            });
        }
        // Interleave paraphrase/novel queries.
        let start = tests.len() - cfg.tests_per_category;
        rng.shuffle(&mut tests[start..]);
    }
}

/// Stable cluster id: category + family + chosen slot *words* (so ids
/// survive reordering of families' slot lists only if words change).
fn cluster_id(c: Category, cl: &Cluster, fam: &Family) -> u64 {
    let mut key = String::new();
    key.push_str(c.key());
    key.push('|');
    key.push_str(&cl.family.to_string());
    for (si, &wi) in cl.slot_choice.iter().enumerate() {
        key.push('|');
        key.push_str(fam.slots[si][wi]);
    }
    fnv1a64(key.as_bytes())
}

/// Answer-group id: like [`cluster_id`] but only over the family's
/// answer-determining slots (see `Family::answer_slots`).
fn answer_group_id(c: Category, cl: &Cluster, fam: &Family) -> u64 {
    let mut key = String::new();
    key.push_str(c.key());
    key.push_str("|ans|");
    key.push_str(&cl.family.to_string());
    match fam.answer_slots {
        None => {
            for (si, &wi) in cl.slot_choice.iter().enumerate() {
                key.push('|');
                key.push_str(fam.slots[si][wi]);
            }
        }
        Some(slots) => {
            for &si in slots {
                key.push('|');
                key.push_str(fam.slots[si][cl.slot_choice[si]]);
            }
        }
    }
    fnv1a64(key.as_bytes())
}

/// Substitute slot words into the template.
fn render(fam: &Family, template: usize, slot_choice: &[usize]) -> String {
    let mut out = fam.templates[template].to_string();
    for (si, &wi) in slot_choice.iter().enumerate() {
        out = out.replace(&format!("{{{si}}}"), fam.slots[si][wi]);
    }
    out
}

/// Synthesized ground-truth answer. Content is never judged semantically
/// (the judge compares cluster ids); length drives the token/cost model,
/// matching typical LLM answer lengths (60–180 words).
fn synth_answer(c: Category, cluster: u64, question: &str, rng: &mut Rng) -> String {
    let openers = [
        "Here is what you need to know:",
        "Great question.",
        "Thanks for reaching out.",
        "Let me walk you through it.",
    ];
    let filler = [
        "First, confirm the basic details and double check your settings.",
        "In most cases this takes just a couple of minutes to resolve.",
        "If the problem persists, contacting support with the reference number helps.",
        "You can find the relevant option in the main menu under settings.",
        "This approach is recommended because it is simple and reliable.",
        "Keep in mind edge cases and always verify the result afterwards.",
        "The documentation covers this topic in more depth with examples.",
        "A common mistake is skipping the verification step, so do not omit it.",
    ];
    let n_sentences = 3 + rng.below(6);
    let mut s = format!(
        "{} Regarding \"{}\" [ref {:016x}|{}]: ",
        openers[rng.below(openers.len())],
        question,
        cluster,
        c.key()
    );
    for _ in 0..n_sentences {
        s.push_str(filler[rng.below(filler.len())]);
        s.push(' ');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_enumeration_covers_all_combos() {
        // Indirectly: paper-scale generation must find enough clusters in
        // every category (the assert inside generate_category).
        let ds = WorkloadGenerator::new(9).generate(&DatasetConfig::paper());
        assert_eq!(ds.base.len(), 8_000);
    }

    #[test]
    fn answers_embed_group_reference_and_groups_share_answers() {
        let ds = WorkloadGenerator::new(5).generate(&DatasetConfig::small());
        let mut by_group: std::collections::HashMap<u64, &str> =
            std::collections::HashMap::new();
        for p in &ds.base {
            assert!(p.answer.contains(&format!("{:016x}", p.answer_group)));
            assert!(p.answer.len() > 80, "answer too short for the cost model");
            // Same answer group => identical answer text (the property
            // that makes group-level judge verdicts honest).
            let prev = by_group.insert(p.answer_group, p.answer.as_str());
            if let Some(prev) = prev {
                assert_eq!(prev, p.answer, "answer group must share one answer");
            }
        }
    }

    #[test]
    fn novelty_fraction_respected() {
        let ds = WorkloadGenerator::new(6).generate(&DatasetConfig::paper());
        for c in ALL_CATEGORIES {
            let novel = ds.tests_for(c).filter(|q| q.novel).count();
            let expected = (500.0 * category_spec(c).novelty).round() as usize;
            assert_eq!(novel, expected, "{c:?}");
        }
    }

    #[test]
    fn render_replaces_all_markers() {
        let ds = WorkloadGenerator::new(8).generate(&DatasetConfig::small());
        for p in &ds.base {
            assert!(!p.question.contains('{'), "unrendered slot: {}", p.question);
        }
        for q in &ds.tests {
            assert!(!q.text.contains('{'), "unrendered slot: {}", q.text);
        }
    }
}
