//! Transport-agnostic typed serving API (v1).
//!
//! The serving surface of the crate is a pair of message types —
//! [`QueryRequest`] in, [`QueryResponse`] out — plus an [`AdminRequest`]
//! side channel. The coordinator's [`crate::coordinator::Server::serve`]
//! and `serve_batch` speak these types directly; every front-end (the
//! in-process `handle`/`handle_batch` shims, the `semcached` HTTP
//! daemon, future transports) is a thin codec around them.
//!
//! Design points, replacing the pre-v1 surface:
//!
//! * **No sentinel returns.** A lookup-or-insert resolves to a typed
//!   [`Outcome`] (`Hit`/`Miss`/`Rejected`) instead of the old
//!   "`insert` returned 0" convention.
//! * **Per-request options.** Threshold, TTL, and top-k ride on the
//!   request ([`QueryOptions`]), replacing the global
//!   `Server::set_threshold` override; options are validated and an
//!   invalid request is answered with `Outcome::Rejected`, never a
//!   panic.
//! * **Wire-format ready.** Every type round-trips through the in-tree
//!   [`crate::json`] module (`to_json`/`from_json`); `from_json` is
//!   strict (unknown fields and wrong types are errors) so malformed
//!   network input fails loudly at the boundary.
//! * **Tenancy rides `client_tag`.** The tag is not just an echo: it
//!   selects the tenant namespace the query runs in (lookups only see
//!   entries the same tenant inserted; see [`crate::tenancy`]). Untagged
//!   and whitespace-only tags share the `"default"` tenant. A quota
//!   rejection (entry footprint larger than the tenant's byte quota)
//!   surfaces as `Outcome::Rejected`, like any other typed refusal.

use std::collections::BTreeMap;

use crate::error::{anyhow, bail, Context, Result};
use crate::json::{obj, Value};
use crate::llm::FaultPlan;

/// Rejection-reason prefix for "the upstream LLM was unavailable and no
/// degraded candidate existed". Front-ends key on it: the HTTP layer
/// maps rejections carrying this prefix to `503` + `Retry-After` instead
/// of the generic `200`-with-rejected-outcome shape.
pub const REASON_UPSTREAM_UNAVAILABLE: &str = "upstream unavailable";

/// Largest accepted per-request `top_k`. The ANN search pre-allocates
/// `O(top_k)` scratch, so an unbounded remote-supplied value would let
/// one request demand an arbitrary allocation.
pub const MAX_TOP_K: usize = 1024;

/// Per-request overrides for the cache workflow. `None` means "use the
/// server's configured value".
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QueryOptions {
    /// Cosine-similarity gate for this request. Must be finite and in
    /// `[-1, 1]` (the full cosine range, so experiments can run lenient
    /// gates below the configured production threshold).
    pub threshold: Option<f32>,
    /// TTL for an entry inserted by this request, ms (`Some(0)` pins the
    /// entry as immortal, overriding a configured default TTL).
    pub ttl_ms: Option<u64>,
    /// Neighbors fetched before thresholding; must be in
    /// `1..=`[`MAX_TOP_K`].
    pub top_k: Option<usize>,
    /// Skip the exact-match embedding memo tier's *read* for this
    /// request (the forward pass runs even for verbatim repeats; the
    /// fresh embedding is still admitted to the tier). A benchmark /
    /// debugging escape hatch — it never changes results, the encoder
    /// is deterministic.
    pub embed_bypass: bool,
    /// End-to-end serving deadline for this request, ms (overrides the
    /// server's configured `upstream_deadline_ms`). The budget is
    /// consumed from the moment the request is accepted — batcher queue
    /// wait included — and what remains bounds upstream retries; when it
    /// runs out the request degrades or rejects instead of waiting.
    pub deadline_ms: Option<u64>,
}

impl QueryOptions {
    pub fn validate(&self) -> Result<()> {
        if let Some(t) = self.threshold {
            if !t.is_finite() || !(-1.0..=1.0).contains(&t) {
                bail!("threshold must be a finite value in [-1, 1], got {t}");
            }
        }
        if let Some(k) = self.top_k {
            if k == 0 {
                bail!("top_k must be >= 1");
            }
            if k > MAX_TOP_K {
                bail!("top_k must be <= {MAX_TOP_K}, got {k}");
            }
        }
        if self.deadline_ms == Some(0) {
            bail!("deadline_ms must be >= 1");
        }
        Ok(())
    }
}

/// One query, addressed to [`crate::coordinator::Server::serve`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QueryRequest {
    /// The user's query text (must be non-empty).
    pub text: String,
    /// Ground-truth answer-group id when known (evaluation traces);
    /// production callers leave it `None`.
    pub cluster: Option<u64>,
    pub options: QueryOptions,
    /// Caller identifier, echoed back on the response — and the tenant
    /// namespace this query runs in ([`crate::tenancy::normalize_tag`]:
    /// `None`/blank share the `"default"` tenant). Lookups never cross
    /// tenant boundaries.
    pub client_tag: Option<String>,
}

impl QueryRequest {
    pub fn new(text: impl Into<String>) -> Self {
        Self { text: text.into(), ..Self::default() }
    }

    pub fn with_cluster(mut self, cluster: u64) -> Self {
        self.cluster = Some(cluster);
        self
    }

    pub fn with_threshold(mut self, threshold: f32) -> Self {
        self.options.threshold = Some(threshold);
        self
    }

    pub fn with_ttl_ms(mut self, ttl_ms: u64) -> Self {
        self.options.ttl_ms = Some(ttl_ms);
        self
    }

    pub fn with_top_k(mut self, top_k: usize) -> Self {
        self.options.top_k = Some(top_k);
        self
    }

    pub fn with_client_tag(mut self, tag: impl Into<String>) -> Self {
        self.client_tag = Some(tag.into());
        self
    }

    pub fn with_embed_bypass(mut self) -> Self {
        self.options.embed_bypass = true;
        self
    }

    pub fn with_deadline_ms(mut self, deadline_ms: u64) -> Self {
        self.options.deadline_ms = Some(deadline_ms);
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.text.trim().is_empty() {
            bail!("query text must be non-empty");
        }
        self.options.validate()
    }

    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("text".to_string(), Value::Str(self.text.clone()));
        if let Some(c) = self.cluster {
            m.insert("cluster".to_string(), c.into());
        }
        if let Some(t) = self.options.threshold {
            m.insert("threshold".to_string(), Value::Num(t as f64));
        }
        if let Some(ttl) = self.options.ttl_ms {
            m.insert("ttl_ms".to_string(), ttl.into());
        }
        if let Some(k) = self.options.top_k {
            m.insert("top_k".to_string(), k.into());
        }
        if self.options.embed_bypass {
            m.insert("embed_bypass".to_string(), Value::Bool(true));
        }
        if let Some(d) = self.options.deadline_ms {
            m.insert("deadline_ms".to_string(), d.into());
        }
        if let Some(tag) = &self.client_tag {
            m.insert("client_tag".to_string(), Value::Str(tag.clone()));
        }
        Value::Object(m)
    }

    /// Strict wire decode: unknown fields, wrong types, and invalid
    /// option values are all errors (the HTTP layer maps them to 400s).
    pub fn from_json(v: &Value) -> Result<Self> {
        let fields = v.as_object().context("query request must be a JSON object")?;
        for key in fields.keys() {
            match key.as_str() {
                "text" | "cluster" | "threshold" | "ttl_ms" | "top_k" | "client_tag"
                | "embed_bypass" | "deadline_ms" => {}
                other => bail!("unknown field '{other}' in query request"),
            }
        }
        let text = v
            .get("text")
            .as_str()
            .context("missing or non-string field 'text'")?
            .to_string();
        let threshold = match v.get("threshold") {
            Value::Null => None,
            t => Some(t.as_f64().context("field 'threshold' must be a number")? as f32),
        };
        let top_k = match v.get("top_k") {
            Value::Null => None,
            t => Some(t.as_usize().context("field 'top_k' must be a non-negative integer")?),
        };
        let client_tag = match v.get("client_tag") {
            Value::Null => None,
            t => Some(t.as_str().context("field 'client_tag' must be a string")?.to_string()),
        };
        let embed_bypass = match v.get("embed_bypass") {
            Value::Null => false,
            b => b.as_bool().context("field 'embed_bypass' must be a boolean")?,
        };
        let req = QueryRequest {
            text,
            cluster: opt_u64(v.get("cluster"), "cluster")?,
            options: QueryOptions {
                threshold,
                ttl_ms: opt_u64(v.get("ttl_ms"), "ttl_ms")?,
                top_k,
                embed_bypass,
                deadline_ms: opt_u64(v.get("deadline_ms"), "deadline_ms")?,
            },
            client_tag,
        };
        req.validate()?;
        Ok(req)
    }
}

/// How a query resolved against the cache.
#[derive(Debug, Clone, PartialEq)]
pub enum Outcome {
    /// Served from the semantic cache.
    Hit { score: f32, entry_id: u64 },
    /// Cache miss: the (simulated) LLM answered and the reply was
    /// inserted under `inserted_id`.
    Miss { inserted_id: u64 },
    /// Served from the cache at the relaxed `degraded_threshold`
    /// because the upstream was unavailable (breaker open, retries or
    /// deadline exhausted). Explicitly *not* a `Hit`: the score may be
    /// below the request's gate and the answer is best-effort stale —
    /// clients see the degradation, it is never passed off as fresh.
    Degraded { score: f32, entry_id: u64 },
    /// The request was not served by the normal workflow (invalid
    /// options, rejected insert, upstream unavailable with no degraded
    /// candidate — see [`REASON_UPSTREAM_UNAVAILABLE`]).
    Rejected { reason: String },
}

impl Outcome {
    pub fn is_hit(&self) -> bool {
        matches!(self, Outcome::Hit { .. })
    }

    pub fn to_json(&self) -> Value {
        match self {
            Outcome::Hit { score, entry_id } => obj([
                ("type", "hit".into()),
                ("score", Value::Num(*score as f64)),
                ("entry_id", (*entry_id).into()),
            ]),
            Outcome::Miss { inserted_id } => {
                obj([("type", "miss".into()), ("inserted_id", (*inserted_id).into())])
            }
            Outcome::Degraded { score, entry_id } => obj([
                ("type", "degraded".into()),
                ("score", Value::Num(*score as f64)),
                ("entry_id", (*entry_id).into()),
            ]),
            Outcome::Rejected { reason } => {
                obj([("type", "rejected".into()), ("reason", reason.as_str().into())])
            }
        }
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        match v.get("type").as_str() {
            Some("hit") => Ok(Outcome::Hit {
                score: v.get("score").as_f64().context("hit outcome missing number 'score'")?
                    as f32,
                entry_id: v
                    .get("entry_id")
                    .as_u64()
                    .context("hit outcome missing integer 'entry_id'")?,
            }),
            Some("miss") => Ok(Outcome::Miss {
                inserted_id: v
                    .get("inserted_id")
                    .as_u64()
                    .context("miss outcome missing integer 'inserted_id'")?,
            }),
            Some("degraded") => Ok(Outcome::Degraded {
                score: v
                    .get("score")
                    .as_f64()
                    .context("degraded outcome missing number 'score'")? as f32,
                entry_id: v
                    .get("entry_id")
                    .as_u64()
                    .context("degraded outcome missing integer 'entry_id'")?,
            }),
            Some("rejected") => Ok(Outcome::Rejected {
                reason: v
                    .get("reason")
                    .as_str()
                    .context("rejected outcome missing string 'reason'")?
                    .to_string(),
            }),
            _ => Err(anyhow!("outcome 'type' must be hit|miss|degraded|rejected")),
        }
    }
}

/// Per-stage latency of one served query, ms. Measured wall-clock for
/// everything the process does, simulated time for the LLM leg (see
/// DESIGN.md §3).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencyBreakdown {
    pub total_ms: f64,
    pub embed_ms: f64,
    pub index_ms: f64,
    /// Simulated upstream latency (0 for cache hits).
    pub llm_ms: f64,
    /// True when `embed_ms` was an exact-match memo-tier hit (no
    /// encoder forward pass ran for this request).
    pub embed_cached: bool,
    /// True when this response was served in degraded mode (mirrors
    /// `Outcome::Degraded`, so latency rows alone identify stale
    /// serving windows).
    pub degraded: bool,
}

impl LatencyBreakdown {
    pub fn to_json(&self) -> Value {
        obj([
            ("total_ms", self.total_ms.into()),
            ("embed_ms", self.embed_ms.into()),
            ("index_ms", self.index_ms.into()),
            ("llm_ms", self.llm_ms.into()),
            ("embed_cached", Value::Bool(self.embed_cached)),
            ("degraded", Value::Bool(self.degraded)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let num = |k: &str| {
            v.get(k).as_f64().with_context(|| format!("latency field '{k}' must be a number"))
        };
        Ok(Self {
            total_ms: num("total_ms")?,
            embed_ms: num("embed_ms")?,
            index_ms: num("index_ms")?,
            llm_ms: num("llm_ms")?,
            // Absent in pre-memo payloads: default cold.
            embed_cached: match v.get("embed_cached") {
                Value::Null => false,
                b => b.as_bool().context("latency field 'embed_cached' must be a boolean")?,
            },
            // Absent in pre-resilience payloads: default fresh.
            degraded: match v.get("degraded") {
                Value::Null => false,
                b => b.as_bool().context("latency field 'degraded' must be a boolean")?,
            },
        })
    }
}

/// The answer to a [`QueryRequest`].
#[derive(Debug, Clone, PartialEq)]
pub struct QueryResponse {
    /// The response text. Empty for requests rejected *before* serving
    /// (invalid options); a request rejected at insert time — after the
    /// upstream already answered — still carries the upstream's text, so
    /// the answer is never silently dropped.
    pub response: String,
    pub outcome: Outcome,
    pub latency: LatencyBreakdown,
    /// Judge verdict for cache hits when ground truth was provided.
    pub judged_positive: Option<bool>,
    /// Cluster of the cached entry that served a hit.
    pub matched_cluster: Option<u64>,
    /// Echo of the request's `client_tag`.
    pub client_tag: Option<String>,
}

impl QueryResponse {
    /// The answer for a request that failed validation or insert.
    pub fn rejected(req: &QueryRequest, reason: impl Into<String>) -> Self {
        Self {
            response: String::new(),
            outcome: Outcome::Rejected { reason: reason.into() },
            latency: LatencyBreakdown::default(),
            judged_positive: None,
            matched_cluster: None,
            client_tag: req.client_tag.clone(),
        }
    }

    pub fn is_hit(&self) -> bool {
        self.outcome.is_hit()
    }

    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("response".to_string(), Value::Str(self.response.clone()));
        m.insert("outcome".to_string(), self.outcome.to_json());
        m.insert("latency".to_string(), self.latency.to_json());
        if let Some(b) = self.judged_positive {
            m.insert("judged_positive".to_string(), Value::Bool(b));
        }
        if let Some(c) = self.matched_cluster {
            m.insert("matched_cluster".to_string(), c.into());
        }
        if let Some(tag) = &self.client_tag {
            m.insert("client_tag".to_string(), Value::Str(tag.clone()));
        }
        Value::Object(m)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        v.as_object().context("query response must be a JSON object")?;
        Ok(Self {
            response: v.get("response").as_str().context("missing string 'response'")?.to_string(),
            outcome: Outcome::from_json(v.get("outcome"))?,
            latency: LatencyBreakdown::from_json(v.get("latency"))?,
            judged_positive: match v.get("judged_positive") {
                Value::Null => None,
                b => Some(b.as_bool().context("'judged_positive' must be a boolean")?),
            },
            matched_cluster: opt_u64(v.get("matched_cluster"), "matched_cluster")?,
            client_tag: match v.get("client_tag") {
                Value::Null => None,
                t => Some(t.as_str().context("'client_tag' must be a string")?.to_string()),
            },
        })
    }
}

/// Administrative operations on a running server.
#[derive(Debug, Clone, PartialEq)]
pub enum AdminRequest {
    /// Drop every cached entry (all partitions).
    Flush,
    /// Run one housekeeping pass (TTL sweep + rebuild check) now.
    Housekeep,
    /// Write a durability snapshot now and truncate the WAL it covers
    /// (requires the daemon to be serving with `--data-dir`).
    Snapshot,
    /// Snapshot serving metrics and cache state.
    Stats,
    /// Replace the upstream fault schedule (the chaos harness' wire
    /// control). The plan replaces the previous one wholesale; an
    /// all-defaults plan (`"plan": {}` or no plan at all) clears every
    /// fault.
    Fault(FaultPlan),
}

impl AdminRequest {
    pub fn to_json(&self) -> Value {
        let action = match self {
            AdminRequest::Flush => "flush",
            AdminRequest::Housekeep => "housekeep",
            AdminRequest::Snapshot => "snapshot",
            AdminRequest::Stats => "stats",
            AdminRequest::Fault(plan) => {
                return obj([("action", "fault".into()), ("plan", plan.to_json())]);
            }
        };
        obj([("action", action.into())])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        match v.get("action").as_str() {
            Some("flush") => Ok(AdminRequest::Flush),
            Some("housekeep") => Ok(AdminRequest::Housekeep),
            Some("snapshot") => Ok(AdminRequest::Snapshot),
            Some("stats") => Ok(AdminRequest::Stats),
            Some("fault") => {
                let plan = match v.get("plan") {
                    Value::Null => FaultPlan::default(),
                    p => FaultPlan::from_json(p)?,
                };
                Ok(AdminRequest::Fault(plan))
            }
            Some(other) => Err(anyhow!(
                "unknown admin action '{other}' (flush|housekeep|snapshot|stats|fault)"
            )),
            None => Err(anyhow!("admin request must carry a string field 'action'")),
        }
    }
}

/// The result of an [`AdminRequest`].
#[derive(Debug, Clone, PartialEq)]
pub enum AdminResponse {
    Flushed { removed: usize },
    Housekept { expired: usize, rebuilt: usize },
    /// A durability snapshot was written: live entries captured and the
    /// snapshot file size.
    Snapshotted { entries: usize, bytes: usize },
    /// The request named a valid action the server cannot perform in its
    /// current configuration (e.g. `snapshot` without `--data-dir`).
    Unsupported { reason: String },
    Stats(Value),
    /// The upstream fault schedule was replaced; echoes the effective
    /// plan so callers can confirm what the injector is now running.
    FaultSet { plan: FaultPlan },
}

impl AdminResponse {
    pub fn to_json(&self) -> Value {
        match self {
            AdminResponse::Flushed { removed } => {
                obj([("action", "flush".into()), ("removed", (*removed).into())])
            }
            AdminResponse::Housekept { expired, rebuilt } => obj([
                ("action", "housekeep".into()),
                ("expired", (*expired).into()),
                ("rebuilt", (*rebuilt).into()),
            ]),
            AdminResponse::Snapshotted { entries, bytes } => obj([
                ("action", "snapshot".into()),
                ("entries", (*entries).into()),
                ("bytes", (*bytes).into()),
            ]),
            AdminResponse::Unsupported { reason } => {
                obj([("error", reason.as_str().into())])
            }
            AdminResponse::Stats(v) => v.clone(),
            AdminResponse::FaultSet { plan } => {
                obj([("action", "fault".into()), ("plan", plan.to_json())])
            }
        }
    }
}

fn opt_u64(v: &Value, field: &str) -> Result<Option<u64>> {
    match v {
        Value::Null => Ok(None),
        other => other
            .as_u64()
            .with_context(|| format!("field '{field}' must be a non-negative integer"))
            .map(Some),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    #[test]
    fn request_builder_and_roundtrip() {
        let req = QueryRequest::new("how do i reset my password")
            .with_cluster(42)
            .with_threshold(0.75)
            .with_ttl_ms(30_000)
            .with_top_k(3)
            .with_client_tag("bot-7")
            .with_embed_bypass()
            .with_deadline_ms(2_000);
        req.validate().unwrap();
        let wire = req.to_json().to_string();
        let back = QueryRequest::from_json(&parse(&wire).unwrap()).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn minimal_request_omits_optional_fields() {
        let req = QueryRequest::new("hello");
        let j = req.to_json();
        assert!(j.get("cluster").is_null());
        assert!(j.get("threshold").is_null());
        let back = QueryRequest::from_json(&j).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn strict_decode_rejects_malformed_requests() {
        for (src, why) in [
            (r#"[1]"#, "non-object"),
            (r#"{}"#, "missing text"),
            (r#"{"text": 3}"#, "non-string text"),
            (r#"{"text": "  "}"#, "blank text"),
            (r#"{"text": "q", "bogus": 1}"#, "unknown field"),
            (r#"{"text": "q", "top_k": 0}"#, "top_k zero"),
            (r#"{"text": "q", "top_k": -1}"#, "negative top_k"),
            (r#"{"text": "q", "top_k": 1000000000000}"#, "top_k beyond MAX_TOP_K"),
            (r#"{"text": "q", "threshold": 2.0}"#, "threshold out of range"),
            (r#"{"text": "q", "threshold": "hi"}"#, "non-number threshold"),
            (r#"{"text": "q", "ttl_ms": -5}"#, "negative ttl"),
            (r#"{"text": "q", "cluster": 1.5}"#, "fractional cluster"),
            (r#"{"text": "q", "embed_bypass": 1}"#, "non-boolean embed_bypass"),
            (r#"{"text": "q", "deadline_ms": 0}"#, "zero deadline"),
            (r#"{"text": "q", "deadline_ms": -1}"#, "negative deadline"),
            (r#"{"text": "q", "deadline_ms": "soon"}"#, "non-integer deadline"),
        ] {
            let v = parse(src).unwrap();
            assert!(QueryRequest::from_json(&v).is_err(), "should reject {why}: {src}");
        }
    }

    #[test]
    fn options_validate_nan_and_range() {
        let mut o = QueryOptions::default();
        o.threshold = Some(f32::NAN);
        assert!(o.validate().is_err(), "NaN threshold");
        o.threshold = Some(-1.5);
        assert!(o.validate().is_err(), "below cosine range");
        o.threshold = Some(-1.0);
        assert!(o.validate().is_ok(), "lenient but legal");
        o.threshold = None;
        o.top_k = Some(MAX_TOP_K);
        assert!(o.validate().is_ok(), "cap itself is legal");
        o.top_k = Some(MAX_TOP_K + 1);
        assert!(o.validate().is_err(), "beyond the allocation cap");
    }

    #[test]
    fn outcome_roundtrip_and_bad_type() {
        for o in [
            Outcome::Hit { score: 0.8125, entry_id: 7 },
            Outcome::Miss { inserted_id: 1 },
            Outcome::Degraded { score: 0.625, entry_id: 3 },
            Outcome::Rejected { reason: "top_k must be >= 1".into() },
        ] {
            let wire = o.to_json().to_string();
            assert_eq!(Outcome::from_json(&parse(&wire).unwrap()).unwrap(), o);
        }
        assert!(Outcome::from_json(&parse(r#"{"type": "meow"}"#).unwrap()).is_err());
        assert!(Outcome::from_json(&parse(r#"{"type": "hit"}"#).unwrap()).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let resp = QueryResponse {
            response: "click 'forgot password'".into(),
            outcome: Outcome::Hit { score: 0.9375, entry_id: 12 },
            latency: LatencyBreakdown {
                total_ms: 1.5,
                embed_ms: 1.25,
                index_ms: 0.25,
                llm_ms: 0.0,
                embed_cached: true,
                degraded: false,
            },
            judged_positive: Some(true),
            matched_cluster: Some(42),
            client_tag: Some("bot-7".into()),
        };
        let wire = resp.to_json().to_string();
        assert_eq!(QueryResponse::from_json(&parse(&wire).unwrap()).unwrap(), resp);
        // Optional fields absent stay None.
        let bare = QueryResponse::rejected(&QueryRequest::new("q"), "nope");
        let wire = bare.to_json().to_string();
        assert_eq!(QueryResponse::from_json(&parse(&wire).unwrap()).unwrap(), bare);
    }

    #[test]
    fn pre_memo_latency_payload_decodes_as_cold() {
        // Wire payloads from before the memo tier carry no
        // `embed_cached` (nor, later, `degraded`); they must decode (as
        // a cold, fresh serve), not 400.
        let v = parse(r#"{"total_ms": 1.0, "embed_ms": 0.5, "index_ms": 0.25, "llm_ms": 0.0}"#)
            .unwrap();
        let lat = LatencyBreakdown::from_json(&v).unwrap();
        assert!(!lat.embed_cached);
        assert!(!lat.degraded);
    }

    #[test]
    fn degraded_outcome_is_marked_and_never_a_hit() {
        let o = Outcome::Degraded { score: 0.5, entry_id: 9 };
        assert!(!o.is_hit(), "degraded serving must never masquerade as a fresh hit");
        let j = o.to_json();
        assert_eq!(j.get("type").as_str(), Some("degraded"));
        let lat = LatencyBreakdown { degraded: true, ..LatencyBreakdown::default() };
        let back = LatencyBreakdown::from_json(&lat.to_json()).unwrap();
        assert!(back.degraded);
    }

    #[test]
    fn admin_roundtrip() {
        for a in [
            AdminRequest::Flush,
            AdminRequest::Housekeep,
            AdminRequest::Snapshot,
            AdminRequest::Stats,
            AdminRequest::Fault(FaultPlan::full_outage()),
            AdminRequest::Fault(FaultPlan { error_prob: 0.25, ..FaultPlan::default() }),
        ] {
            let wire = a.to_json().to_string();
            assert_eq!(AdminRequest::from_json(&parse(&wire).unwrap()).unwrap(), a);
        }
        assert!(AdminRequest::from_json(&parse(r#"{"action": "reboot"}"#).unwrap()).is_err());
        let r = AdminResponse::Housekept { expired: 3, rebuilt: 1 };
        assert_eq!(r.to_json().get("expired").as_usize(), Some(3));
        let r = AdminResponse::Snapshotted { entries: 12, bytes: 4096 };
        let j = r.to_json();
        assert_eq!(j.get("action").as_str(), Some("snapshot"));
        assert_eq!(j.get("entries").as_usize(), Some(12));
        assert_eq!(j.get("bytes").as_usize(), Some(4096));
        let r = AdminResponse::Unsupported { reason: "no data dir".into() };
        assert_eq!(r.to_json().get("error").as_str(), Some("no data dir"));
    }

    #[test]
    fn admin_fault_verb_decodes_partial_plans() {
        // No plan at all, or an empty plan, clears every fault.
        for src in [r#"{"action": "fault"}"#, r#"{"action": "fault", "plan": {}}"#] {
            match AdminRequest::from_json(&parse(src).unwrap()).unwrap() {
                AdminRequest::Fault(plan) => assert!(plan.is_noop(), "{src}"),
                other => panic!("expected Fault, got {other:?}"),
            }
        }
        // The `outage` shorthand opens a down-until-reconfigured window.
        let v = parse(r#"{"action": "fault", "plan": {"outage": true}}"#).unwrap();
        match AdminRequest::from_json(&v).unwrap() {
            AdminRequest::Fault(plan) => {
                assert_eq!((plan.outage_from_call, plan.outage_until_call), (0, u64::MAX));
            }
            other => panic!("expected Fault, got {other:?}"),
        }
        // Malformed plans are refused at the boundary.
        let v = parse(r#"{"action": "fault", "plan": {"error_prob": 7}}"#).unwrap();
        assert!(AdminRequest::from_json(&v).is_err());
        let r = AdminResponse::FaultSet { plan: FaultPlan::default() };
        let j = r.to_json();
        assert_eq!(j.get("action").as_str(), Some("fault"));
        assert!(j.get("plan").get("error_prob").as_f64().is_some());
    }
}
