//! `semcached` — the semantic cache as a network service.
//!
//! `semcached serve` binds the zero-dependency HTTP/1.1 front-end
//! ([`semcache::coordinator::http`]) over a cache-fronted
//! [`semcache::coordinator::Server`] — by default on the epoll/poll
//! event loop (`--threaded-accept` selects the legacy blocking pool);
//! the `query`/`metrics`/`admin` subcommands are a tiny client for it
//! (no `curl` needed in CI), and `stress-idle` holds many idle
//! keep-alive connections open so scripts can probe idle-fan-in
//! behavior (used by `verify.sh`). Run `semcached help` for usage.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use semcache::cli::{Args, SEMCACHED_USAGE};
use semcache::config::Config;
use semcache::coordinator::{
    http_request, serve_http, HttpConfig, Server, ServerConfig,
};
use semcache::embedding::build_encoder;
use semcache::error::{bail, Context, Result};
use semcache::json::to_string_pretty;
use semcache::workload::{DatasetConfig, WorkloadGenerator};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "" | "help" => {
            print!("{SEMCACHED_USAGE}");
            Ok(())
        }
        "serve" => cmd_serve(&args),
        "query" => cmd_query(&args),
        "metrics" => cmd_metrics(&args),
        "admin" => cmd_admin(&args),
        "stress-idle" => cmd_stress_idle(&args),
        other => bail!("unknown subcommand '{other}' (try `semcached help`)"),
    }
}

/// Assemble the typed config from file + CLI overrides (the daemon's
/// own flags are reserved and skipped).
fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = Config::from_args(
        args,
        &[
            "port",
            "bind",
            "http-workers",
            "workers",
            "populate",
            "port-file",
            "data-dir",
            "batch-max-size",
            "batch-wait-us",
            "batch-queue",
            "no-batch",
            "event-loop",
            "threaded-accept",
            "max-conns",
            "reactors",
            "dispatchers",
        ],
    )?;
    if let Some(w) = args.opt("workers") {
        cfg.workers = w.parse().context("--workers")?;
    }
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    if let Some(dir) = args.opt("data-dir") {
        cfg.data_dir = dir.to_string();
    }
    // Re-validate after *every* override (config file, `--workers`,
    // `--data-dir`): cross-field invariants like "a data dir requires
    // snapshot_interval_secs >= 1" must hold no matter which source
    // supplied each half of the pair.
    cfg.validate()?;
    // The validating builders are the construction path for the daemon:
    // a bad --similarity_threshold (NaN, out of range) fails here, at
    // startup, not as a panic mid-request — and so do bad batcher knobs
    // (--batch-max-size 0, --batch-wait-us beyond 1s).
    let mut server_cfg = ServerConfig::from_app_config(&cfg)?;
    let mut batch = server_cfg.batch.clone();
    if let Some(v) = args.opt("batch-max-size") {
        batch.max_batch_size = v.parse().context("--batch-max-size")?;
    }
    if let Some(v) = args.opt("batch-wait-us") {
        batch.max_wait_us = v.parse().context("--batch-wait-us")?;
    }
    if let Some(v) = args.opt("batch-queue") {
        batch.queue_capacity = v.parse().context("--batch-queue")?;
    }
    batch.validate()?;
    server_cfg.batch = batch;
    let encoder = build_encoder(&cfg)?;
    // `try_new` recovers persisted state (snapshot + WAL replay) when a
    // data dir is configured; without one it is identical to `new`.
    let server = Arc::new(Server::try_new(encoder, server_cfg)?);
    if server.persistence().is_some() {
        let rec = server.recovery();
        eprintln!(
            "[durability: {} entries recovered ({} WAL records replayed{}{}) from {}]",
            rec.entries,
            rec.replayed,
            if rec.torn_tail { ", torn tail trimmed" } else { "" },
            if rec.expired_during_downtime > 0 {
                format!(", {} expired during downtime", rec.expired_during_downtime)
            } else {
                String::new()
            },
            cfg.data_dir,
        );
    }

    if let Some(scale) = args.opt("populate") {
        let ds_cfg = match scale {
            "paper" => DatasetConfig::paper(),
            "small" => DatasetConfig::small(),
            "tiny" => DatasetConfig::tiny(),
            other => bail!("unknown --populate scale '{other}' (paper|small|tiny)"),
        };
        let ds = WorkloadGenerator::new(cfg.workload_seed).generate(&ds_cfg);
        eprintln!("[populating cache with {} QA pairs...]", ds.base.len());
        server.populate(&ds.base);
        server.register_ground_truth(&ds);
    }
    let _hk = server.start_housekeeping(Duration::from_millis(cfg.housekeeping_ms));
    // Periodic snapshots (and WAL truncation) while serving with a data
    // dir; `None` keeps the guard optional without a second code path.
    let _snap = server
        .persistence()
        .is_some()
        .then(|| server.start_snapshotter(Duration::from_secs(cfg.snapshot_interval_secs)));

    let port: u16 = args.opt_parse("port", 8080)?;
    let bind = args.opt("bind").unwrap_or("127.0.0.1");
    let http_workers: usize = args.opt_parse("http-workers", 4)?;
    // `--no-batch value` / `--no-batch=value` parse as an *option*, not
    // a flag; refuse loudly rather than silently serving batched when
    // the operator asked for the escape hatch.
    if args.opt("no-batch").is_some() {
        bail!("--no-batch is a bare flag and takes no value");
    }
    let batching = !args.flag("no-batch");
    // Serving-mode flags (same bare-flag discipline): the event loop is
    // the default; `--threaded-accept` is the escape hatch back to the
    // blocking pool, `--event-loop` forces the default explicitly (e.g.
    // over a config file that set `http_event_loop = false`).
    for mode_flag in ["event-loop", "threaded-accept"] {
        if args.opt(mode_flag).is_some() {
            bail!("--{mode_flag} is a bare flag and takes no value");
        }
    }
    if args.flag("event-loop") && args.flag("threaded-accept") {
        bail!("--event-loop and --threaded-accept are mutually exclusive");
    }
    let event_loop = if args.flag("threaded-accept") {
        false
    } else {
        args.flag("event-loop") || cfg.http_event_loop
    };
    let max_conns: usize = args.opt_parse("max-conns", cfg.http_max_conns)?;
    if max_conns == 0 {
        bail!("--max-conns must be >= 1");
    }
    // Wire-path widths: `--reactors`/`--dispatchers` over the config
    // keys over core-count autosizing. 0 = the pre-sharding
    // single-threaded behavior (normalized to 1 inside serve_http).
    let reactors: usize = args.opt_parse("reactors", cfg.http_reactors)?;
    if reactors > 256 {
        bail!("--reactors must be <= 256");
    }
    let dispatchers: usize = args.opt_parse("dispatchers", cfg.http_dispatchers)?;
    if dispatchers > semcache::coordinator::MAX_DISPATCHERS_LIMIT {
        bail!("--dispatchers must be <= {}", semcache::coordinator::MAX_DISPATCHERS_LIMIT);
    }
    let handle = serve_http(
        server,
        HttpConfig {
            addr: format!("{bind}:{port}"),
            workers: http_workers,
            batching,
            event_loop,
            max_conns,
            reactors,
            dispatchers,
            ..HttpConfig::default()
        },
    )?;
    let addr = handle.local_addr();
    if let Some(path) = args.opt("port-file") {
        // Written atomically (tmp + rename) once the listener is
        // accepting: readers polling the file never observe a partial
        // address, making this the ready-signal handshake for scripts
        // (verify.sh) instead of a fixed sleep.
        let tmp = format!("{path}.tmp");
        std::fs::write(&tmp, addr.to_string())
            .with_context(|| format!("writing --port-file {path}"))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing --port-file {path}"))?;
    }
    println!(
        "semcached listening on http://{addr} ({} mode, max {max_conns} conns, \
         {} reactor(s), {} dispatcher(s))",
        if event_loop { "event-loop" } else { "threaded-accept" },
        reactors.max(1),
        dispatchers.max(1),
    );
    println!("endpoints: POST /v1/query /v1/query_batch /v1/admin | GET /v1/metrics /v1/health");
    // Serve until killed; the accept/worker threads do all the work.
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn addr_of(args: &Args) -> String {
    args.opt("addr").unwrap_or("127.0.0.1:8080").to_string()
}

/// Print a response and fail the process on non-2xx, so shell callers
/// (verify.sh) can gate on the exit code.
fn finish(status: u16, body: &semcache::json::Value) -> Result<()> {
    print!("{}", to_string_pretty(body));
    if status != 200 {
        bail!("server returned HTTP {status}");
    }
    Ok(())
}

fn cmd_query(args: &Args) -> Result<()> {
    let text = args.positional().join(" ");
    if text.trim().is_empty() {
        bail!("usage: semcached query [--addr host:port] <text>");
    }
    let mut req = semcache::api::QueryRequest::new(text);
    if let Some(t) = args.opt("threshold") {
        req = req.with_threshold(t.parse().context("--threshold")?);
    }
    if let Some(k) = args.opt("top-k") {
        req = req.with_top_k(k.parse().context("--top-k")?);
    }
    if let Some(ttl) = args.opt("ttl-ms") {
        req = req.with_ttl_ms(ttl.parse().context("--ttl-ms")?);
    }
    if let Some(d) = args.opt("deadline-ms") {
        req = req.with_deadline_ms(d.parse().context("--deadline-ms")?);
    }
    if let Some(tag) = args.opt("tag") {
        req = req.with_client_tag(tag);
    }
    // `--embed-bypass <word>` would silently swallow the first query
    // word as the option's value (the CLI grammar pairs `--key` with the
    // next non-`--` token); refuse loudly, like `serve` does for
    // `--no-batch`, and require the flag after the text.
    if args.opt("embed-bypass").is_some() {
        bail!("--embed-bypass is a bare flag and takes no value; put it after the query text");
    }
    if args.flag("embed-bypass") {
        req = req.with_embed_bypass();
    }
    let (status, body) =
        http_request(&addr_of(args), "POST", "/v1/query", Some(&req.to_json().to_string()))?;
    finish(status, &body)
}

/// Hold N idle keep-alive connections open against a daemon for a
/// while. This is the exact failure shape of thread-per-connection
/// serving (every idle socket pins a worker); `verify.sh` runs it in
/// the background and asserts a fresh query still answers promptly on
/// the event-loop path.
fn cmd_stress_idle(args: &Args) -> Result<()> {
    let addr = addr_of(args);
    let conns: usize = args.opt_parse("conns", 64)?;
    let hold_ms: u64 = args.opt_parse("hold-ms", 5_000)?;
    let mut held = Vec::with_capacity(conns);
    for i in 0..conns {
        let stream = TcpStream::connect(&addr)
            .with_context(|| format!("opening idle connection {i} to {addr}"))?;
        held.push(stream);
    }
    println!("holding {} idle connections to {addr} for {hold_ms} ms", held.len());
    std::thread::sleep(Duration::from_millis(hold_ms));
    drop(held);
    println!("released");
    Ok(())
}

fn cmd_metrics(args: &Args) -> Result<()> {
    let (status, body) = http_request(&addr_of(args), "GET", "/v1/metrics", None)?;
    finish(status, &body)
}

/// Assemble a partial [`semcache::llm::FaultPlan`] from `admin fault`
/// options. Flags map 1:1 onto the plan's JSON fields and go through
/// the same strict partial-plan codec the wire uses, so range
/// validation lives in exactly one place. No options at all decodes
/// `{}` — "clear all faults".
fn fault_plan_from_args(args: &Args) -> Result<semcache::llm::FaultPlan> {
    let mut m = std::collections::BTreeMap::new();
    for (flag, key) in [
        ("error-prob", "error_prob"),
        ("rate-limit-prob", "rate_limit_prob"),
        ("spike-prob", "spike_prob"),
        ("spike-min-ms", "spike_min_ms"),
        ("spike-max-ms", "spike_max_ms"),
        ("hang-prob", "hang_prob"),
    ] {
        if let Some(v) = args.opt(flag) {
            let p: f64 = v.parse().with_context(|| format!("--{flag}"))?;
            m.insert(key.to_string(), p.into());
        }
    }
    for (flag, key) in [
        ("retry-after-ms", "retry_after_ms"),
        ("hang-ms", "hang_ms"),
        ("outage-from-call", "outage_from_call"),
        ("outage-until-call", "outage_until_call"),
        ("fault-seed", "seed"),
    ] {
        if let Some(v) = args.opt(flag) {
            let n: u64 = v.parse().with_context(|| format!("--{flag}"))?;
            m.insert(key.to_string(), n.into());
        }
    }
    // Same bare-flag discipline as `--no-batch`: `--outage value` would
    // silently swallow the next token.
    if args.opt("outage").is_some() {
        bail!("--outage is a bare flag and takes no value");
    }
    if args.flag("outage") {
        m.insert("outage".to_string(), semcache::json::Value::Bool(true));
    }
    semcache::llm::FaultPlan::from_json(&semcache::json::Value::Object(m))
        .context("assembling fault plan")
}

fn cmd_admin(args: &Args) -> Result<()> {
    let action = match args.positional().first().map(|s| s.as_str()) {
        Some("flush") => semcache::api::AdminRequest::Flush,
        Some("housekeep") => semcache::api::AdminRequest::Housekeep,
        Some("snapshot") => semcache::api::AdminRequest::Snapshot,
        Some("fault") => semcache::api::AdminRequest::Fault(fault_plan_from_args(args)?),
        Some("stats") | None => semcache::api::AdminRequest::Stats,
        Some(other) => {
            bail!("unknown admin action '{other}' (flush|housekeep|snapshot|stats|fault)")
        }
    };
    let (status, body) = http_request(
        &addr_of(args),
        "POST",
        "/v1/admin",
        Some(&action.to_json().to_string()),
    )?;
    finish(status, &body)
}
