//! Serving metrics: counters, latency histograms, cost accounting.
//!
//! The coordinator exposes one [`Metrics`] registry; every component
//! (cache, batcher, upstream) records into it lock-free (atomics) or via
//! a short mutex on the histogram shards. The experiment harness reads a
//! [`MetricsSnapshot`] at the end of a run and renders the paper's rows.

mod histogram;

pub use histogram::Histogram;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::json::{obj, Value};
use crate::util::Summary;

/// Token cost model (USD per 1M tokens), defaults roughly at GPT-4o-mini
/// published pricing — only ratios matter for the reproduction.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub usd_per_1m_input_tokens: f64,
    pub usd_per_1m_output_tokens: f64,
    pub usd_per_1m_embedding_tokens: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            usd_per_1m_input_tokens: 0.15,
            usd_per_1m_output_tokens: 0.60,
            usd_per_1m_embedding_tokens: 0.02,
        }
    }
}

/// Central metrics registry.
#[derive(Default)]
pub struct Metrics {
    // Request-path counters.
    pub requests: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub llm_calls: AtomicU64,
    pub positive_hits: AtomicU64,
    pub negative_hits: AtomicU64,
    /// Requests answered with `Outcome::Rejected` (invalid options,
    /// rejected inserts, upstream unavailable with no degraded
    /// candidate) instead of the normal workflow.
    pub rejected: AtomicU64,
    // Upstream fault domain (coordinator::resilience over llm::FaultPlan).
    /// Requests answered from the cache at the relaxed
    /// `degraded_threshold` because the upstream was unavailable. A
    /// degraded hit is neither a `cache_hits` hit nor a `cache_misses`
    /// miss: the serving balance is
    /// `cache_hits + cache_misses + degraded_hits + rejected == requests`.
    pub degraded_hits: AtomicU64,
    /// Failed upstream call attempts (errors, 429s, timeouts, outage
    /// refusals), counted per attempt.
    pub upstream_errors: AtomicU64,
    /// Upstream attempts that were retries of a failed attempt.
    pub upstream_retries: AtomicU64,
    /// Misses shed by the upstream in-flight concurrency cap (never
    /// attempted upstream).
    pub upstream_shed: AtomicU64,
    /// Circuit-breaker state gauge: 0 = closed, 1 = open, 2 = half-open.
    pub breaker_state: AtomicU64,
    /// Breaker transition counters (closed/half-open → open, open →
    /// half-open, half-open → closed).
    pub breaker_opens: AtomicU64,
    pub breaker_half_opens: AtomicU64,
    pub breaker_closes: AtomicU64,
    // HTTP front-end counters.
    pub http_requests: AtomicU64,
    pub http_errors: AtomicU64,
    /// Connections taken on by the front-end (event loop: at accept;
    /// threaded: when a worker picks the connection up).
    pub http_conns_accepted: AtomicU64,
    /// Connections refused at accept time because `max_conns` was
    /// reached (answered 503 and closed; event-loop mode only).
    pub http_conns_rejected: AtomicU64,
    /// Gauge: connections currently open (accepted minus closed).
    pub http_conns_open: AtomicU64,
    /// Readable events that delivered bytes without completing a request
    /// (slow-drip / fragmented delivery; event-loop mode).
    pub http_parse_stalls: AtomicU64,
    // Embedding memo tier (exact-match LRU in front of the encoder):
    // serving-path encodes answered from / missing the tier. Requests
    // served by an encoder without a memo tier count as misses (every
    // embed is a hit or a miss, mirroring the cache-hit invariant).
    pub embed_cache_hits: AtomicU64,
    pub embed_cache_misses: AtomicU64,
    // Token accounting for the cost model.
    pub llm_input_tokens: AtomicU64,
    pub llm_output_tokens: AtomicU64,
    pub embedding_tokens: AtomicU64,
    // Batch serving pipeline counters.
    pub batches: AtomicU64,
    pub batch_queries: AtomicU64,
    // Cross-request micro-batching engine (coordinator::batcher).
    /// Dispatches (one `serve_batch` call per dispatched micro-batch).
    pub batcher_dispatches: AtomicU64,
    /// Requests that went through the batcher's dispatch path.
    pub batcher_queries: AtomicU64,
    /// Requests answered from an identical in-flight twin in the same
    /// dispatch window (no embed, no lookup, no LLM call of their own).
    pub coalesced: AtomicU64,
    /// Gauge: submissions accepted by the batcher but not yet pulled
    /// into a dispatch (mirrors [`crate::coordinator::Batcher::queue_depth`]).
    pub batch_queue_depth: AtomicU64,
    // Durability (crate::persist): WAL appends, snapshots, recovery.
    /// Records appended to the write-ahead log since startup.
    pub wal_records: AtomicU64,
    /// Framed bytes appended to the write-ahead log since startup.
    pub wal_bytes: AtomicU64,
    /// WAL appends that failed (disk full, dir deleted) for mutations
    /// that were already acknowledged. Non-zero means durability is
    /// degraded until the next successful snapshot — alert on it.
    pub wal_append_errors: AtomicU64,
    /// Snapshots successfully written (temp + atomic rename completed).
    pub snapshots_written: AtomicU64,
    /// Wall time of the startup recovery pass (snapshot load + WAL
    /// replay), in ms. Zero when the server started without a data dir.
    pub recovery_ms: AtomicU64,
    /// Entries restored live by the startup recovery pass.
    pub recovered_entries: AtomicU64,
    // Index kernel selection (crate::index quantized scan).
    /// ANN lookups served while the int8 quantized candidate scan was
    /// active (`quantized_scan` on and not overridden by
    /// `SEMCACHE_SCALAR_KERNELS`). Lookups minus this = exact-scan
    /// lookups, so a deploy can confirm which kernel actually ran.
    pub quantized_lookups: AtomicU64,
    // Latency histograms (ms), mutex-guarded (record is a few ns anyway).
    lat_total: Mutex<Histogram>,
    lat_embed: Mutex<Histogram>,
    /// Embed latency of memo-tier hits only (the paper's dominant
    /// repeat-query shape; contrast with `lat_embed`, which mixes hits
    /// and cold forward passes).
    lat_embed_memo: Mutex<Histogram>,
    lat_index: Mutex<Histogram>,
    lat_llm: Mutex<Histogram>,
    // Per-stage batch pipeline histograms (one observation per batch):
    // summed per-chunk embedding wall, final in-order merge, end-to-end.
    lat_batch_embed: Mutex<Histogram>,
    lat_batch_merge: Mutex<Histogram>,
    lat_batch_total: Mutex<Histogram>,
    // Batcher histograms: time a request sat queued before its dispatch
    // started, wall time of one dispatch (serve + reply fan-out), and the
    // dispatched micro-batch size (a count, recorded through the same
    // histogram type — only `summary()` statistics are read from it).
    lat_queue_wait: Mutex<Histogram>,
    lat_dispatch: Mutex<Histogram>,
    batcher_batch_size: Mutex<Histogram>,
    // Per-reactor breakdowns (event-loop front-end): one block per
    // reactor thread, registered at reactor startup. Reactors bump their
    // own block and the aggregate gauges at the same sites, so the
    // per-reactor values always sum to the aggregates.
    reactors: Mutex<Vec<Arc<ReactorStats>>>,
}

/// Per-reactor counters for the sharded event loop. Each reactor thread
/// owns one (via [`Metrics::register_reactor`]) and bumps it alongside
/// the aggregate connection gauges, giving `/v1/metrics` a per-reactor
/// `open`/`accepted`/`stalls` breakdown that sums to the aggregates.
#[derive(Default)]
pub struct ReactorStats {
    pub accepted: AtomicU64,
    pub open: AtomicU64,
    pub parse_stalls: AtomicU64,
}

impl ReactorStats {
    pub fn conn_open(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
        self.open.fetch_add(1, Ordering::Relaxed);
    }

    /// Saturating, mirroring [`Metrics::record_conn_closed`].
    pub fn conn_closed(&self) {
        let _ = self.open.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            if v > 0 {
                Some(v - 1)
            } else {
                None
            }
        });
    }

    pub fn parse_stall(&self) {
        self.parse_stalls.fetch_add(1, Ordering::Relaxed);
    }
}

/// Circuit-breaker state, mirrored into the `breaker_state` gauge by
/// `coordinator::resilience` on every transition. The numeric encoding
/// (0/1/2) is what lives in the atomic; `/v1/metrics` renders the name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    pub fn as_str(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half_open",
        }
    }

    fn from_gauge(v: u64) -> Self {
        match v {
            1 => BreakerState::Open,
            2 => BreakerState::HalfOpen,
            _ => BreakerState::Closed,
        }
    }

    fn gauge(self) -> u64 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::Open => 1,
            BreakerState::HalfOpen => 2,
        }
    }
}

/// Snapshot of one reactor's block (index = reactor id).
#[derive(Debug, Clone)]
pub struct ReactorSnapshot {
    pub accepted: u64,
    pub open: u64,
    pub parse_stalls: u64,
}

/// Immutable snapshot used by reports and experiments.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub llm_calls: u64,
    pub positive_hits: u64,
    pub negative_hits: u64,
    pub rejected: u64,
    pub degraded_hits: u64,
    pub upstream_errors: u64,
    pub upstream_retries: u64,
    pub upstream_shed: u64,
    pub breaker_state: BreakerState,
    pub breaker_opens: u64,
    pub breaker_half_opens: u64,
    pub breaker_closes: u64,
    pub http_requests: u64,
    pub http_errors: u64,
    pub http_conns_accepted: u64,
    pub http_conns_rejected: u64,
    /// Gauge at snapshot time: currently-open connections.
    pub http_conns_open: u64,
    pub http_parse_stalls: u64,
    pub embed_cache_hits: u64,
    pub embed_cache_misses: u64,
    pub llm_input_tokens: u64,
    pub llm_output_tokens: u64,
    pub embedding_tokens: u64,
    pub batches: u64,
    pub batch_queries: u64,
    pub batcher_dispatches: u64,
    pub batcher_queries: u64,
    pub coalesced: u64,
    /// Gauge at snapshot time: queued-but-undispatched batcher submissions.
    pub batch_queue_depth: u64,
    pub wal_records: u64,
    pub wal_bytes: u64,
    /// Failed appends of acknowledged mutations (durability degraded).
    pub wal_append_errors: u64,
    pub snapshots_written: u64,
    pub recovery_ms: u64,
    pub recovered_entries: u64,
    /// Lookups served by the quantized candidate scan.
    pub quantized_lookups: u64,
    pub lat_total: Summary,
    pub lat_embed: Summary,
    /// Embed latency over memo-tier hits only.
    pub lat_embed_memo: Summary,
    pub lat_index: Summary,
    pub lat_llm: Summary,
    pub lat_batch_embed: Summary,
    pub lat_batch_merge: Summary,
    pub lat_batch_total: Summary,
    pub lat_queue_wait: Summary,
    pub lat_dispatch: Summary,
    /// Statistics over dispatched micro-batch sizes (mean/percentiles of
    /// a count, not a latency).
    pub batcher_batch_size: Summary,
    /// Per-reactor breakdowns (index = reactor id); empty outside
    /// event-loop serving.
    pub reactors: Vec<ReactorSnapshot>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_llm_call(&self, input_tokens: u64, output_tokens: u64) {
        self.llm_calls.fetch_add(1, Ordering::Relaxed);
        self.llm_input_tokens.fetch_add(input_tokens, Ordering::Relaxed);
        self.llm_output_tokens.fetch_add(output_tokens, Ordering::Relaxed);
    }

    pub fn record_embedding(&self, tokens: u64) {
        self.embedding_tokens.fetch_add(tokens, Ordering::Relaxed);
    }

    /// One serving-path embed, resolved by the memo tier (`hit`) or a
    /// cold forward pass.
    pub fn record_embed_cache(&self, hit: bool) {
        if hit {
            self.embed_cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.embed_cache_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// One request answered from the cache at the relaxed degraded
    /// threshold while the upstream was unavailable.
    pub fn record_degraded_hit(&self) {
        self.degraded_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// One failed upstream attempt (per attempt, not per request).
    pub fn record_upstream_error(&self) {
        self.upstream_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One retried upstream attempt (attempt number ≥ 2).
    pub fn record_upstream_retry(&self) {
        self.upstream_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// One miss shed by the in-flight upstream concurrency cap.
    pub fn record_upstream_shed(&self) {
        self.upstream_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// One breaker transition: updates the state gauge and bumps the
    /// matching transition counter.
    pub fn record_breaker_transition(&self, to: BreakerState) {
        self.breaker_state.store(to.gauge(), Ordering::Relaxed);
        match to {
            BreakerState::Open => &self.breaker_opens,
            BreakerState::HalfOpen => &self.breaker_half_opens,
            BreakerState::Closed => &self.breaker_closes,
        }
        .fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_http_request(&self) {
        self.http_requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_http_error(&self) {
        self.http_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One connection taken on (bumps the accepted counter and the
    /// open-connections gauge). Paired with [`Metrics::record_conn_closed`].
    pub fn record_conn_open(&self) {
        self.http_conns_accepted.fetch_add(1, Ordering::Relaxed);
        self.http_conns_open.fetch_add(1, Ordering::Relaxed);
    }

    /// One connection closed (decrements the gauge; saturates at zero so
    /// a stray unpaired call can never wrap the gauge).
    pub fn record_conn_closed(&self) {
        let _ = self.http_conns_open.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| if v > 0 { Some(v - 1) } else { None },
        );
    }

    /// One connection refused at accept time (`max_conns` reached).
    pub fn record_conn_rejected(&self) {
        self.http_conns_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// One readable round that left a request incomplete.
    pub fn record_parse_stall(&self) {
        self.http_parse_stalls.fetch_add(1, Ordering::Relaxed);
    }

    /// Register one reactor thread's per-reactor block; the returned
    /// handle is bumped by that reactor alongside the aggregate gauges.
    /// Blocks appear on `/v1/metrics` as the `"reactors"` array, in
    /// registration order (= reactor id).
    pub fn register_reactor(&self) -> Arc<ReactorStats> {
        let stats = Arc::new(ReactorStats::default());
        self.reactors.lock().unwrap().push(stats.clone());
        stats
    }

    pub fn record_judgement(&self, positive: bool) {
        if positive {
            self.positive_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.negative_hits.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One `handle_batch` call over `queries` queries.
    pub fn record_batch(&self, queries: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_queries.fetch_add(queries, Ordering::Relaxed);
    }

    /// One batcher dispatch coalescing `queries` in-flight requests.
    pub fn record_batcher_dispatch(&self, queries: u64) {
        self.batcher_dispatches.fetch_add(1, Ordering::Relaxed);
        self.batcher_queries.fetch_add(queries, Ordering::Relaxed);
        self.batcher_batch_size.lock().unwrap().observe(queries as f64);
    }

    /// One request answered from an identical in-flight twin.
    pub fn record_coalesced(&self) {
        self.coalesced.fetch_add(1, Ordering::Relaxed);
    }

    /// Refresh the batcher queue-depth gauge (set, not accumulated — the
    /// batcher owns the authoritative counter and mirrors it here on
    /// every enqueue/dequeue).
    pub fn set_batch_queue_depth(&self, depth: u64) {
        self.batch_queue_depth.store(depth, Ordering::Relaxed);
    }

    /// One WAL record appended (`bytes` = framed length on disk).
    pub fn record_wal_append(&self, bytes: u64) {
        self.wal_records.fetch_add(1, Ordering::Relaxed);
        self.wal_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// One WAL append that failed after its mutation was acknowledged.
    pub fn record_wal_append_error(&self) {
        self.wal_append_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One snapshot made durable.
    pub fn record_snapshot_written(&self) {
        self.snapshots_written.fetch_add(1, Ordering::Relaxed);
    }

    /// One ANN lookup that ran the int8 quantized candidate scan.
    pub fn record_quantized_lookup(&self) {
        self.quantized_lookups.fetch_add(1, Ordering::Relaxed);
    }

    /// Result of the startup recovery pass.
    pub fn record_recovery(&self, ms: u64, entries: u64) {
        self.recovery_ms.store(ms, Ordering::Relaxed);
        self.recovered_entries.store(entries, Ordering::Relaxed);
    }

    pub fn observe_total_ms(&self, ms: f64) {
        self.lat_total.lock().unwrap().observe(ms);
    }
    pub fn observe_embed_ms(&self, ms: f64) {
        self.lat_embed.lock().unwrap().observe(ms);
    }
    pub fn observe_embed_memo_ms(&self, ms: f64) {
        self.lat_embed_memo.lock().unwrap().observe(ms);
    }
    pub fn observe_index_ms(&self, ms: f64) {
        self.lat_index.lock().unwrap().observe(ms);
    }
    pub fn observe_llm_ms(&self, ms: f64) {
        self.lat_llm.lock().unwrap().observe(ms);
    }
    pub fn observe_batch_embed_ms(&self, ms: f64) {
        self.lat_batch_embed.lock().unwrap().observe(ms);
    }
    pub fn observe_batch_merge_ms(&self, ms: f64) {
        self.lat_batch_merge.lock().unwrap().observe(ms);
    }
    pub fn observe_batch_total_ms(&self, ms: f64) {
        self.lat_batch_total.lock().unwrap().observe(ms);
    }
    pub fn observe_queue_wait_ms(&self, ms: f64) {
        self.lat_queue_wait.lock().unwrap().observe(ms);
    }
    pub fn observe_dispatch_ms(&self, ms: f64) {
        self.lat_dispatch.lock().unwrap().observe(ms);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            llm_calls: self.llm_calls.load(Ordering::Relaxed),
            positive_hits: self.positive_hits.load(Ordering::Relaxed),
            negative_hits: self.negative_hits.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            degraded_hits: self.degraded_hits.load(Ordering::Relaxed),
            upstream_errors: self.upstream_errors.load(Ordering::Relaxed),
            upstream_retries: self.upstream_retries.load(Ordering::Relaxed),
            upstream_shed: self.upstream_shed.load(Ordering::Relaxed),
            breaker_state: BreakerState::from_gauge(self.breaker_state.load(Ordering::Relaxed)),
            breaker_opens: self.breaker_opens.load(Ordering::Relaxed),
            breaker_half_opens: self.breaker_half_opens.load(Ordering::Relaxed),
            breaker_closes: self.breaker_closes.load(Ordering::Relaxed),
            http_requests: self.http_requests.load(Ordering::Relaxed),
            http_errors: self.http_errors.load(Ordering::Relaxed),
            http_conns_accepted: self.http_conns_accepted.load(Ordering::Relaxed),
            http_conns_rejected: self.http_conns_rejected.load(Ordering::Relaxed),
            http_conns_open: self.http_conns_open.load(Ordering::Relaxed),
            http_parse_stalls: self.http_parse_stalls.load(Ordering::Relaxed),
            embed_cache_hits: self.embed_cache_hits.load(Ordering::Relaxed),
            embed_cache_misses: self.embed_cache_misses.load(Ordering::Relaxed),
            llm_input_tokens: self.llm_input_tokens.load(Ordering::Relaxed),
            llm_output_tokens: self.llm_output_tokens.load(Ordering::Relaxed),
            embedding_tokens: self.embedding_tokens.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batch_queries: self.batch_queries.load(Ordering::Relaxed),
            batcher_dispatches: self.batcher_dispatches.load(Ordering::Relaxed),
            batcher_queries: self.batcher_queries.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            batch_queue_depth: self.batch_queue_depth.load(Ordering::Relaxed),
            wal_records: self.wal_records.load(Ordering::Relaxed),
            wal_bytes: self.wal_bytes.load(Ordering::Relaxed),
            wal_append_errors: self.wal_append_errors.load(Ordering::Relaxed),
            snapshots_written: self.snapshots_written.load(Ordering::Relaxed),
            recovery_ms: self.recovery_ms.load(Ordering::Relaxed),
            recovered_entries: self.recovered_entries.load(Ordering::Relaxed),
            quantized_lookups: self.quantized_lookups.load(Ordering::Relaxed),
            lat_total: self.lat_total.lock().unwrap().summary(),
            lat_embed: self.lat_embed.lock().unwrap().summary(),
            lat_embed_memo: self.lat_embed_memo.lock().unwrap().summary(),
            lat_index: self.lat_index.lock().unwrap().summary(),
            lat_llm: self.lat_llm.lock().unwrap().summary(),
            lat_batch_embed: self.lat_batch_embed.lock().unwrap().summary(),
            lat_batch_merge: self.lat_batch_merge.lock().unwrap().summary(),
            lat_batch_total: self.lat_batch_total.lock().unwrap().summary(),
            lat_queue_wait: self.lat_queue_wait.lock().unwrap().summary(),
            lat_dispatch: self.lat_dispatch.lock().unwrap().summary(),
            batcher_batch_size: self.batcher_batch_size.lock().unwrap().summary(),
            reactors: self
                .reactors
                .lock()
                .unwrap()
                .iter()
                .map(|r| ReactorSnapshot {
                    accepted: r.accepted.load(Ordering::Relaxed),
                    open: r.open.load(Ordering::Relaxed),
                    parse_stalls: r.parse_stalls.load(Ordering::Relaxed),
                })
                .collect(),
        }
    }
}

impl MetricsSnapshot {
    /// Cache hit rate in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.requests as f64
        }
    }

    /// Positive-hit (accuracy) rate among judged hits.
    pub fn positive_rate(&self) -> f64 {
        let judged = self.positive_hits + self.negative_hits;
        if judged == 0 {
            0.0
        } else {
            self.positive_hits as f64 / judged as f64
        }
    }

    /// Fraction of requests that reached the LLM API.
    pub fn api_call_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.llm_calls as f64 / self.requests as f64
        }
    }

    /// USD cost under the given model.
    pub fn cost_usd(&self, m: &CostModel) -> f64 {
        (self.llm_input_tokens as f64 * m.usd_per_1m_input_tokens
            + self.llm_output_tokens as f64 * m.usd_per_1m_output_tokens
            + self.embedding_tokens as f64 * m.usd_per_1m_embedding_tokens)
            / 1e6
    }

    pub fn to_json(&self) -> Value {
        obj([
            ("requests", self.requests.into()),
            ("cache_hits", self.cache_hits.into()),
            ("cache_misses", self.cache_misses.into()),
            ("llm_calls", self.llm_calls.into()),
            ("positive_hits", self.positive_hits.into()),
            ("negative_hits", self.negative_hits.into()),
            ("rejected", self.rejected.into()),
            ("degraded_hits", self.degraded_hits.into()),
            ("upstream_errors", self.upstream_errors.into()),
            ("upstream_retries", self.upstream_retries.into()),
            ("shed", self.upstream_shed.into()),
            ("breaker_state", self.breaker_state.as_str().into()),
            ("breaker_opens", self.breaker_opens.into()),
            ("breaker_half_opens", self.breaker_half_opens.into()),
            ("breaker_closes", self.breaker_closes.into()),
            ("http_requests", self.http_requests.into()),
            ("http_errors", self.http_errors.into()),
            ("conns_accepted", self.http_conns_accepted.into()),
            ("conns_rejected", self.http_conns_rejected.into()),
            ("open_connections", self.http_conns_open.into()),
            ("parse_stalls", self.http_parse_stalls.into()),
            // Per-reactor breakdowns. The block keys (`open`, `accepted`,
            // `stalls`) are deliberately distinct from the aggregate key
            // names above so flat text scrapers (verify.sh) can sum them
            // without ambiguity.
            (
                "reactors",
                Value::Array(
                    self.reactors
                        .iter()
                        .enumerate()
                        .map(|(id, r)| {
                            obj([
                                ("id", (id as u64).into()),
                                ("accepted", r.accepted.into()),
                                ("open", r.open.into()),
                                ("stalls", r.parse_stalls.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("hit_rate", self.hit_rate().into()),
            ("positive_rate", self.positive_rate().into()),
            ("api_call_rate", self.api_call_rate().into()),
            ("lat_total_mean_ms", self.lat_total.mean.into()),
            ("lat_total_p50_ms", self.lat_total.p50.into()),
            ("lat_total_p95_ms", self.lat_total.p95.into()),
            ("lat_total_p99_ms", self.lat_total.p99.into()),
            ("lat_llm_mean_ms", self.lat_llm.mean.into()),
            ("lat_embed_mean_ms", self.lat_embed.mean.into()),
            ("embed_cache_hits", self.embed_cache_hits.into()),
            ("embed_cache_misses", self.embed_cache_misses.into()),
            ("lat_embed_memo_mean_ms", self.lat_embed_memo.mean.into()),
            ("lat_embed_memo_p50_ms", self.lat_embed_memo.p50.into()),
            ("lat_embed_memo_p95_ms", self.lat_embed_memo.p95.into()),
            ("lat_index_mean_ms", self.lat_index.mean.into()),
            ("batches", self.batches.into()),
            ("batch_queries", self.batch_queries.into()),
            ("lat_batch_embed_mean_ms", self.lat_batch_embed.mean.into()),
            ("lat_batch_merge_mean_ms", self.lat_batch_merge.mean.into()),
            ("lat_batch_total_mean_ms", self.lat_batch_total.mean.into()),
            ("batcher_dispatches", self.batcher_dispatches.into()),
            ("batcher_queries", self.batcher_queries.into()),
            ("coalesced", self.coalesced.into()),
            ("batch_queue_depth", self.batch_queue_depth.into()),
            ("batcher_batch_mean", self.batcher_batch_size.mean.into()),
            ("batcher_batch_p95", self.batcher_batch_size.p95.into()),
            ("lat_queue_wait_mean_ms", self.lat_queue_wait.mean.into()),
            ("lat_queue_wait_p95_ms", self.lat_queue_wait.p95.into()),
            ("lat_dispatch_mean_ms", self.lat_dispatch.mean.into()),
            ("wal_records", self.wal_records.into()),
            ("wal_bytes", self.wal_bytes.into()),
            ("wal_append_errors", self.wal_append_errors.into()),
            ("snapshots_written", self.snapshots_written.into()),
            ("recovery_ms", self.recovery_ms.into()),
            ("recovered_entries", self.recovered_entries.into()),
            ("quantized_lookups", self.quantized_lookups.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.record_request();
        }
        for _ in 0..6 {
            m.record_hit();
            m.record_judgement(true);
        }
        for _ in 0..4 {
            m.record_miss();
            m.record_llm_call(100, 50);
        }
        m.record_judgement(false);
        let s = m.snapshot();
        assert!((s.hit_rate() - 0.6).abs() < 1e-12);
        assert!((s.api_call_rate() - 0.4).abs() < 1e-12);
        assert!((s.positive_rate() - 6.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn cost_model() {
        let m = Metrics::new();
        m.record_llm_call(1_000_000, 1_000_000);
        m.record_embedding(1_000_000);
        let s = m.snapshot();
        let c = s.cost_usd(&CostModel::default());
        assert!((c - (0.15 + 0.60 + 0.02)).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.hit_rate(), 0.0);
        assert_eq!(s.positive_rate(), 0.0);
        assert_eq!(s.lat_total.n, 0);
    }

    #[test]
    fn batch_counters_and_stage_latencies() {
        let m = Metrics::new();
        m.record_batch(32);
        m.record_batch(16);
        m.observe_batch_embed_ms(5.0);
        m.observe_batch_merge_ms(0.2);
        m.observe_batch_total_ms(9.0);
        let s = m.snapshot();
        assert_eq!(s.batches, 2);
        assert_eq!(s.batch_queries, 48);
        assert_eq!(s.lat_batch_embed.n, 1);
        assert!((s.lat_batch_total.mean - 9.0).abs() < 1e-9);
        let j = s.to_json();
        assert_eq!(j.get("batches").as_usize(), Some(2));
        assert_eq!(j.get("batch_queries").as_usize(), Some(48));
    }

    #[test]
    fn batcher_counters_and_histograms() {
        let m = Metrics::new();
        m.record_batcher_dispatch(8);
        m.record_batcher_dispatch(2);
        m.record_coalesced();
        m.record_coalesced();
        m.observe_queue_wait_ms(0.5);
        m.observe_dispatch_ms(3.0);
        let s = m.snapshot();
        assert_eq!(s.batcher_dispatches, 2);
        assert_eq!(s.batcher_queries, 10);
        assert_eq!(s.coalesced, 2);
        assert!((s.batcher_batch_size.mean - 5.0).abs() < 1e-9);
        assert_eq!(s.lat_queue_wait.n, 1);
        assert_eq!(s.lat_dispatch.n, 1);
        let j = s.to_json();
        assert_eq!(j.get("batcher_dispatches").as_usize(), Some(2));
        assert_eq!(j.get("coalesced").as_usize(), Some(2));
        assert!(j.get("batcher_batch_mean").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn batch_queue_depth_is_a_gauge() {
        let m = Metrics::new();
        m.set_batch_queue_depth(7);
        assert_eq!(m.snapshot().batch_queue_depth, 7);
        m.set_batch_queue_depth(2);
        let s = m.snapshot();
        assert_eq!(s.batch_queue_depth, 2, "set, not accumulated");
        assert_eq!(s.to_json().get("batch_queue_depth").as_usize(), Some(2));
    }

    #[test]
    fn embed_cache_counters_and_memo_histogram() {
        let m = Metrics::new();
        m.record_embed_cache(true);
        m.record_embed_cache(true);
        m.record_embed_cache(false);
        m.observe_embed_memo_ms(0.01);
        m.observe_embed_memo_ms(0.03);
        let s = m.snapshot();
        assert_eq!(s.embed_cache_hits, 2);
        assert_eq!(s.embed_cache_misses, 1);
        assert_eq!(s.lat_embed_memo.n, 2);
        let j = s.to_json();
        assert_eq!(j.get("embed_cache_hits").as_usize(), Some(2));
        assert_eq!(j.get("embed_cache_misses").as_usize(), Some(1));
        assert!(j.get("lat_embed_memo_p50_ms").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn conn_counters_and_open_gauge() {
        let m = Metrics::new();
        m.record_conn_open();
        m.record_conn_open();
        m.record_conn_open();
        m.record_conn_closed();
        m.record_conn_rejected();
        m.record_parse_stall();
        m.record_parse_stall();
        let s = m.snapshot();
        assert_eq!(s.http_conns_accepted, 3);
        assert_eq!(s.http_conns_open, 2, "gauge = accepted - closed");
        assert_eq!(s.http_conns_rejected, 1);
        assert_eq!(s.http_parse_stalls, 2);
        let j = s.to_json();
        assert_eq!(j.get("conns_accepted").as_usize(), Some(3));
        assert_eq!(j.get("open_connections").as_usize(), Some(2));
        assert_eq!(j.get("conns_rejected").as_usize(), Some(1));
        assert_eq!(j.get("parse_stalls").as_usize(), Some(2));
        // The gauge saturates instead of wrapping on unpaired closes.
        m.record_conn_closed();
        m.record_conn_closed();
        m.record_conn_closed();
        assert_eq!(m.snapshot().http_conns_open, 0);
    }

    #[test]
    fn per_reactor_blocks_sum_to_aggregates() {
        let m = Metrics::new();
        let r0 = m.register_reactor();
        let r1 = m.register_reactor();
        // Reactors bump their own block and the aggregate at the same
        // sites; mirror that discipline here.
        for stats in [&r0, &r0, &r1] {
            m.record_conn_open();
            stats.conn_open();
        }
        m.record_conn_closed();
        r0.conn_closed();
        m.record_parse_stall();
        r1.parse_stall();
        let s = m.snapshot();
        assert_eq!(s.reactors.len(), 2);
        assert_eq!(s.reactors.iter().map(|r| r.accepted).sum::<u64>(), s.http_conns_accepted);
        assert_eq!(s.reactors.iter().map(|r| r.open).sum::<u64>(), s.http_conns_open);
        assert_eq!(
            s.reactors.iter().map(|r| r.parse_stalls).sum::<u64>(),
            s.http_parse_stalls
        );
        let j = s.to_json();
        let blocks = j.get("reactors").as_array().expect("reactors array");
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[0].get("id").as_usize(), Some(0));
        assert_eq!(blocks[0].get("open").as_usize(), Some(1));
        assert_eq!(blocks[1].get("accepted").as_usize(), Some(1));
        assert_eq!(blocks[1].get("stalls").as_usize(), Some(1));
        // Unpaired close saturates per-reactor too.
        r1.conn_closed();
        r1.conn_closed();
        assert_eq!(m.snapshot().reactors[1].open, 0);
    }

    #[test]
    fn durability_counters() {
        let m = Metrics::new();
        m.record_wal_append(120);
        m.record_wal_append(80);
        m.record_wal_append_error();
        m.record_snapshot_written();
        m.record_recovery(42, 17);
        let s = m.snapshot();
        assert_eq!(s.wal_records, 2);
        assert_eq!(s.wal_bytes, 200);
        assert_eq!(s.wal_append_errors, 1);
        assert_eq!(s.snapshots_written, 1);
        assert_eq!(s.recovery_ms, 42);
        assert_eq!(s.recovered_entries, 17);
        let j = s.to_json();
        assert_eq!(j.get("wal_records").as_usize(), Some(2));
        assert_eq!(j.get("wal_bytes").as_usize(), Some(200));
        assert_eq!(j.get("wal_append_errors").as_usize(), Some(1));
        assert_eq!(j.get("snapshots_written").as_usize(), Some(1));
        assert_eq!(j.get("recovered_entries").as_usize(), Some(17));
    }

    #[test]
    fn quantized_lookup_counter() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().quantized_lookups, 0);
        m.record_quantized_lookup();
        m.record_quantized_lookup();
        let s = m.snapshot();
        assert_eq!(s.quantized_lookups, 2);
        assert_eq!(s.to_json().get("quantized_lookups").as_usize(), Some(2));
    }

    #[test]
    fn upstream_fault_counters_and_breaker_gauge() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().breaker_state, BreakerState::Closed, "default gauge");
        m.record_degraded_hit();
        m.record_degraded_hit();
        m.record_upstream_error();
        m.record_upstream_error();
        m.record_upstream_error();
        m.record_upstream_retry();
        m.record_upstream_shed();
        m.record_breaker_transition(BreakerState::Open);
        m.record_breaker_transition(BreakerState::HalfOpen);
        m.record_breaker_transition(BreakerState::Closed);
        m.record_breaker_transition(BreakerState::Open);
        let s = m.snapshot();
        assert_eq!(s.degraded_hits, 2);
        assert_eq!(s.upstream_errors, 3);
        assert_eq!(s.upstream_retries, 1);
        assert_eq!(s.upstream_shed, 1);
        assert_eq!(s.breaker_state, BreakerState::Open, "gauge tracks latest transition");
        assert_eq!(s.breaker_opens, 2);
        assert_eq!(s.breaker_half_opens, 1);
        assert_eq!(s.breaker_closes, 1);
        let j = s.to_json();
        assert_eq!(j.get("degraded_hits").as_usize(), Some(2));
        assert_eq!(j.get("upstream_errors").as_usize(), Some(3));
        assert_eq!(j.get("upstream_retries").as_usize(), Some(1));
        assert_eq!(j.get("shed").as_usize(), Some(1));
        assert_eq!(j.get("breaker_state").as_str(), Some("open"));
        assert_eq!(j.get("breaker_opens").as_usize(), Some(2));
        assert_eq!(j.get("breaker_half_opens").as_usize(), Some(1));
        assert_eq!(j.get("breaker_closes").as_usize(), Some(1));
    }

    #[test]
    fn json_has_key_fields() {
        let m = Metrics::new();
        m.record_request();
        m.observe_total_ms(1.5);
        let j = m.snapshot().to_json();
        assert_eq!(j.get("requests").as_usize(), Some(1));
        assert!(j.get("lat_total_mean_ms").as_f64().unwrap() > 0.0);
    }
}
