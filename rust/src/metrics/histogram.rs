//! Log-bucketed latency histogram plus an exact reservoir for percentile
//! reporting. Buckets cover 1µs .. ~70s with ~8% relative error; the
//! reservoir keeps up to 4096 exact samples (uniform via index hashing)
//! from which `summary()` derives interpolated percentiles.

use crate::util::{Summary};

const BUCKETS: usize = 256;
/// log-spaced: bucket i covers [BASE^i, BASE^(i+1)) microseconds.
const BASE: f64 = 1.08;
const RESERVOIR: usize = 4096;

#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum_ms: f64,
    reservoir: Vec<f64>,
    seen: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; BUCKETS],
            total: 0,
            sum_ms: 0.0,
            reservoir: Vec::with_capacity(RESERVOIR),
            seen: 0,
        }
    }

    fn bucket(ms: f64) -> usize {
        let us = (ms * 1000.0).max(1.0);
        let b = us.ln() / BASE.ln();
        (b as usize).min(BUCKETS - 1)
    }

    pub fn observe(&mut self, ms: f64) {
        let ms = if ms.is_finite() && ms >= 0.0 { ms } else { 0.0 };
        self.counts[Self::bucket(ms)] += 1;
        self.total += 1;
        self.sum_ms += ms;
        // Reservoir sampling (Vitter's algorithm R with splitmix hash for
        // determinism across runs of the same trace).
        self.seen += 1;
        if self.reservoir.len() < RESERVOIR {
            self.reservoir.push(ms);
        } else {
            let mut x = self.seen.wrapping_mul(0x9E3779B97F4A7C15);
            x ^= x >> 29;
            let j = (x % self.seen) as usize;
            if j < RESERVOIR {
                self.reservoir[j] = ms;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_ms(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ms / self.total as f64
        }
    }

    /// Percentile from the log buckets (upper bound of the bucket).
    pub fn bucket_percentile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q / 100.0 * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return BASE.powi(i as i32 + 1) / 1000.0; // µs → ms
            }
        }
        BASE.powi(BUCKETS as i32) / 1000.0
    }

    /// Exact-ish summary from the reservoir (mean from full stream).
    pub fn summary(&self) -> Summary {
        let mut s = Summary::of(&self.reservoir);
        s.n = self.total as usize;
        if self.total > 0 {
            s.mean = self.mean_ms();
        }
        s
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ms += other.sum_ms;
        for &v in &other.reservoir {
            if self.reservoir.len() < RESERVOIR {
                self.reservoir.push(v);
            }
        }
        self.seen += other.seen;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.observe(i as f64);
        }
        assert!((h.mean_ms() - 50.5).abs() < 1e-9);
        assert_eq!(h.count(), 100);
    }

    #[test]
    fn bucket_percentile_monotone_and_close() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.observe(i as f64 * 0.1);
        }
        let p50 = h.bucket_percentile(50.0);
        let p95 = h.bucket_percentile(95.0);
        let p99 = h.bucket_percentile(99.0);
        assert!(p50 <= p95 && p95 <= p99);
        // ~8% bucket error + bucket upper bound.
        assert!((p50 - 50.0).abs() / 50.0 < 0.15, "p50={p50}");
        assert!((p95 - 95.0).abs() / 95.0 < 0.15, "p95={p95}");
    }

    #[test]
    fn summary_uses_reservoir() {
        let mut h = Histogram::new();
        for i in 0..10_000 {
            h.observe((i % 100) as f64);
        }
        let s = h.summary();
        assert_eq!(s.n, 10_000);
        assert!((s.mean - 49.5).abs() < 0.01);
        assert!((s.p50 - 49.5).abs() < 5.0);
    }

    #[test]
    fn pathological_values_do_not_panic() {
        let mut h = Histogram::new();
        h.observe(f64::NAN);
        h.observe(-5.0);
        h.observe(f64::INFINITY);
        h.observe(1e12);
        assert_eq!(h.count(), 4);
        let _ = h.summary();
    }

    #[test]
    fn merge_adds() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.observe(1.0);
        b.observe(3.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean_ms() - 2.0).abs() < 1e-12);
    }
}
