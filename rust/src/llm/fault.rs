//! Seeded, schedule-driven fault injection for the simulated upstream.
//!
//! Real GPT-backed deployments fail in a handful of well-known shapes:
//! per-call 5xx errors, 429 rate limits carrying a `retry-after`,
//! long-tail latency spikes, calls that hang past any reasonable
//! deadline, and full outage windows. [`FaultPlan`] describes a seeded
//! schedule of all five; [`FaultInjector`] replays it deterministically
//! per upstream call index, so a chaos run is exactly reproducible from
//! `(plan, call sequence)`. The plan is runtime-swappable — the
//! `/v1/admin` `fault` verb replaces it over the wire, which is how the
//! chaos harness and `verify.sh` drive outages against a live daemon.
//!
//! Fault decisions draw from their *own* seeded RNG, separate from the
//! answer-synthesis RNG in [`super::SimLlm`]: injecting faults never
//! perturbs the answers a fault-free run would have produced.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::error::{bail, Context, Result};
use crate::json::Value;
use crate::util::Rng;

/// A typed upstream failure (the simulated analogue of the OpenAI API's
/// failure modes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LlmError {
    /// 429: the upstream asked us to back off for `retry_after_ms`.
    RateLimited { retry_after_ms: u64 },
    /// 5xx-style transient server error.
    ServerError,
    /// The call would not have completed within the caller's budget
    /// (a hang or extreme latency spike, cut off at the deadline).
    Timeout { budget_ms: u64 },
    /// The upstream is inside a scheduled full-outage window.
    Outage,
}

impl LlmError {
    /// The upstream's requested backoff, when it sent one.
    pub fn retry_after_ms(&self) -> Option<u64> {
        match self {
            LlmError::RateLimited { retry_after_ms } => Some(*retry_after_ms),
            _ => None,
        }
    }
}

impl std::fmt::Display for LlmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LlmError::RateLimited { retry_after_ms } => {
                write!(f, "upstream rate-limited (retry after {retry_after_ms} ms)")
            }
            LlmError::ServerError => write!(f, "upstream server error"),
            LlmError::Timeout { budget_ms } => {
                write!(f, "upstream call exceeded its {budget_ms} ms budget")
            }
            LlmError::Outage => write!(f, "upstream outage"),
        }
    }
}

/// One seeded fault schedule. The default plan injects nothing — a
/// fault-free `SimLlm` behaves exactly as it did before this module
/// existed.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed for the fault-decision RNG (separate from the answer RNG).
    pub seed: u64,
    /// Per-call probability of a transient `ServerError`.
    pub error_prob: f64,
    /// Per-call probability of a 429 `RateLimited`.
    pub rate_limit_prob: f64,
    /// `retry-after` advertised by injected 429s, ms.
    pub retry_after_ms: u64,
    /// Per-call probability of an added latency spike.
    pub spike_prob: f64,
    /// Spike size range, ms (uniform).
    pub spike_min_ms: f64,
    pub spike_max_ms: f64,
    /// Per-call probability of a hang: the sampled latency jumps by
    /// `hang_ms`, far past any sane deadline, so the caller's budget —
    /// not this module — decides when to give up.
    pub hang_prob: f64,
    pub hang_ms: u64,
    /// Full-outage window over upstream call indices:
    /// calls with `outage_from_call <= index < outage_until_call` fail
    /// with [`LlmError::Outage`]. An empty window (`from >= until`)
    /// means no outage; `(0, u64::MAX)` is "down until reconfigured".
    pub outage_from_call: u64,
    pub outage_until_call: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            seed: 0xFA17,
            error_prob: 0.0,
            rate_limit_prob: 0.0,
            retry_after_ms: 250,
            spike_prob: 0.0,
            spike_min_ms: 800.0,
            spike_max_ms: 2_500.0,
            hang_prob: 0.0,
            hang_ms: 30_000,
            outage_from_call: 0,
            outage_until_call: 0,
        }
    }
}

impl FaultPlan {
    /// Is any fault active under this plan?
    pub fn is_noop(&self) -> bool {
        self.error_prob == 0.0
            && self.rate_limit_prob == 0.0
            && self.spike_prob == 0.0
            && self.hang_prob == 0.0
            && self.outage_from_call >= self.outage_until_call
    }

    /// A plan whose only effect is a full outage until reconfigured.
    pub fn full_outage() -> Self {
        Self { outage_from_call: 0, outage_until_call: u64::MAX, ..Self::default() }
    }

    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("error_prob", self.error_prob),
            ("rate_limit_prob", self.rate_limit_prob),
            ("spike_prob", self.spike_prob),
            ("hang_prob", self.hang_prob),
        ] {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                bail!("fault {name} must be a probability in [0, 1], got {p}");
            }
        }
        for (name, ms) in [("spike_min_ms", self.spike_min_ms), ("spike_max_ms", self.spike_max_ms)]
        {
            if !ms.is_finite() || ms < 0.0 {
                bail!("fault {name} must be finite and >= 0, got {ms}");
            }
        }
        if self.spike_max_ms < self.spike_min_ms {
            bail!(
                "fault spike_max_ms ({}) must be >= spike_min_ms ({})",
                self.spike_max_ms,
                self.spike_min_ms
            );
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("seed".to_string(), self.seed.into());
        m.insert("error_prob".to_string(), self.error_prob.into());
        m.insert("rate_limit_prob".to_string(), self.rate_limit_prob.into());
        m.insert("retry_after_ms".to_string(), self.retry_after_ms.into());
        m.insert("spike_prob".to_string(), self.spike_prob.into());
        m.insert("spike_min_ms".to_string(), self.spike_min_ms.into());
        m.insert("spike_max_ms".to_string(), self.spike_max_ms.into());
        m.insert("hang_prob".to_string(), self.hang_prob.into());
        m.insert("hang_ms".to_string(), self.hang_ms.into());
        m.insert("outage_from_call".to_string(), self.outage_from_call.into());
        m.insert("outage_until_call".to_string(), self.outage_until_call.into());
        Value::Object(m)
    }

    /// Strict decode over a *partial* plan: absent fields keep their
    /// defaults, so `{}` is "clear all faults" and
    /// `{"outage": true}` is shorthand for a down-until-reconfigured
    /// window. Unknown fields are errors, like every v1 codec.
    pub fn from_json(v: &Value) -> Result<Self> {
        let fields = v.as_object().context("fault plan must be a JSON object")?;
        for key in fields.keys() {
            match key.as_str() {
                "seed" | "error_prob" | "rate_limit_prob" | "retry_after_ms" | "spike_prob"
                | "spike_min_ms" | "spike_max_ms" | "hang_prob" | "hang_ms"
                | "outage_from_call" | "outage_until_call" | "outage" => {}
                other => bail!("unknown field '{other}' in fault plan"),
            }
        }
        let mut plan = FaultPlan::default();
        let num = |key: &str, out: &mut f64| -> Result<()> {
            match v.get(key) {
                Value::Null => Ok(()),
                x => {
                    *out = x.as_f64().with_context(|| format!("fault '{key}' must be a number"))?;
                    Ok(())
                }
            }
        };
        let int = |key: &str, out: &mut u64| -> Result<()> {
            match v.get(key) {
                Value::Null => Ok(()),
                x => {
                    *out = x
                        .as_u64()
                        .with_context(|| format!("fault '{key}' must be a non-negative integer"))?;
                    Ok(())
                }
            }
        };
        int("seed", &mut plan.seed)?;
        num("error_prob", &mut plan.error_prob)?;
        num("rate_limit_prob", &mut plan.rate_limit_prob)?;
        int("retry_after_ms", &mut plan.retry_after_ms)?;
        num("spike_prob", &mut plan.spike_prob)?;
        num("spike_min_ms", &mut plan.spike_min_ms)?;
        num("spike_max_ms", &mut plan.spike_max_ms)?;
        num("hang_prob", &mut plan.hang_prob)?;
        int("hang_ms", &mut plan.hang_ms)?;
        int("outage_from_call", &mut plan.outage_from_call)?;
        int("outage_until_call", &mut plan.outage_until_call)?;
        match v.get("outage") {
            Value::Null => {}
            b => {
                if b.as_bool().context("fault 'outage' must be a boolean")? {
                    plan.outage_from_call = 0;
                    plan.outage_until_call = u64::MAX;
                } else {
                    plan.outage_from_call = 0;
                    plan.outage_until_call = 0;
                }
            }
        }
        plan.validate()?;
        Ok(plan)
    }
}

/// What the injector decided for one upstream call.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultDecision {
    /// `Some` fails the call outright.
    pub error: Option<LlmError>,
    /// Extra latency (spike/hang) added to a surviving call, ms.
    pub extra_latency_ms: f64,
}

impl FaultDecision {
    fn clean() -> Self {
        Self { error: None, extra_latency_ms: 0.0 }
    }
}

/// Replays a [`FaultPlan`] deterministically over upstream call indices.
pub struct FaultInjector {
    state: Mutex<FaultState>,
}

struct FaultState {
    plan: FaultPlan,
    rng: Rng,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        let rng = Rng::new(plan.seed);
        Self { state: Mutex::new(FaultState { plan, rng }) }
    }

    /// Swap in a new plan; the fault RNG is re-seeded from the plan, so
    /// behavior from this moment is reproducible from the plan alone.
    pub fn set_plan(&self, plan: FaultPlan) {
        let mut s = self.state.lock().unwrap();
        s.rng = Rng::new(plan.seed);
        s.plan = plan;
    }

    pub fn plan(&self) -> FaultPlan {
        self.state.lock().unwrap().plan.clone()
    }

    /// Decide the fate of upstream call `call_idx`. The outage window is
    /// checked first (pure schedule, no randomness); the probabilistic
    /// draws happen in a fixed order so a given plan replays bit-for-bit.
    pub fn decide(&self, call_idx: u64) -> FaultDecision {
        let mut s = self.state.lock().unwrap();
        if s.plan.is_noop() {
            return FaultDecision::clean();
        }
        if call_idx >= s.plan.outage_from_call && call_idx < s.plan.outage_until_call {
            return FaultDecision { error: Some(LlmError::Outage), extra_latency_ms: 0.0 };
        }
        let plan = s.plan.clone();
        if plan.rate_limit_prob > 0.0 && s.rng.chance(plan.rate_limit_prob) {
            return FaultDecision {
                error: Some(LlmError::RateLimited { retry_after_ms: plan.retry_after_ms }),
                extra_latency_ms: 0.0,
            };
        }
        if plan.error_prob > 0.0 && s.rng.chance(plan.error_prob) {
            return FaultDecision { error: Some(LlmError::ServerError), extra_latency_ms: 0.0 };
        }
        let mut extra = 0.0;
        if plan.hang_prob > 0.0 && s.rng.chance(plan.hang_prob) {
            extra += plan.hang_ms as f64;
        }
        if plan.spike_prob > 0.0 && s.rng.chance(plan.spike_prob) {
            extra += s.rng.range_f64(plan.spike_min_ms, plan.spike_max_ms);
        }
        FaultDecision { error: None, extra_latency_ms: extra }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    #[test]
    fn default_plan_injects_nothing() {
        let inj = FaultInjector::new(FaultPlan::default());
        for i in 0..1000 {
            assert_eq!(inj.decide(i), FaultDecision::clean());
        }
    }

    #[test]
    fn outage_window_is_schedule_exact() {
        let plan =
            FaultPlan { outage_from_call: 3, outage_until_call: 6, ..FaultPlan::default() };
        let inj = FaultInjector::new(plan);
        for i in 0..10 {
            let d = inj.decide(i);
            if (3..6).contains(&i) {
                assert_eq!(d.error, Some(LlmError::Outage), "call {i} must be in the outage");
            } else {
                assert_eq!(d.error, None, "call {i} must survive");
            }
        }
    }

    #[test]
    fn seeded_schedules_replay_identically() {
        let plan = FaultPlan {
            error_prob: 0.3,
            rate_limit_prob: 0.2,
            spike_prob: 0.25,
            hang_prob: 0.1,
            ..FaultPlan::default()
        };
        let a = FaultInjector::new(plan.clone());
        let b = FaultInjector::new(plan);
        let run = |inj: &FaultInjector| -> Vec<FaultDecision> {
            (0..200).map(|i| inj.decide(i)).collect()
        };
        assert_eq!(run(&a), run(&b));
        // Reconfiguring re-seeds: the same plan replays again.
        let replay = a.plan();
        a.set_plan(replay);
        assert_eq!(run(&a), run(&b).clone());
    }

    #[test]
    fn rate_limit_carries_retry_after() {
        let plan =
            FaultPlan { rate_limit_prob: 1.0, retry_after_ms: 777, ..FaultPlan::default() };
        let inj = FaultInjector::new(plan);
        match inj.decide(0).error {
            Some(LlmError::RateLimited { retry_after_ms }) => assert_eq!(retry_after_ms, 777),
            other => panic!("expected RateLimited, got {other:?}"),
        }
        assert_eq!(
            inj.decide(1).error.as_ref().and_then(|e| e.retry_after_ms()),
            Some(777)
        );
    }

    #[test]
    fn plan_json_roundtrip_and_partial_decode() {
        let plan = FaultPlan {
            seed: 9,
            error_prob: 0.5,
            rate_limit_prob: 0.125,
            retry_after_ms: 100,
            spike_prob: 0.25,
            spike_min_ms: 10.0,
            spike_max_ms: 20.0,
            hang_prob: 0.0625,
            hang_ms: 5_000,
            outage_from_call: 2,
            outage_until_call: 8,
        };
        let wire = plan.to_json().to_string();
        assert_eq!(FaultPlan::from_json(&parse(&wire).unwrap()).unwrap(), plan);

        // `{}` clears everything; `outage` shorthand opens/closes the window.
        let cleared = FaultPlan::from_json(&parse("{}").unwrap()).unwrap();
        assert!(cleared.is_noop());
        let down = FaultPlan::from_json(&parse(r#"{"outage": true}"#).unwrap()).unwrap();
        assert_eq!((down.outage_from_call, down.outage_until_call), (0, u64::MAX));
        assert!(!down.is_noop());
        let up = FaultPlan::from_json(&parse(r#"{"outage": false}"#).unwrap()).unwrap();
        assert!(up.is_noop());

        // Strictness: unknown fields and bad probabilities are errors.
        assert!(FaultPlan::from_json(&parse(r#"{"bogus": 1}"#).unwrap()).is_err());
        assert!(FaultPlan::from_json(&parse(r#"{"error_prob": 1.5}"#).unwrap()).is_err());
        assert!(FaultPlan::from_json(&parse(r#"{"error_prob": -0.1}"#).unwrap()).is_err());
        assert!(FaultPlan::from_json(
            &parse(r#"{"spike_min_ms": 50, "spike_max_ms": 10}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn hangs_and_spikes_add_latency_without_failing() {
        let plan = FaultPlan {
            hang_prob: 1.0,
            hang_ms: 30_000,
            spike_prob: 1.0,
            spike_min_ms: 100.0,
            spike_max_ms: 200.0,
            ..FaultPlan::default()
        };
        let inj = FaultInjector::new(plan);
        let d = inj.decide(0);
        assert_eq!(d.error, None);
        assert!(d.extra_latency_ms >= 30_100.0, "hang + spike: {}", d.extra_latency_ms);
    }
}
