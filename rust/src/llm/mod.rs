//! Simulated LLM upstream + validation judge (DESIGN.md §3 substitutions).
//!
//! The paper calls the OpenAI GPT API for cache misses and uses GPT-4o
//! Mini to validate cache hits. Offline we replace both:
//!
//! * [`SimLlm`] — deterministic upstream with a calibrated latency model
//!   (network RTT + per-output-token decode time, log-normal-ish jitter)
//!   and token-metered accounting. Latency is *virtual* by default (the
//!   experiment clock sums it without sleeping) and can optionally pace
//!   wall-clock for the live-serving demo.
//! * [`Judge`] — labels a cache hit positive iff the cached entry's
//!   ground-truth cluster matches the query's cluster (the noise-free
//!   analogue of the paper's LLM judge; an optional error rate models
//!   judge disagreement).

mod fault;
mod judge;
mod sim;

pub use fault::{FaultDecision, FaultInjector, FaultPlan, LlmError};
pub use judge::{Judge, JudgeConfig};
pub use sim::{LlmResponse, SimLlm, SimLlmConfig};

/// Approximate token count of a text under a GPT-style BPE: the paper's
/// cost accounting only needs ratios, so words × 4/3 is the standard
/// serviceable estimate.
pub fn approx_tokens(text: &str) -> u64 {
    let words = text.split_whitespace().count() as u64;
    (words * 4).div_ceil(3).max(1)
}

#[cfg(test)]
mod tests {
    #[test]
    fn token_estimate_scales_with_words() {
        use super::approx_tokens;
        assert_eq!(approx_tokens("one two three"), 4);
        assert!(approx_tokens("") >= 1);
        let long: String = std::iter::repeat("word").take(300).collect::<Vec<_>>().join(" ");
        assert_eq!(approx_tokens(&long), 400);
    }
}
