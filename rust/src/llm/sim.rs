//! The simulated GPT upstream.
//!
//! Latency model (calibrated against public GPT-4o-mini serving numbers,
//! only the *ratio* to the cache path matters for Figure 3):
//!
//! ```text
//! latency = rtt + out_tokens * ms_per_token   (+ lognormal-ish jitter
//!           on both terms via exp(N(0, sigma)))
//! ```
//!
//! Answers come from the workload's ground truth when provided (so cache
//! misses populate the cache with the *right* response for their
//! cluster), else a deterministic synthetic completion.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{bail, Result};
use crate::util::Rng;

use super::{approx_tokens, FaultInjector, FaultPlan, LlmError};

/// Upstream configuration.
#[derive(Debug, Clone)]
pub struct SimLlmConfig {
    /// Mean network + queueing round trip, ms.
    pub rtt_ms: f64,
    /// Decode time per output token, ms (≈ 80 tok/s → 12.5).
    pub ms_per_token: f64,
    /// Mean output length when synthesizing an answer, tokens.
    pub mean_output_tokens: f64,
    /// σ of the lognormal jitter on rtt and decode rate.
    pub jitter_sigma: f64,
    /// If true, `call` sleeps the sampled latency (live demo); if false
    /// the latency is only reported (fast experiments).
    pub real_sleep: bool,
    pub seed: u64,
}

impl Default for SimLlmConfig {
    fn default() -> Self {
        Self {
            rtt_ms: 150.0,
            ms_per_token: 12.0,
            mean_output_tokens: 120.0,
            jitter_sigma: 0.25,
            real_sleep: false,
            seed: 0x11AA,
        }
    }
}

impl SimLlmConfig {
    /// Latency-model parameters from the app-level
    /// [`crate::config::Config`] (shared by both binaries).
    pub fn from_app_config(cfg: &crate::config::Config) -> SimLlmConfig {
        // Every field maps explicitly: a `..Default::default()` here once
        // silently dropped `jitter_sigma` and `seed`, making chaos runs
        // unreproducible from config files.
        SimLlmConfig {
            rtt_ms: cfg.llm_rtt_ms,
            ms_per_token: cfg.llm_ms_per_token,
            mean_output_tokens: cfg.llm_mean_output_tokens,
            jitter_sigma: cfg.llm_jitter_sigma,
            real_sleep: cfg.llm_real_sleep,
            seed: cfg.llm_seed,
        }
    }

    /// Reject latency-model parameters that would make sampled latencies
    /// NaN, negative, or degenerate (used by `ServerConfig::builder`).
    pub fn validate(&self) -> Result<()> {
        if !self.rtt_ms.is_finite() || self.rtt_ms < 0.0 {
            bail!("llm rtt_ms must be finite and >= 0, got {}", self.rtt_ms);
        }
        if !self.ms_per_token.is_finite() || self.ms_per_token < 0.0 {
            bail!("llm ms_per_token must be finite and >= 0, got {}", self.ms_per_token);
        }
        if !self.mean_output_tokens.is_finite() || self.mean_output_tokens <= 0.0 {
            bail!("llm mean_output_tokens must be finite and > 0, got {}", self.mean_output_tokens);
        }
        if !self.jitter_sigma.is_finite() || self.jitter_sigma < 0.0 {
            bail!("llm jitter_sigma must be finite and >= 0, got {}", self.jitter_sigma);
        }
        Ok(())
    }
}

/// One upstream completion.
#[derive(Debug, Clone)]
pub struct LlmResponse {
    pub text: String,
    pub input_tokens: u64,
    pub output_tokens: u64,
    /// Sampled end-to-end latency of this call, ms.
    pub latency_ms: f64,
}

/// Deterministic simulated LLM API with a runtime-swappable fault
/// schedule (see [`FaultInjector`]).
pub struct SimLlm {
    cfg: SimLlmConfig,
    rng: Mutex<Rng>,
    calls: AtomicU64,
    faults: FaultInjector,
}

impl SimLlm {
    pub fn new(cfg: SimLlmConfig) -> Self {
        let seed = cfg.seed;
        Self {
            cfg,
            rng: Mutex::new(Rng::new(seed)),
            calls: AtomicU64::new(0),
            faults: FaultInjector::new(FaultPlan::default()),
        }
    }

    pub fn config(&self) -> &SimLlmConfig {
        &self.cfg
    }

    /// Upstream call attempts, including ones that failed.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Replace the active fault schedule (the `/v1/admin` fault verb
    /// lands here). Takes effect on the next call.
    pub fn set_fault_plan(&self, plan: FaultPlan) {
        self.faults.set_plan(plan);
    }

    pub fn fault_plan(&self) -> FaultPlan {
        self.faults.plan()
    }

    /// Complete a query. `ground_truth` supplies the workload's answer
    /// text when known; otherwise a synthetic completion is generated.
    /// Fails when the active [`FaultPlan`] says this call fails.
    pub fn call(&self, question: &str, ground_truth: Option<&str>) -> Result<LlmResponse, LlmError> {
        self.call_within(question, ground_truth, None)
    }

    /// [`SimLlm::call`] under a latency budget: a call whose sampled
    /// latency (including injected hangs/spikes) exceeds `budget_ms`
    /// fails with [`LlmError::Timeout`] instead of being reported — or
    /// slept — in full. This is how the resilience layer cuts off hung
    /// calls at the request deadline without parking a thread.
    pub fn call_within(
        &self,
        question: &str,
        ground_truth: Option<&str>,
        budget_ms: Option<u64>,
    ) -> Result<LlmResponse, LlmError> {
        let idx = self.calls.fetch_add(1, Ordering::Relaxed);
        let fault = self.faults.decide(idx);
        if let Some(err) = fault.error {
            // A refused call still pays roughly one network round trip
            // when pacing wall-clock (errors are fast, not free).
            if self.cfg.real_sleep {
                let wait_ms = match budget_ms {
                    Some(b) => self.cfg.rtt_ms.min(b as f64),
                    None => self.cfg.rtt_ms,
                };
                std::thread::sleep(std::time::Duration::from_micros((wait_ms * 1e3) as u64));
            }
            return Err(err);
        }
        let (answer, jr, jd, extra) = {
            let mut rng = self.rng.lock().unwrap();
            let answer = match ground_truth {
                Some(a) => a.to_string(),
                None => synth_completion(question, &mut rng),
            };
            // Jitter factors: exp(N(0, σ)) — multiplicative, mean ≈ 1.
            let jr = (rng.normal(0.0, self.cfg.jitter_sigma)).exp();
            let jd = (rng.normal(0.0, self.cfg.jitter_sigma)).exp();
            // Occasional long-tail stall (p95-ish spikes seen in real APIs).
            let extra = if rng.chance(0.02) { rng.range_f64(500.0, 2000.0) } else { 0.0 };
            (answer, jr, jd, extra)
        };
        let input_tokens = approx_tokens(question);
        let output_tokens = approx_tokens(&answer);
        let latency_ms = self.cfg.rtt_ms * jr
            + output_tokens as f64 * self.cfg.ms_per_token * jd
            + extra
            + fault.extra_latency_ms;
        if let Some(budget) = budget_ms {
            if latency_ms > budget as f64 {
                // The caller would have given up at the deadline; when
                // pacing wall-clock we sleep exactly the budget.
                if self.cfg.real_sleep {
                    std::thread::sleep(std::time::Duration::from_millis(budget));
                }
                return Err(LlmError::Timeout { budget_ms: budget });
            }
        }
        if self.cfg.real_sleep {
            std::thread::sleep(std::time::Duration::from_micros((latency_ms * 1e3) as u64));
        }
        Ok(LlmResponse { text: answer, input_tokens, output_tokens, latency_ms })
    }
}

fn synth_completion(question: &str, rng: &mut Rng) -> String {
    let n_words = (rng.exponential(90.0) as usize).clamp(20, 400);
    let mut s = format!("Here is an answer to \"{question}\". ");
    let lexicon = [
        "the", "system", "will", "process", "your", "request", "and",
        "return", "a", "result", "based", "on", "standard", "settings",
        "please", "verify", "details", "before", "continuing", "carefully",
    ];
    for _ in 0..n_words {
        s.push_str(lexicon[rng.below(lexicon.len())]);
        s.push(' ');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ground_truth_passthrough_and_accounting() {
        let llm = SimLlm::new(SimLlmConfig::default());
        let r = llm.call("where is my order", Some("It ships tomorrow.")).unwrap();
        assert_eq!(r.text, "It ships tomorrow.");
        assert_eq!(r.input_tokens, approx_tokens("where is my order"));
        assert_eq!(r.output_tokens, approx_tokens("It ships tomorrow."));
        assert_eq!(llm.calls(), 1);
    }

    #[test]
    fn latency_positive_and_token_scaled() {
        let llm = SimLlm::new(SimLlmConfig { jitter_sigma: 0.0, ..Default::default() });
        let short = llm.call("q", Some("short answer here")).unwrap();
        let long_text: String =
            std::iter::repeat("word").take(300).collect::<Vec<_>>().join(" ");
        let long = llm.call("q", Some(&long_text)).unwrap();
        assert!(short.latency_ms > 100.0, "rtt floor");
        assert!(long.latency_ms > short.latency_ms + 1000.0, "decode dominates long outputs");
    }

    #[test]
    fn mean_latency_in_expected_band() {
        let llm = SimLlm::new(SimLlmConfig::default());
        let mut total = 0.0;
        let n = 500;
        for i in 0..n {
            total += llm.call(&format!("question {i}"), None).unwrap().latency_ms;
        }
        let mean = total / n as f64;
        // rtt 150 + ~mean tokens * 12 with jitter: order of 0.5–3.5 s.
        assert!((500.0..3500.0).contains(&mean), "mean latency {mean}");
        assert_eq!(llm.calls(), n);
    }

    #[test]
    fn synthetic_answers_deterministic_per_instance() {
        let a = SimLlm::new(SimLlmConfig::default()).call("q", None).unwrap().text;
        let b = SimLlm::new(SimLlmConfig::default()).call("q", None).unwrap().text;
        assert_eq!(a, b);
    }

    #[test]
    fn outage_plan_fails_calls_then_clears() {
        let llm = SimLlm::new(SimLlmConfig::default());
        llm.set_fault_plan(FaultPlan::full_outage());
        assert_eq!(llm.call("q", None).unwrap_err(), LlmError::Outage);
        assert_eq!(llm.call("q", None).unwrap_err(), LlmError::Outage);
        // Failed attempts are still counted calls.
        assert_eq!(llm.calls(), 2);
        llm.set_fault_plan(FaultPlan::default());
        assert!(llm.call("q", Some("back up")).is_ok());
    }

    #[test]
    fn budget_cuts_off_injected_hangs_as_timeouts() {
        let llm = SimLlm::new(SimLlmConfig { jitter_sigma: 0.0, ..Default::default() });
        llm.set_fault_plan(FaultPlan { hang_prob: 1.0, hang_ms: 60_000, ..FaultPlan::default() });
        match llm.call_within("q", Some("a"), Some(2_000)) {
            Err(LlmError::Timeout { budget_ms }) => assert_eq!(budget_ms, 2_000),
            other => panic!("expected Timeout, got {other:?}"),
        }
        // Without a budget the hang is reported as (huge) latency.
        let r = llm.call_within("q", Some("a"), None).unwrap();
        assert!(r.latency_ms > 60_000.0);
    }

    #[test]
    fn faults_do_not_perturb_answer_synthesis() {
        // A faulty run's surviving answers must match the fault-free
        // run's answers for the same questions (separate RNG streams).
        let clean = SimLlm::new(SimLlmConfig::default());
        let faulty = SimLlm::new(SimLlmConfig::default());
        faulty.set_fault_plan(FaultPlan {
            spike_prob: 0.5,
            hang_prob: 0.25,
            hang_ms: 1,
            ..FaultPlan::default()
        });
        for i in 0..50 {
            let q = format!("question number {i}");
            let a = clean.call(&q, None).unwrap().text;
            let b = faulty.call(&q, None).unwrap().text;
            assert_eq!(a, b, "answer diverged at {i}");
        }
    }
}
