//! Hit-validation judge — the GPT-4o-mini substitute (paper §3.3).
//!
//! The paper shows the LLM judge both the test query and the cached
//! question and asks for a binary "is the cached response valid" verdict.
//! Our workload carries ground-truth cluster ids, so the noise-free
//! verdict is cluster equality; an optional symmetric error rate models
//! judge disagreement (default 0: the reported positive rates then
//! measure the *cache's* accuracy, not the judge's).

use std::sync::Mutex;

use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct JudgeConfig {
    /// Probability the judge flips the true verdict.
    pub error_rate: f64,
    pub seed: u64,
}

impl Default for JudgeConfig {
    fn default() -> Self {
        Self { error_rate: 0.0, seed: 0x0DD5EED }
    }
}

/// Binary verdict provider for cache hits.
pub struct Judge {
    cfg: JudgeConfig,
    rng: Mutex<Rng>,
}

impl Judge {
    pub fn new(cfg: JudgeConfig) -> Self {
        let seed = cfg.seed;
        Self { cfg, rng: Mutex::new(Rng::new(seed)) }
    }

    /// Verdict for a hit: did the cache return a response that answers
    /// the query? Ground truth is cluster equality.
    pub fn validate(&self, query_cluster: u64, cached_cluster: u64) -> bool {
        let truth = query_cluster == cached_cluster;
        if self.cfg.error_rate > 0.0 && self.rng.lock().unwrap().chance(self.cfg.error_rate) {
            !truth
        } else {
            truth
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_judge_is_cluster_equality() {
        let j = Judge::new(JudgeConfig::default());
        assert!(j.validate(5, 5));
        assert!(!j.validate(5, 6));
    }

    #[test]
    fn noisy_judge_flips_at_configured_rate() {
        let j = Judge::new(JudgeConfig { error_rate: 0.25, seed: 7 });
        let mut flips = 0;
        let n = 20_000;
        for i in 0..n {
            if !j.validate(i, i) {
                flips += 1;
            }
        }
        let rate = flips as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.02, "flip rate {rate}");
    }
}
