//! Hierarchical Navigable Small World graphs, from scratch.
//!
//! Follows Malkov & Yashunin (2018) — the algorithm the paper's
//! `hnswlib-node` dependency implements:
//!
//! * geometric level assignment `l = floor(-ln(U) * mL)`, `mL = 1/ln(M)`;
//! * greedy descent through upper layers (ef=1), beam search with
//!   `ef_construction` on insert layers (Alg. 2);
//! * neighbor selection by the pruning heuristic (Alg. 4): a candidate is
//!   kept only if it is closer to the base point than to any already-kept
//!   neighbor — this is what keeps the graph navigable on clustered data;
//! * bidirectional linking with degree cap `M` (`M0 = 2M` on layer 0);
//! * soft deletes (tombstones filtered from results but still traversed),
//!   plus [`HnswIndex::rebuild`] — the paper's periodic "rebalancing";
//! * dynamic growth: no fixed capacity, matching the paper's
//!   "starts with a minimal size and dynamically grows" behaviour.
//!
//! Vectors are stored L2-normalized in one contiguous matrix; similarity
//! is the raw dot product (= cosine). Search scratch (visited epochs +
//! candidate heaps) is pooled per thread so the hot path does not allocate
//! after warm-up (§Perf).

use std::cell::RefCell;
use std::collections::BinaryHeap;
use std::collections::HashMap;
use std::cmp::Reverse;

use super::{quantized_preselect_width, Neighbor, OrdF32, VectorIndex};
use crate::util::{dot, dot_i8, l2_normalized, quantize_i8, SplitMix64};

/// Tunables; defaults follow hnswlib's.
#[derive(Debug, Clone)]
pub struct HnswConfig {
    /// Max out-degree on layers >= 1 (layer 0 uses 2M).
    pub m: usize,
    /// Beam width during construction.
    pub ef_construction: usize,
    /// Beam width during search (clamped up to k).
    pub ef_search: usize,
    /// Level-sampling seed (deterministic builds for tests/benches).
    pub seed: u64,
}

impl Default for HnswConfig {
    fn default() -> Self {
        Self { m: 16, ef_construction: 200, ef_search: 64, seed: 0x9e37_79b9 }
    }
}

/// Version tag of [`HnswIndex::dump`]'s byte format. Bump on any layout
/// change; recovery treats a mismatched version as "re-index from stored
/// embeddings", not an error.
pub const HNSW_DUMP_VERSION: u32 = 1;

struct Node {
    id: u64,
    level: usize,
    deleted: bool,
    /// neighbors[l] = out-edges on layer l (l <= level).
    neighbors: Vec<Vec<u32>>,
}

/// HNSW index over cosine similarity.
///
/// Alongside the exact f32 matrix the index maintains an int8 code
/// matrix (per-node scale; `util::vecmath::quantize_i8`). When built
/// `with_quantized(.., true)`, the *query-time* beam traversal scores
/// candidates through the codes — 4× more vectors per cache line — and
/// the surviving candidate set is exact-reranked in f32 before results
/// are returned, so scores and the top-k ordering stay exact-f32.
/// Graph *construction* always uses exact scores: the edge set of a
/// graph is identical whether or not quantized scanning is enabled,
/// and codes are deterministically re-derived from the f32 vectors on
/// [`HnswIndex::load`] (the dump format is unchanged).
pub struct HnswIndex {
    dim: usize,
    cfg: HnswConfig,
    ml: f64,
    data: Vec<f32>,
    /// Int8 codes, same slot layout as `data`; re-derived, never persisted.
    qdata: Vec<i8>,
    /// Per-slot quantization scales.
    qscales: Vec<f32>,
    nodes: Vec<Node>,
    by_id: HashMap<u64, u32>,
    entry: Option<u32>,
    max_level: usize,
    n_live: usize,
    rng: SplitMix64,
    quantized: bool,
}

/// Per-thread search scratch: epoch-stamped visited marks, reused heaps.
struct Scratch {
    visited: Vec<u32>,
    epoch: u32,
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch { visited: Vec::new(), epoch: 0 });
}

impl HnswIndex {
    pub fn new(dim: usize, cfg: HnswConfig) -> Self {
        Self::with_quantized(dim, cfg, false)
    }

    /// `quantized = true` routes query-time beam scoring through the
    /// int8 code matrix (the `quantized_scan` config key); `false`
    /// keeps the seed exact-f32 traversal.
    pub fn with_quantized(dim: usize, cfg: HnswConfig, quantized: bool) -> Self {
        assert!(dim > 0 && cfg.m >= 2);
        let ml = 1.0 / (cfg.m as f64).ln();
        let rng = SplitMix64::new(cfg.seed);
        Self {
            dim,
            cfg,
            ml,
            data: Vec::new(),
            qdata: Vec::new(),
            qscales: Vec::new(),
            nodes: Vec::new(),
            by_id: HashMap::new(),
            entry: None,
            max_level: 0,
            n_live: 0,
            rng,
            quantized,
        }
    }

    /// Whether query-time traversal uses the quantized scoring path.
    pub fn quantized(&self) -> bool {
        self.quantized
    }

    #[inline]
    fn vec_of(&self, n: u32) -> &[f32] {
        let r = n as usize;
        &self.data[r * self.dim..(r + 1) * self.dim]
    }

    #[inline]
    fn qvec_of(&self, n: u32) -> &[i8] {
        let r = n as usize;
        &self.qdata[r * self.dim..(r + 1) * self.dim]
    }

    #[inline]
    fn sim(&self, n: u32, q: &[f32]) -> f32 {
        dot(self.vec_of(n), q)
    }

    /// Approximate similarity of node `n` against pre-quantized query
    /// codes (`qs` = query scale). Exact 0 for zero vectors, matching
    /// the f32 dot.
    #[inline]
    fn qsim(&self, n: u32, qcodes: &[i8], qs: f32) -> f32 {
        qs * self.qscales[n as usize] * dot_i8(self.qvec_of(n), qcodes) as f32
    }

    /// (Re)derive the int8 codes for `slot` from its f32 vector.
    fn requantize_slot(&mut self, slot: u32) {
        let r = slot as usize;
        let mut codes = Vec::new();
        let scale = quantize_i8(&self.data[r * self.dim..(r + 1) * self.dim], &mut codes);
        if self.qdata.len() < (r + 1) * self.dim {
            self.qdata.resize((r + 1) * self.dim, 0);
        }
        if self.qscales.len() < r + 1 {
            self.qscales.resize(r + 1, 0.0);
        }
        self.qdata[r * self.dim..(r + 1) * self.dim].copy_from_slice(&codes);
        self.qscales[r] = scale;
    }

    fn sample_level(&mut self) -> usize {
        let u = 1.0 - self.rng.next_f64(); // (0, 1]
        ((-u.ln()) * self.ml).floor() as usize
    }

    /// Greedy 1-best descent on one layer (upper-layer routing).
    fn greedy_step(&self, q: &[f32], cur: u32, layer: usize) -> u32 {
        self.greedy_step_by(&|n| self.sim(n, q), cur, layer)
    }

    /// [`greedy_step`](Self::greedy_step) over an arbitrary node scorer
    /// (monomorphized; the quantized path passes the int8 scorer).
    fn greedy_step_by<F: Fn(u32) -> f32>(&self, score: &F, mut cur: u32, layer: usize) -> u32 {
        let mut cur_sim = score(cur);
        loop {
            let mut improved = false;
            for &nb in &self.nodes[cur as usize].neighbors[layer] {
                let s = score(nb);
                if s > cur_sim {
                    cur_sim = s;
                    cur = nb;
                    improved = true;
                }
            }
            if !improved {
                return cur;
            }
        }
    }

    /// Beam search on one layer (Alg. 2). Returns candidates best-first.
    fn search_layer(&self, q: &[f32], entry: u32, ef: usize, layer: usize) -> Vec<(f32, u32)> {
        self.search_layer_by(&|n| self.sim(n, q), entry, ef, layer)
    }

    /// [`search_layer`](Self::search_layer) over an arbitrary node
    /// scorer (monomorphized; the quantized path passes the int8
    /// scorer).
    fn search_layer_by<F: Fn(u32) -> f32>(
        &self,
        score: &F,
        entry: u32,
        ef: usize,
        layer: usize,
    ) -> Vec<(f32, u32)> {
        SCRATCH.with(|s| {
            let mut s = s.borrow_mut();
            if s.visited.len() < self.nodes.len() {
                s.visited.resize(self.nodes.len(), 0);
            }
            s.epoch = s.epoch.wrapping_add(1);
            if s.epoch == 0 {
                s.visited.iter_mut().for_each(|v| *v = 0);
                s.epoch = 1;
            }
            let epoch = s.epoch;

            // candidates: max-heap by sim; results: min-heap of size ef.
            let mut candidates: BinaryHeap<(OrdF32, u32)> = BinaryHeap::new();
            let mut results: BinaryHeap<Reverse<(OrdF32, u32)>> = BinaryHeap::new();
            let e_sim = score(entry);
            s.visited[entry as usize] = epoch;
            candidates.push((OrdF32(e_sim), entry));
            results.push(Reverse((OrdF32(e_sim), entry)));

            while let Some((OrdF32(c_sim), c)) = candidates.pop() {
                let worst = results.peek().map(|Reverse((OrdF32(s), _))| *s).unwrap_or(f32::MIN);
                if c_sim < worst && results.len() >= ef {
                    break;
                }
                for &nb in &self.nodes[c as usize].neighbors[layer] {
                    if s.visited[nb as usize] == epoch {
                        continue;
                    }
                    s.visited[nb as usize] = epoch;
                    let nb_sim = score(nb);
                    let worst = results.peek().map(|Reverse((OrdF32(s), _))| *s).unwrap_or(f32::MIN);
                    if results.len() < ef || nb_sim > worst {
                        candidates.push((OrdF32(nb_sim), nb));
                        results.push(Reverse((OrdF32(nb_sim), nb)));
                        if results.len() > ef {
                            results.pop();
                        }
                    }
                }
            }
            let mut out: Vec<(f32, u32)> =
                results.into_iter().map(|Reverse((OrdF32(s), n))| (s, n)).collect();
            out.sort_by(|a, b| b.0.total_cmp(&a.0));
            out
        })
    }

    /// Neighbor-selection heuristic (Alg. 4): keep a candidate only if it
    /// is more similar to the base than to every already-selected
    /// neighbor; this avoids redundant clustered edges.
    fn select_neighbors(&self, candidates: &[(f32, u32)], m: usize) -> Vec<u32> {
        let mut selected: Vec<u32> = Vec::with_capacity(m);
        for &(base_sim, cand) in candidates {
            if selected.len() >= m {
                break;
            }
            let cand_vec = self.vec_of(cand);
            let dominated = selected
                .iter()
                .any(|&s| dot(self.vec_of(s), cand_vec) > base_sim);
            if !dominated {
                selected.push(cand);
            }
        }
        // Back-fill with closest skipped candidates if the heuristic was
        // too aggressive (hnswlib's keepPrunedConnections behaviour).
        if selected.len() < m {
            for &(_, cand) in candidates {
                if selected.len() >= m {
                    break;
                }
                if !selected.contains(&cand) {
                    selected.push(cand);
                }
            }
        }
        selected
    }

    /// Cap `node`'s layer-`layer` adjacency to `m` using the heuristic.
    fn shrink_links(&mut self, node: u32, layer: usize, m: usize) {
        let links = self.nodes[node as usize].neighbors[layer].clone();
        if links.len() <= m {
            return;
        }
        let nv = self.vec_of(node).to_vec();
        let mut scored: Vec<(f32, u32)> =
            links.iter().map(|&nb| (dot(self.vec_of(nb), &nv), nb)).collect();
        scored.sort_by(|a, b| b.0.total_cmp(&a.0));
        let kept = self.select_neighbors(&scored, m);
        self.nodes[node as usize].neighbors[layer] = kept;
    }

    /// The paper's "periodic rebalancing": rebuild the graph from live
    /// entries only, reclaiming tombstones and restoring link quality.
    pub fn rebuild(&mut self) {
        let mut pairs: Vec<(u64, Vec<f32>)> = Vec::with_capacity(self.n_live);
        for n in &self.nodes {
            if !n.deleted {
                pairs.push((n.id, self.vec_of(self.by_id[&n.id]).to_vec()));
            }
        }
        let mut fresh = HnswIndex::with_quantized(self.dim, self.cfg.clone(), self.quantized);
        for (id, v) in pairs {
            fresh.insert_normalized(id, v);
        }
        *self = fresh;
    }

    /// Fraction of tombstoned nodes (rebuild trigger input).
    pub fn garbage_ratio(&self) -> f64 {
        if self.nodes.is_empty() {
            0.0
        } else {
            1.0 - self.n_live as f64 / self.nodes.len() as f64
        }
    }

    /// Total node slots including tombstones.
    pub fn slots(&self) -> usize {
        self.nodes.len()
    }

    pub fn config(&self) -> &HnswConfig {
        &self.cfg
    }

    /// Search with an explicit beam width (the `ef` knob exposed for the
    /// recall/latency trade-off bench).
    pub fn search_ef(&self, query: &[f32], k: usize, ef: usize) -> Vec<Neighbor> {
        assert_eq!(query.len(), self.dim, "dimension mismatch");
        let Some(mut cur) = self.entry else { return Vec::new() };
        if k == 0 {
            return Vec::new();
        }
        let q = l2_normalized(query);
        if self.quantized && !crate::util::scalar_kernels_forced() {
            // Quantized traversal: score the descent and the layer-0
            // beam through the int8 code matrix, then exact-rerank the
            // surviving candidates in f32. The beam is widened to the
            // preselect width so quantization noise near the cut line
            // cannot evict true top-k candidates; returned scores are
            // exact f32 dots either way.
            let mut qcodes = Vec::new();
            let qs = quantize_i8(&q, &mut qcodes);
            let score = |n: u32| self.qsim(n, &qcodes, qs);
            for layer in (1..=self.max_level).rev() {
                cur = self.greedy_step_by(&score, cur, layer);
            }
            let ef = ef.max(k).max(quantized_preselect_width(k)).max(1);
            let found = self.search_layer_by(&score, cur, ef, 0);
            let mut out: Vec<Neighbor> = found
                .iter()
                .filter(|&&(_, n)| !self.nodes[n as usize].deleted)
                .map(|&(_, n)| Neighbor {
                    id: self.nodes[n as usize].id,
                    score: self.sim(n, &q),
                })
                .collect();
            out.sort_by(|a, b| b.score.total_cmp(&a.score));
            out.truncate(k);
            return out;
        }
        for layer in (1..=self.max_level).rev() {
            cur = self.greedy_step(&q, cur, layer);
        }
        let ef = ef.max(k);
        let found = self.search_layer(&q, cur, ef.max(1), 0);
        let mut out = Vec::with_capacity(k);
        for (s, n) in found {
            if !self.nodes[n as usize].deleted {
                out.push(Neighbor { id: self.nodes[n as usize].id, score: s });
                if out.len() == k {
                    break;
                }
            }
        }
        out
    }

    /// Exhaustive exact scan over live nodes — the last-resort
    /// fallback when beam widening cannot surface `k` live results
    /// (e.g. live islands unreachable through a tombstone-saturated
    /// neighborhood). O(n), but only ever taken on pathological graphs.
    fn exhaustive_search(&self, q: &[f32], k: usize) -> Vec<Neighbor> {
        let mut scored: Vec<Neighbor> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| !n.deleted)
            .map(|(slot, n)| Neighbor { id: n.id, score: self.sim(slot as u32, q) })
            .collect();
        scored.sort_by(|a, b| b.score.total_cmp(&a.score));
        scored.truncate(k);
        scored
    }

    /// Serialize the full graph (vectors, adjacency, tombstones, entry
    /// point, level-sampler state) into `buf`. A graph loaded from this
    /// dump is bit-identical to the original for every `search_ef` call:
    /// stored vectors keep their exact bit patterns and the adjacency
    /// arrays are preserved verbatim, so traversal order cannot differ.
    pub fn dump(&self, buf: &mut Vec<u8>) {
        use crate::persist::codec::*;
        put_u32(buf, HNSW_DUMP_VERSION);
        put_u64(buf, self.dim as u64);
        put_u64(buf, self.cfg.m as u64);
        put_u64(buf, self.cfg.ef_construction as u64);
        put_u64(buf, self.cfg.ef_search as u64);
        put_u64(buf, self.cfg.seed);
        put_u64(buf, self.rng.state());
        put_u32(buf, self.max_level as u32);
        match self.entry {
            Some(e) => {
                put_u8(buf, 1);
                put_u32(buf, e);
            }
            None => {
                put_u8(buf, 0);
                put_u32(buf, 0);
            }
        }
        put_u32(buf, self.nodes.len() as u32);
        for n in &self.nodes {
            put_u64(buf, n.id);
            put_u32(buf, n.level as u32);
            put_u8(buf, n.deleted as u8);
            for layer in &n.neighbors {
                put_u32(buf, layer.len() as u32);
                for &nb in layer {
                    put_u32(buf, nb);
                }
            }
        }
        put_f32s(buf, &self.data);
    }

    /// Deserialize a graph produced by [`HnswIndex::dump`].
    ///
    /// Every structural invariant the search path relies on is validated
    /// here (neighbor slots in range, adjacency only between layers both
    /// endpoints reach, entry node owns the top level, vector matrix
    /// sized `nodes * dim`) — a corrupt or version-skewed dump returns
    /// `Err` and the recovery path falls back to re-indexing from stored
    /// embeddings; it never loads a graph that could panic a search.
    pub fn load(bytes: &[u8]) -> Result<HnswIndex, crate::persist::codec::DecodeError> {
        use crate::persist::codec::{DecodeError, Reader};
        let fail = |m: &str| DecodeError(format!("hnsw dump: {m}"));
        let mut r = Reader::new(bytes);
        let version = r.u32()?;
        if version != HNSW_DUMP_VERSION {
            return Err(fail(&format!(
                "graph version {version} != supported {HNSW_DUMP_VERSION}"
            )));
        }
        let dim = r.u64()? as usize;
        let cfg = HnswConfig {
            m: r.u64()? as usize,
            ef_construction: r.u64()? as usize,
            ef_search: r.u64()? as usize,
            seed: r.u64()?,
        };
        let rng_state = r.u64()?;
        let max_level = r.u32()? as usize;
        let has_entry = r.u8()? != 0;
        let entry_slot = r.u32()?;
        if dim == 0 || cfg.m < 2 {
            return Err(fail("invalid dim/M"));
        }
        if max_level > 64 {
            return Err(fail("implausible max_level"));
        }
        let n_nodes = r.list_len(13)?; // id(8) + level(4) + deleted(1)
        let mut nodes = Vec::with_capacity(n_nodes);
        let mut by_id = HashMap::with_capacity(n_nodes);
        let mut n_live = 0usize;
        for slot in 0..n_nodes {
            let id = r.u64()?;
            let level = r.u32()? as usize;
            if level > max_level {
                return Err(fail("node level above max_level"));
            }
            let deleted = r.u8()? != 0;
            let mut neighbors = Vec::with_capacity(level + 1);
            for _ in 0..=level {
                let cnt = r.list_len(4)?;
                let mut layer = Vec::with_capacity(cnt);
                for _ in 0..cnt {
                    let nb = r.u32()?;
                    if nb as usize >= n_nodes {
                        return Err(fail("neighbor slot out of range"));
                    }
                    layer.push(nb);
                }
                neighbors.push(layer);
            }
            if by_id.insert(id, slot as u32).is_some() {
                return Err(fail("duplicate node id"));
            }
            if !deleted {
                n_live += 1;
            }
            nodes.push(Node { id, level, deleted, neighbors });
        }
        // Cross-node invariant: an edge to `nb` on layer l is only legal
        // if `nb` itself reaches layer l (greedy descent dereferences
        // nb.neighbors[l]).
        for n in &nodes {
            for (l, layer) in n.neighbors.iter().enumerate() {
                for &nb in layer {
                    if nodes[nb as usize].level < l {
                        return Err(fail("edge to node below its layer"));
                    }
                }
            }
        }
        let entry = if has_entry {
            if entry_slot as usize >= n_nodes {
                return Err(fail("entry slot out of range"));
            }
            if nodes[entry_slot as usize].level != max_level {
                return Err(fail("entry node does not own max_level"));
            }
            Some(entry_slot)
        } else {
            if n_nodes > 0 {
                return Err(fail("non-empty graph without an entry point"));
            }
            None
        };
        let data = r.f32s()?;
        if data.len() != n_nodes * dim {
            return Err(fail("vector matrix size mismatch"));
        }
        // Re-derive the int8 codes from the exact dumped vectors:
        // quantization is a pure function of the f32 data, so a loaded
        // graph scores identically to the pre-dump original and the
        // dump format stays at version 1.
        let mut qdata = Vec::with_capacity(data.len());
        let mut qscales = Vec::with_capacity(n_nodes);
        let mut codes = Vec::new();
        for slot in 0..n_nodes {
            qscales.push(quantize_i8(&data[slot * dim..(slot + 1) * dim], &mut codes));
            qdata.extend_from_slice(&codes);
        }
        let ml = 1.0 / (cfg.m as f64).ln();
        Ok(HnswIndex {
            dim,
            cfg,
            ml,
            data,
            qdata,
            qscales,
            nodes,
            by_id,
            entry,
            max_level,
            n_live,
            rng: SplitMix64::from_state(rng_state),
            quantized: false,
        })
    }

    /// Enable/disable the quantized query path on a loaded graph
    /// (snapshot recovery re-applies the `quantized_scan` config after
    /// [`HnswIndex::load`], which defaults to the exact path).
    pub fn set_quantized(&mut self, on: bool) {
        self.quantized = on;
    }

    fn insert_normalized(&mut self, id: u64, v: Vec<f32>) {
        if let Some(&slot) = self.by_id.get(&id) {
            // Overwrite: update vector in place, revive if tombstoned.
            self.data[slot as usize * self.dim..(slot as usize + 1) * self.dim]
                .copy_from_slice(&v);
            self.requantize_slot(slot);
            if self.nodes[slot as usize].deleted {
                self.nodes[slot as usize].deleted = false;
                self.n_live += 1;
            }
            return;
        }
        let level = self.sample_level();
        let slot = self.nodes.len() as u32;
        self.data.extend_from_slice(&v);
        self.requantize_slot(slot);
        self.nodes.push(Node {
            id,
            level,
            deleted: false,
            neighbors: (0..=level).map(|_| Vec::new()).collect(),
        });
        self.by_id.insert(id, slot);
        self.n_live += 1;

        let Some(mut cur) = self.entry else {
            self.entry = Some(slot);
            self.max_level = level;
            return;
        };

        // Route down from the top to level+1 greedily.
        for layer in ((level + 1)..=self.max_level).rev() {
            cur = self.greedy_step(&v, cur, layer);
        }

        // Connect on layers min(level, max_level)..0.
        let m = self.cfg.m;
        for layer in (0..=level.min(self.max_level)).rev() {
            let found = self.search_layer(&v, cur, self.cfg.ef_construction, layer);
            cur = found.first().map(|&(_, n)| n).unwrap_or(cur);
            let m_layer = if layer == 0 { 2 * m } else { m };
            let selected = self.select_neighbors(&found, m);
            self.nodes[slot as usize].neighbors[layer] = selected.clone();
            for nb in selected {
                self.nodes[nb as usize].neighbors[layer].push(slot);
                if self.nodes[nb as usize].neighbors[layer].len() > m_layer {
                    self.shrink_links(nb, layer, m_layer);
                }
            }
        }

        if level > self.max_level {
            self.max_level = level;
            self.entry = Some(slot);
        }
    }
}

impl VectorIndex for HnswIndex {
    fn insert(&mut self, id: u64, vec: &[f32]) {
        assert_eq!(vec.len(), self.dim, "dimension mismatch");
        self.insert_normalized(id, l2_normalized(vec));
    }

    fn remove(&mut self, id: u64) -> bool {
        match self.by_id.get(&id) {
            Some(&slot) if !self.nodes[slot as usize].deleted => {
                self.nodes[slot as usize].deleted = true;
                self.n_live -= 1;
                true
            }
            _ => false,
        }
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Neighbor> {
        // Widen the beam when many tombstones may hide results. The
        // static widening is capped, so it alone cannot guarantee
        // coverage on tombstone-saturated graphs — and quantized
        // approximation error must not compound with that shrinkage.
        // Contract: whenever >= min(k, n_live) live nodes exist, the
        // candidate set handed to the exact rerank is at least that
        // large. Enforced by doubling ef until the beam covers the
        // graph, then falling back to an exhaustive live scan (live
        // islands can be unreachable no matter how wide the beam).
        let tombstones = self.nodes.len() - self.n_live;
        // `.max(1)` keeps the doubling below progressing even under a
        // pathological `ef_search = 0` config.
        let mut ef = (self.cfg.ef_search + 2 * tombstones.min(64)).max(1);
        let want = k.min(self.n_live);
        let mut out = self.search_ef(query, k, ef);
        while out.len() < want && ef < self.nodes.len() {
            ef = (ef * 2).min(self.nodes.len());
            out = self.search_ef(query, k, ef);
        }
        if out.len() < want {
            out = self.exhaustive_search(&l2_normalized(query), k);
        }
        out
    }

    fn len(&self) -> usize {
        self.n_live
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn slots(&self) -> usize {
        self.nodes.len()
    }

    fn is_hnsw(&self) -> bool {
        true
    }

    fn hnsw_config(&self) -> Option<&HnswConfig> {
        Some(&self.cfg)
    }

    fn dump_graph(&self) -> Option<Vec<u8>> {
        let mut buf = Vec::new();
        self.dump(&mut buf);
        Some(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::FlatIndex;
    use crate::util::Rng;

    fn random_vec(rng: &mut Rng, dim: usize) -> Vec<f32> {
        (0..dim).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
    }

    /// Recall@10 vs the flat oracle must be high on random data.
    #[test]
    fn recall_against_flat() {
        let dim = 24;
        let n = 2_000;
        let mut rng = Rng::new(7);
        let mut hnsw = HnswIndex::new(dim, HnswConfig::default());
        let mut flat = FlatIndex::new(dim);
        for id in 0..n as u64 {
            let v = random_vec(&mut rng, dim);
            hnsw.insert(id, &v);
            flat.insert(id, &v);
        }
        let mut hits = 0usize;
        let queries = 50;
        for _ in 0..queries {
            let q = random_vec(&mut rng, dim);
            let truth: Vec<u64> = flat.search(&q, 10).iter().map(|n| n.id).collect();
            let got: Vec<u64> = hnsw.search(&q, 10).iter().map(|n| n.id).collect();
            hits += got.iter().filter(|id| truth.contains(id)).count();
        }
        let recall = hits as f64 / (10 * queries) as f64;
        assert!(recall > 0.9, "recall@10 = {recall}");
    }

    #[test]
    fn clustered_data_finds_cluster_center() {
        let dim = 16;
        let mut rng = Rng::new(3);
        let mut hnsw = HnswIndex::new(dim, HnswConfig::default());
        // 20 clusters of 100 points.
        let centers: Vec<Vec<f32>> = (0..20).map(|_| random_vec(&mut rng, dim)).collect();
        for id in 0..2_000u64 {
            let c = &centers[(id / 100) as usize];
            let v: Vec<f32> =
                c.iter().map(|x| x + rng.range_f64(-0.05, 0.05) as f32).collect();
            hnsw.insert(id, &v);
        }
        for (ci, c) in centers.iter().enumerate() {
            let res = hnsw.search(c, 5);
            for n in res {
                assert_eq!(
                    (n.id / 100) as usize,
                    ci,
                    "neighbor from wrong cluster (id {})",
                    n.id
                );
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let build = || {
            let mut idx = HnswIndex::new(8, HnswConfig::default());
            let mut rng = Rng::new(5);
            for id in 0..500u64 {
                idx.insert(id, &random_vec(&mut rng, 8));
            }
            let q = random_vec(&mut rng, 8);
            idx.search(&q, 5).iter().map(|n| n.id).collect::<Vec<_>>()
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn rebuild_reclaims_tombstones_and_preserves_results() {
        let mut rng = Rng::new(11);
        let mut idx = HnswIndex::new(12, HnswConfig::default());
        let mut vecs = Vec::new();
        for id in 0..600u64 {
            let v = random_vec(&mut rng, 12);
            idx.insert(id, &v);
            vecs.push(v);
        }
        for id in 300..600u64 {
            idx.remove(id);
        }
        assert!(idx.garbage_ratio() > 0.49);
        let q = &vecs[17];
        let before: Vec<u64> = idx.search(q, 5).iter().map(|n| n.id).collect();
        idx.rebuild();
        assert_eq!(idx.garbage_ratio(), 0.0);
        assert_eq!(idx.len(), 300);
        assert_eq!(idx.slots(), 300);
        let after: Vec<u64> = idx.search(q, 5).iter().map(|n| n.id).collect();
        assert_eq!(before[0], after[0], "nearest neighbor preserved across rebuild");
        assert!(after.iter().all(|&id| id < 300));
    }

    #[test]
    fn deleted_entries_never_returned_even_all_deleted() {
        let mut idx = HnswIndex::new(8, HnswConfig::default());
        let mut rng = Rng::new(2);
        for id in 0..50u64 {
            idx.insert(id, &random_vec(&mut rng, 8));
        }
        for id in 0..50u64 {
            idx.remove(id);
        }
        assert!(idx.search(&random_vec(&mut rng, 8), 5).is_empty());
    }

    #[test]
    fn single_element_and_empty() {
        let mut idx = HnswIndex::new(4, HnswConfig::default());
        assert!(idx.search(&[1.0, 0.0, 0.0, 0.0], 3).is_empty());
        idx.insert(9, &[1.0, 0.0, 0.0, 0.0]);
        let r = idx.search(&[1.0, 0.0, 0.0, 0.0], 3);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, 9);
    }

    #[test]
    fn dump_load_search_parity_with_tombstones() {
        // A loaded graph must return bit-identical search_ef results —
        // same ids, same score bit patterns — including on graphs that
        // carry tombstones (deleted nodes are serialized, not elided).
        let dim = 16;
        let mut rng = Rng::new(21);
        let mut idx = HnswIndex::new(dim, HnswConfig::default());
        for id in 0..800u64 {
            idx.insert(id, &random_vec(&mut rng, dim));
        }
        for id in (0..800u64).step_by(3) {
            idx.remove(id);
        }
        let mut buf = Vec::new();
        idx.dump(&mut buf);
        let loaded = HnswIndex::load(&buf).expect("dump must load");
        assert_eq!(loaded.len(), idx.len());
        assert_eq!(loaded.slots(), idx.slots());
        assert_eq!(loaded.garbage_ratio(), idx.garbage_ratio());
        for _ in 0..40 {
            let q = random_vec(&mut rng, dim);
            for &(k, ef) in &[(1usize, 8usize), (5, 32), (10, 128)] {
                let a = idx.search_ef(&q, k, ef);
                let b = loaded.search_ef(&q, k, ef);
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.id, y.id, "neighbor ids diverge after load");
                    assert_eq!(
                        x.score.to_bits(),
                        y.score.to_bits(),
                        "scores must be bit-identical after load"
                    );
                }
            }
        }
        // The level sampler resumes where it left off: identical inserts
        // into both graphs keep them in lock-step.
        let mut idx = idx;
        let mut loaded = loaded;
        let v = random_vec(&mut rng, dim);
        idx.insert(9_000, &v);
        loaded.insert(9_000, &v);
        let q = random_vec(&mut rng, dim);
        let a: Vec<u64> = idx.search_ef(&q, 10, 64).iter().map(|n| n.id).collect();
        let b: Vec<u64> = loaded.search_ef(&q, 10, 64).iter().map(|n| n.id).collect();
        assert_eq!(a, b, "post-load inserts diverged");
    }

    #[test]
    fn rebuild_after_load_reclaims_tombstones() {
        let mut rng = Rng::new(33);
        let mut idx = HnswIndex::new(12, HnswConfig::default());
        for id in 0..400u64 {
            idx.insert(id, &random_vec(&mut rng, 12));
        }
        for id in 200..400u64 {
            idx.remove(id);
        }
        let mut buf = Vec::new();
        idx.dump(&mut buf);
        let mut loaded = HnswIndex::load(&buf).unwrap();
        assert!(loaded.garbage_ratio() > 0.49, "tombstones survive the dump");
        loaded.rebuild();
        assert_eq!(loaded.garbage_ratio(), 0.0);
        assert_eq!(loaded.len(), 200);
        assert_eq!(loaded.slots(), 200, "rebuild after load reclaims tombstones");
        let q = random_vec(&mut rng, 12);
        assert!(loaded.search(&q, 5).iter().all(|n| n.id < 200));
    }

    #[test]
    fn load_rejects_corrupt_dumps() {
        let mut rng = Rng::new(44);
        let mut idx = HnswIndex::new(8, HnswConfig::default());
        for id in 0..60u64 {
            idx.insert(id, &random_vec(&mut rng, 8));
        }
        let mut buf = Vec::new();
        idx.dump(&mut buf);
        // Version skew -> Err (the re-index fallback trigger).
        let mut skew = buf.clone();
        skew[0] ^= 0xFF;
        assert!(HnswIndex::load(&skew).is_err());
        // Truncations at every prefix length must error, never panic.
        for cut in 0..buf.len().min(200) {
            assert!(HnswIndex::load(&buf[..cut]).is_err());
        }
        assert!(HnswIndex::load(&buf[..buf.len() - 3]).is_err());
        // A loaded-then-validated graph must round-trip.
        assert!(HnswIndex::load(&buf).is_ok());
    }

    #[test]
    fn tombstone_heavy_search_returns_every_live_node() {
        // Directed regression for the beam-widening bug: the static
        // widening (ef_search + 2 * tombstones.min(64)) is capped, so a
        // graph with thousands of tombstones hiding a handful of live
        // nodes could return fewer than min(k, n_live) results — and
        // quantized approximation error must not compound with that.
        // Contract: >= min(k, n_live) results whenever that many live
        // nodes exist.
        for quantized in [false, true] {
            let dim = 16;
            let n = 2_000u64;
            let mut rng = Rng::new(77);
            let mut idx = HnswIndex::with_quantized(dim, HnswConfig::default(), quantized);
            let mut vecs = Vec::new();
            for id in 0..n {
                let v = random_vec(&mut rng, dim);
                idx.insert(id, &v);
                vecs.push(v);
            }
            // Keep 12 scattered survivors; everything else tombstones.
            let live: Vec<u64> = (0..12).map(|i| i * 167).collect();
            for id in 0..n {
                if !live.contains(&id) {
                    idx.remove(id);
                }
            }
            assert_eq!(idx.len(), 12);
            for qi in 0..10 {
                let q = &vecs[(qi * 191) as usize];
                let res = idx.search(q, 12);
                assert_eq!(
                    res.len(),
                    12,
                    "quantized={quantized}: search must surface all live nodes"
                );
                let mut got: Vec<u64> = res.iter().map(|n| n.id).collect();
                got.sort_unstable();
                assert_eq!(got, live, "quantized={quantized}: wrong live set");
                for w in res.windows(2) {
                    assert!(w[0].score >= w[1].score);
                }
                // A smaller k still fills up.
                assert_eq!(idx.search(q, 5).len(), 5);
            }
        }
    }

    #[test]
    fn quantized_search_matches_exact_graph() {
        // Construction is always exact, so the exact and quantized
        // graphs are structurally identical; the quantized query path
        // must (a) return exact f32 scores and (b) track the exact
        // path's results closely.
        let dim = 24;
        let mut rng = Rng::new(55);
        let mut exact = HnswIndex::new(dim, HnswConfig::default());
        let mut quant = HnswIndex::with_quantized(dim, HnswConfig::default(), true);
        for id in 0..2_000u64 {
            let v = random_vec(&mut rng, dim);
            exact.insert(id, &v);
            quant.insert(id, &v);
        }
        let mut overlap = 0usize;
        let queries = 40;
        for _ in 0..queries {
            let q = random_vec(&mut rng, dim);
            let a = exact.search(&q, 10);
            let b = quant.search(&q, 10);
            assert_eq!(b.len(), 10);
            let truth: Vec<u64> = a.iter().map(|n| n.id).collect();
            for nb in &b {
                if truth.contains(&nb.id) {
                    overlap += 1;
                    // Shared ids must carry the identical exact score.
                    let sa = a.iter().find(|x| x.id == nb.id).unwrap().score;
                    assert_eq!(sa.to_bits(), nb.score.to_bits(), "rerank must be exact f32");
                }
            }
        }
        let agreement = overlap as f64 / (10 * queries) as f64;
        assert!(agreement > 0.9, "quantized-vs-exact top-10 agreement = {agreement}");
    }

    #[test]
    fn quantized_dump_load_search_parity() {
        // Codes are re-derived from the exact dumped f32 vectors, so a
        // loaded quantized graph must search bit-identically to the
        // original (same dump format version as exact graphs).
        let dim = 16;
        let mut rng = Rng::new(66);
        let mut idx = HnswIndex::with_quantized(dim, HnswConfig::default(), true);
        for id in 0..600u64 {
            idx.insert(id, &random_vec(&mut rng, dim));
        }
        for id in (0..600u64).step_by(4) {
            idx.remove(id);
        }
        let mut buf = Vec::new();
        idx.dump(&mut buf);
        let mut loaded = HnswIndex::load(&buf).expect("dump must load");
        assert!(!loaded.quantized(), "load defaults to the exact path");
        loaded.set_quantized(true);
        for _ in 0..25 {
            let q = random_vec(&mut rng, dim);
            let a = idx.search(&q, 7);
            let b = loaded.search(&q, 7);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.score.to_bits(), y.score.to_bits());
            }
        }
    }

    #[test]
    fn ef_search_trades_recall() {
        // ef=4 must not beat ef=128 in recall on the same data.
        let dim = 24;
        let mut rng = Rng::new(13);
        let mut hnsw = HnswIndex::new(dim, HnswConfig::default());
        let mut flat = FlatIndex::new(dim);
        for id in 0..3_000u64 {
            let v = random_vec(&mut rng, dim);
            hnsw.insert(id, &v);
            flat.insert(id, &v);
        }
        let mut recall_at = |ef: usize| {
            let mut rng = Rng::new(99);
            let mut hits = 0;
            for _ in 0..40 {
                let q = random_vec(&mut rng, dim);
                let truth: Vec<u64> = flat.search(&q, 10).iter().map(|n| n.id).collect();
                let got = hnsw.search_ef(&q, 10, ef);
                hits += got.iter().filter(|n| truth.contains(&n.id)).count();
            }
            hits as f64 / 400.0
        };
        let lo = recall_at(10);
        let hi = recall_at(128);
        assert!(hi >= lo, "recall(128)={hi} < recall(10)={lo}");
        assert!(hi > 0.93, "recall(128)={hi}");
    }
}
