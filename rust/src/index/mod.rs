//! Vector indexes for similarity search (paper §2.4).
//!
//! Two implementations of [`VectorIndex`]:
//!
//! * [`FlatIndex`] — exhaustive O(n) scan, the paper's complexity baseline
//!   and the ground truth for recall measurements;
//! * [`HnswIndex`] — Hierarchical Navigable Small World graphs (Malkov &
//!   Yashunin 2018), the paper's production index, built from scratch:
//!   geometric level sampling, beam (`ef`) search, the neighbor-selection
//!   heuristic, bidirectional link pruning, soft deletes, dynamic growth
//!   and periodic rebuild ("rebalancing" in the paper).
//!
//! All indexes store L2-normalized vectors, so cosine similarity reduces
//! to a dot product on the hot path ([`crate::util::dot`]).

mod flat;
mod hnsw;

pub use flat::FlatIndex;
pub use hnsw::{HnswConfig, HnswIndex, HNSW_DUMP_VERSION};

/// A search result: entry id and cosine similarity (descending order).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub id: u64,
    pub score: f32,
}

/// Common interface over flat and HNSW indexes. Vectors are copied in and
/// normalized on insert; ids are caller-assigned and must be unique.
///
/// `Send + Sync` so a partition can share one index behind a `RwLock`
/// and serve concurrent `search` calls under the shared lock (HNSW's
/// per-thread scratch keeps `&self` searches race-free).
pub trait VectorIndex: Send + Sync {
    /// Insert a vector under `id`. Panics if `vec.len() != dim`.
    fn insert(&mut self, id: u64, vec: &[f32]);
    /// Soft-remove an id; returns whether it was present.
    fn remove(&mut self, id: u64) -> bool;
    /// Top-k most cosine-similar live entries, best first.
    fn search(&self, query: &[f32], k: usize) -> Vec<Neighbor>;
    /// Number of live entries.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Vector dimensionality.
    fn dim(&self) -> usize;
    /// Total slots including tombstones (>= `len`). Feeds the garbage
    /// ratio that triggers the periodic rebuild.
    fn slots(&self) -> usize {
        self.len()
    }
    /// True for HNSW-backed indexes (used by partition rebuilds to
    /// recreate the same index kind).
    fn is_hnsw(&self) -> bool {
        false
    }
    /// HNSW tunables when applicable.
    fn hnsw_config(&self) -> Option<&HnswConfig> {
        None
    }
    /// Serialized graph bytes for snapshotting ([`HnswIndex::dump`]);
    /// `None` for indexes that are cheap to rebuild from raw vectors
    /// (flat scan), which snapshots restore by re-inserting embeddings.
    fn dump_graph(&self) -> Option<Vec<u8>> {
        None
    }
}

/// Candidate-set width for the quantized preselect stage: the int8
/// scan keeps `max(4k, 32)` rows for the exact f32 rerank, absorbing
/// quantization-induced rank swaps near the cut line. Shared by both
/// indexes so the recall floor is measured against one contract.
pub(crate) fn quantized_preselect_width(k: usize) -> usize {
    (4 * k).max(32)
}

/// Max-heap ordering helper for f32 scores (NaN-free by construction).
#[derive(PartialEq)]
pub(crate) struct OrdF32(pub f32);

impl Eq for OrdF32 {}
impl PartialOrd for OrdF32 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF32 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Shared conformance suite run against both implementations.
    fn conformance(mut idx: Box<dyn VectorIndex>) {
        let dim = idx.dim();
        let mut rng = Rng::new(42);
        let mut vecs = Vec::new();
        for id in 0..200u64 {
            let v: Vec<f32> = (0..dim).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
            idx.insert(id, &v);
            vecs.push(v);
        }
        assert_eq!(idx.len(), 200);

        // Self-query returns self with similarity ~1.
        for id in [0u64, 57, 199] {
            let res = idx.search(&vecs[id as usize], 1);
            assert_eq!(res[0].id, id, "self-query must return self");
            assert!(res[0].score > 0.999);
        }

        // Results are sorted descending and k-bounded.
        let res = idx.search(&vecs[3], 10);
        assert_eq!(res.len(), 10);
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }

        // Remove hides an entry.
        assert!(idx.remove(3));
        assert!(!idx.remove(3));
        let res = idx.search(&vecs[3], 5);
        assert!(res.iter().all(|n| n.id != 3));
        assert_eq!(idx.len(), 199);

        // k > len clamps.
        let res = idx.search(&vecs[5], 500);
        assert_eq!(res.len(), 199);
    }

    #[test]
    fn flat_conformance() {
        conformance(Box::new(FlatIndex::new(32)));
    }

    #[test]
    fn flat_quantized_conformance() {
        conformance(Box::new(FlatIndex::with_quantized(32, true)));
    }

    #[test]
    fn hnsw_conformance() {
        conformance(Box::new(HnswIndex::new(32, HnswConfig::default())));
    }

    #[test]
    fn hnsw_quantized_conformance() {
        conformance(Box::new(HnswIndex::with_quantized(32, HnswConfig::default(), true)));
    }

    #[test]
    fn ordf32_total_order() {
        let mut v = vec![OrdF32(0.5), OrdF32(-1.0), OrdF32(2.0)];
        v.sort();
        assert_eq!(v[0].0, -1.0);
        assert_eq!(v[2].0, 2.0);
    }
}
