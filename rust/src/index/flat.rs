//! Exhaustive-scan index — the paper's O(n) baseline (§2.4) and the
//! ground-truth oracle for HNSW recall tests.

use std::collections::BinaryHeap;
use std::collections::HashMap;

use super::{Neighbor, OrdF32, VectorIndex};
use crate::util::{dot, l2_normalized};

/// Flat (brute-force) cosine index. Vectors live in one contiguous
/// row-major matrix for scan locality; removals tombstone the row and
/// `compact()` reclaims it.
pub struct FlatIndex {
    dim: usize,
    data: Vec<f32>,
    ids: Vec<u64>,
    live: Vec<bool>,
    by_id: HashMap<u64, usize>,
    n_live: usize,
}

impl FlatIndex {
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0);
        Self { dim, data: Vec::new(), ids: Vec::new(), live: Vec::new(), by_id: HashMap::new(), n_live: 0 }
    }

    /// Row slice for internal row index.
    #[inline]
    fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.dim..(r + 1) * self.dim]
    }

    /// Fraction of tombstoned rows.
    pub fn garbage_ratio(&self) -> f64 {
        if self.ids.is_empty() {
            0.0
        } else {
            1.0 - self.n_live as f64 / self.ids.len() as f64
        }
    }

    /// Rebuild the matrix without tombstones.
    pub fn compact(&mut self) {
        let mut data = Vec::with_capacity(self.n_live * self.dim);
        let mut ids = Vec::with_capacity(self.n_live);
        for r in 0..self.ids.len() {
            if self.live[r] {
                data.extend_from_slice(self.row(r));
                ids.push(self.ids[r]);
            }
        }
        self.by_id = ids.iter().enumerate().map(|(r, &id)| (id, r)).collect();
        self.live = vec![true; ids.len()];
        self.data = data;
        self.ids = ids;
    }

    /// Score every live row against `query` (normalized internally) —
    /// used by benches to compare against the PJRT scorer artifact.
    pub fn score_all(&self, query: &[f32]) -> Vec<Neighbor> {
        let q = l2_normalized(query);
        (0..self.ids.len())
            .filter(|&r| self.live[r])
            .map(|r| Neighbor { id: self.ids[r], score: dot(&q, self.row(r)) })
            .collect()
    }
}

impl VectorIndex for FlatIndex {
    fn insert(&mut self, id: u64, vec: &[f32]) {
        assert_eq!(vec.len(), self.dim, "dimension mismatch");
        if let Some(&r) = self.by_id.get(&id) {
            // Overwrite in place.
            let normalized = l2_normalized(vec);
            self.data[r * self.dim..(r + 1) * self.dim].copy_from_slice(&normalized);
            if !self.live[r] {
                self.live[r] = true;
                self.n_live += 1;
            }
            return;
        }
        let r = self.ids.len();
        self.data.extend_from_slice(&l2_normalized(vec));
        self.ids.push(id);
        self.live.push(true);
        self.by_id.insert(id, r);
        self.n_live += 1;
    }

    fn remove(&mut self, id: u64) -> bool {
        match self.by_id.get(&id) {
            Some(&r) if self.live[r] => {
                self.live[r] = false;
                self.n_live -= 1;
                true
            }
            _ => false,
        }
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Neighbor> {
        assert_eq!(query.len(), self.dim, "dimension mismatch");
        if k == 0 || self.n_live == 0 {
            return Vec::new();
        }
        let q = l2_normalized(query);
        // Min-heap of size k over (score, id): keep the k best.
        let mut heap: BinaryHeap<std::cmp::Reverse<(OrdF32, u64)>> = BinaryHeap::with_capacity(k + 1);
        for r in 0..self.ids.len() {
            if !self.live[r] {
                continue;
            }
            let s = dot(&q, self.row(r));
            if heap.len() < k {
                heap.push(std::cmp::Reverse((OrdF32(s), self.ids[r])));
            } else if s > heap.peek().unwrap().0 .0 .0 {
                heap.pop();
                heap.push(std::cmp::Reverse((OrdF32(s), self.ids[r])));
            }
        }
        let mut out: Vec<Neighbor> = heap
            .into_iter()
            .map(|std::cmp::Reverse((OrdF32(s), id))| Neighbor { id, score: s })
            .collect();
        out.sort_by(|a, b| b.score.total_cmp(&a.score));
        out
    }

    fn len(&self) -> usize {
        self.n_live
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn slots(&self) -> usize {
        self.ids.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exact_topk_matches_full_sort() {
        let mut idx = FlatIndex::new(16);
        let mut rng = Rng::new(1);
        let mut vecs = Vec::new();
        for id in 0..300u64 {
            let v: Vec<f32> = (0..16).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
            idx.insert(id, &v);
            vecs.push(v);
        }
        let q: Vec<f32> = (0..16).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let mut all = idx.score_all(&q);
        all.sort_by(|a, b| b.score.total_cmp(&a.score));
        let top = idx.search(&q, 7);
        for (a, b) in top.iter().zip(all.iter()) {
            assert_eq!(a.id, b.id);
            assert!((a.score - b.score).abs() < 1e-6);
        }
    }

    #[test]
    fn overwrite_same_id_keeps_len() {
        let mut idx = FlatIndex::new(4);
        idx.insert(7, &[1.0, 0.0, 0.0, 0.0]);
        idx.insert(7, &[0.0, 1.0, 0.0, 0.0]);
        assert_eq!(idx.len(), 1);
        let res = idx.search(&[0.0, 1.0, 0.0, 0.0], 1);
        assert!(res[0].score > 0.999);
    }

    #[test]
    fn reinsert_after_remove_revives() {
        let mut idx = FlatIndex::new(4);
        idx.insert(1, &[1.0, 0.0, 0.0, 0.0]);
        assert!(idx.remove(1));
        assert_eq!(idx.len(), 0);
        idx.insert(1, &[0.0, 0.0, 1.0, 0.0]);
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.search(&[0.0, 0.0, 1.0, 0.0], 1)[0].id, 1);
    }

    #[test]
    fn compact_reclaims_tombstones() {
        let mut idx = FlatIndex::new(4);
        for id in 0..100u64 {
            idx.insert(id, &[id as f32 + 1.0, 1.0, 0.0, 0.0]);
        }
        for id in 0..50u64 {
            idx.remove(id);
        }
        assert!(idx.garbage_ratio() > 0.49);
        let before = idx.search(&[60.0, 1.0, 0.0, 0.0], 5);
        idx.compact();
        assert_eq!(idx.garbage_ratio(), 0.0);
        let after = idx.search(&[60.0, 1.0, 0.0, 0.0], 5);
        assert_eq!(
            before.iter().map(|n| n.id).collect::<Vec<_>>(),
            after.iter().map(|n| n.id).collect::<Vec<_>>()
        );
    }

    #[test]
    fn zero_k_and_empty() {
        let idx = FlatIndex::new(4);
        assert!(idx.search(&[1.0, 0.0, 0.0, 0.0], 3).is_empty());
        let mut idx = FlatIndex::new(4);
        idx.insert(1, &[1.0, 0.0, 0.0, 0.0]);
        assert!(idx.search(&[1.0, 0.0, 0.0, 0.0], 0).is_empty());
    }
}
