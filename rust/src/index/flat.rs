//! Exhaustive-scan index — the paper's O(n) baseline (§2.4) and the
//! ground-truth oracle for HNSW recall tests.

use std::collections::BinaryHeap;
use std::collections::HashMap;

use super::{quantized_preselect_width, Neighbor, OrdF32, VectorIndex};
use crate::util::{dot, dot_i8, l2_normalized, quantize_i8};

/// Flat (brute-force) cosine index. Vectors live in one contiguous
/// row-major matrix for scan locality; removals tombstone the row and
/// `compact()` reclaims it.
///
/// An int8 code matrix (per-row scale, symmetric quantization — see
/// `util::vecmath::quantize_i8`) is maintained alongside the f32 rows.
/// With `quantized` scanning enabled, `search` preselects a widened
/// candidate set by streaming the 4×-denser code matrix, then
/// exact-reranks only those candidates in f32 — returned scores are
/// always exact f32 dots.
pub struct FlatIndex {
    dim: usize,
    data: Vec<f32>,
    /// Int8 codes, same row layout as `data`; re-derived, never persisted.
    qdata: Vec<i8>,
    /// Per-row quantization scales.
    qscales: Vec<f32>,
    ids: Vec<u64>,
    live: Vec<bool>,
    by_id: HashMap<u64, usize>,
    n_live: usize,
    quantized: bool,
}

impl FlatIndex {
    pub fn new(dim: usize) -> Self {
        Self::with_quantized(dim, false)
    }

    /// `quantized = true` scores scan candidates through the int8 code
    /// matrix before the exact f32 rerank (the `quantized_scan` config
    /// key); `false` keeps the seed exact-only scan.
    pub fn with_quantized(dim: usize, quantized: bool) -> Self {
        assert!(dim > 0);
        Self {
            dim,
            data: Vec::new(),
            qdata: Vec::new(),
            qscales: Vec::new(),
            ids: Vec::new(),
            live: Vec::new(),
            by_id: HashMap::new(),
            n_live: 0,
            quantized,
        }
    }

    /// Whether searches use the quantized preselect path.
    pub fn quantized(&self) -> bool {
        self.quantized
    }

    /// Row slice for internal row index.
    #[inline]
    fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.dim..(r + 1) * self.dim]
    }

    /// Int8 code row for internal row index.
    #[inline]
    fn qrow(&self, r: usize) -> &[i8] {
        &self.qdata[r * self.dim..(r + 1) * self.dim]
    }

    /// (Re)derive the int8 codes for row `r` from its f32 contents.
    fn requantize_row(&mut self, r: usize) {
        let mut codes = Vec::new();
        let scale = quantize_i8(&self.data[r * self.dim..(r + 1) * self.dim], &mut codes);
        self.qdata[r * self.dim..(r + 1) * self.dim].copy_from_slice(&codes);
        self.qscales[r] = scale;
    }

    /// Fraction of tombstoned rows.
    pub fn garbage_ratio(&self) -> f64 {
        if self.ids.is_empty() {
            0.0
        } else {
            1.0 - self.n_live as f64 / self.ids.len() as f64
        }
    }

    /// Rebuild the matrix without tombstones.
    pub fn compact(&mut self) {
        let mut data = Vec::with_capacity(self.n_live * self.dim);
        let mut qdata = Vec::with_capacity(self.n_live * self.dim);
        let mut qscales = Vec::with_capacity(self.n_live);
        let mut ids = Vec::with_capacity(self.n_live);
        for r in 0..self.ids.len() {
            if self.live[r] {
                data.extend_from_slice(self.row(r));
                qdata.extend_from_slice(self.qrow(r));
                qscales.push(self.qscales[r]);
                ids.push(self.ids[r]);
            }
        }
        self.by_id = ids.iter().enumerate().map(|(r, &id)| (id, r)).collect();
        self.live = vec![true; ids.len()];
        self.data = data;
        self.qdata = qdata;
        self.qscales = qscales;
        self.ids = ids;
    }

    /// Score every live row against `query` (normalized internally) —
    /// used by benches to compare against the PJRT scorer artifact.
    pub fn score_all(&self, query: &[f32]) -> Vec<Neighbor> {
        let q = l2_normalized(query);
        (0..self.ids.len())
            .filter(|&r| self.live[r])
            .map(|r| Neighbor { id: self.ids[r], score: dot(&q, self.row(r)) })
            .collect()
    }
}

impl VectorIndex for FlatIndex {
    fn insert(&mut self, id: u64, vec: &[f32]) {
        assert_eq!(vec.len(), self.dim, "dimension mismatch");
        if let Some(&r) = self.by_id.get(&id) {
            // Overwrite in place.
            let normalized = l2_normalized(vec);
            self.data[r * self.dim..(r + 1) * self.dim].copy_from_slice(&normalized);
            self.requantize_row(r);
            if !self.live[r] {
                self.live[r] = true;
                self.n_live += 1;
            }
            return;
        }
        let r = self.ids.len();
        self.data.extend_from_slice(&l2_normalized(vec));
        self.qdata.resize((r + 1) * self.dim, 0);
        self.qscales.push(0.0);
        self.requantize_row(r);
        self.ids.push(id);
        self.live.push(true);
        self.by_id.insert(id, r);
        self.n_live += 1;
    }

    fn remove(&mut self, id: u64) -> bool {
        match self.by_id.get(&id) {
            Some(&r) if self.live[r] => {
                self.live[r] = false;
                self.n_live -= 1;
                true
            }
            _ => false,
        }
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Neighbor> {
        assert_eq!(query.len(), self.dim, "dimension mismatch");
        if k == 0 || self.n_live == 0 {
            return Vec::new();
        }
        let q = l2_normalized(query);
        // Quantized path: preselect a widened candidate set by int8
        // score, then exact-rerank only those rows in f32. Skipped when
        // the widened set would cover (nearly) every live row anyway,
        // or when `SEMCACHE_SCALAR_KERNELS` forces the reference path.
        let pre = quantized_preselect_width(k);
        if self.quantized && !crate::util::scalar_kernels_forced() && pre < self.n_live {
            let mut qcodes = Vec::new();
            let qs = quantize_i8(&q, &mut qcodes);
            // Min-heap of size `pre` over approximate (score, row).
            let mut heap: BinaryHeap<std::cmp::Reverse<(OrdF32, usize)>> =
                BinaryHeap::with_capacity(pre + 1);
            for r in 0..self.ids.len() {
                if !self.live[r] {
                    continue;
                }
                let s = qs * self.qscales[r] * dot_i8(&qcodes, self.qrow(r)) as f32;
                if heap.len() < pre {
                    heap.push(std::cmp::Reverse((OrdF32(s), r)));
                } else if s > heap.peek().unwrap().0 .0 .0 {
                    heap.pop();
                    heap.push(std::cmp::Reverse((OrdF32(s), r)));
                }
            }
            let mut out: Vec<Neighbor> = heap
                .into_iter()
                .map(|std::cmp::Reverse((_, r))| Neighbor {
                    id: self.ids[r],
                    score: dot(&q, self.row(r)),
                })
                .collect();
            out.sort_by(|a, b| b.score.total_cmp(&a.score));
            out.truncate(k);
            return out;
        }
        // Min-heap of size k over (score, id): keep the k best.
        let mut heap: BinaryHeap<std::cmp::Reverse<(OrdF32, u64)>> = BinaryHeap::with_capacity(k + 1);
        for r in 0..self.ids.len() {
            if !self.live[r] {
                continue;
            }
            let s = dot(&q, self.row(r));
            if heap.len() < k {
                heap.push(std::cmp::Reverse((OrdF32(s), self.ids[r])));
            } else if s > heap.peek().unwrap().0 .0 .0 {
                heap.pop();
                heap.push(std::cmp::Reverse((OrdF32(s), self.ids[r])));
            }
        }
        let mut out: Vec<Neighbor> = heap
            .into_iter()
            .map(|std::cmp::Reverse((OrdF32(s), id))| Neighbor { id, score: s })
            .collect();
        out.sort_by(|a, b| b.score.total_cmp(&a.score));
        out
    }

    fn len(&self) -> usize {
        self.n_live
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn slots(&self) -> usize {
        self.ids.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exact_topk_matches_full_sort() {
        let mut idx = FlatIndex::new(16);
        let mut rng = Rng::new(1);
        let mut vecs = Vec::new();
        for id in 0..300u64 {
            let v: Vec<f32> = (0..16).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
            idx.insert(id, &v);
            vecs.push(v);
        }
        let q: Vec<f32> = (0..16).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let mut all = idx.score_all(&q);
        all.sort_by(|a, b| b.score.total_cmp(&a.score));
        let top = idx.search(&q, 7);
        for (a, b) in top.iter().zip(all.iter()) {
            assert_eq!(a.id, b.id);
            assert!((a.score - b.score).abs() < 1e-6);
        }
    }

    #[test]
    fn overwrite_same_id_keeps_len() {
        let mut idx = FlatIndex::new(4);
        idx.insert(7, &[1.0, 0.0, 0.0, 0.0]);
        idx.insert(7, &[0.0, 1.0, 0.0, 0.0]);
        assert_eq!(idx.len(), 1);
        let res = idx.search(&[0.0, 1.0, 0.0, 0.0], 1);
        assert!(res[0].score > 0.999);
    }

    #[test]
    fn reinsert_after_remove_revives() {
        let mut idx = FlatIndex::new(4);
        idx.insert(1, &[1.0, 0.0, 0.0, 0.0]);
        assert!(idx.remove(1));
        assert_eq!(idx.len(), 0);
        idx.insert(1, &[0.0, 0.0, 1.0, 0.0]);
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.search(&[0.0, 0.0, 1.0, 0.0], 1)[0].id, 1);
    }

    #[test]
    fn compact_reclaims_tombstones() {
        let mut idx = FlatIndex::new(4);
        for id in 0..100u64 {
            idx.insert(id, &[id as f32 + 1.0, 1.0, 0.0, 0.0]);
        }
        for id in 0..50u64 {
            idx.remove(id);
        }
        assert!(idx.garbage_ratio() > 0.49);
        let before = idx.search(&[60.0, 1.0, 0.0, 0.0], 5);
        idx.compact();
        assert_eq!(idx.garbage_ratio(), 0.0);
        let after = idx.search(&[60.0, 1.0, 0.0, 0.0], 5);
        assert_eq!(
            before.iter().map(|n| n.id).collect::<Vec<_>>(),
            after.iter().map(|n| n.id).collect::<Vec<_>>()
        );
    }

    #[test]
    fn quantized_scan_returns_exact_scores_and_survives_compact() {
        let mut exact = FlatIndex::new(24);
        let mut quant = FlatIndex::with_quantized(24, true);
        let mut rng = Rng::new(9);
        for id in 0..400u64 {
            let v: Vec<f32> = (0..24).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
            exact.insert(id, &v);
            quant.insert(id, &v);
        }
        let q: Vec<f32> = (0..24).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let a = exact.search(&q, 5);
        let b = quant.search(&q, 5);
        // Rerank is exact f32, so every returned score must be an exact
        // dot; at modest n the top-5 sets agree on this data.
        assert_eq!(
            a.iter().map(|n| n.id).collect::<Vec<_>>(),
            b.iter().map(|n| n.id).collect::<Vec<_>>()
        );
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.score.to_bits(), y.score.to_bits(), "rerank must return exact f32 scores");
        }
        // Tombstone half, compact: code matrix must stay row-aligned.
        for id in 0..200u64 {
            quant.remove(id);
        }
        let before = quant.search(&q, 5);
        quant.compact();
        let after = quant.search(&q, 5);
        assert_eq!(
            before.iter().map(|n| n.id).collect::<Vec<_>>(),
            after.iter().map(|n| n.id).collect::<Vec<_>>()
        );
        // Overwrite requantizes in place.
        let unit: Vec<f32> = (0..24).map(|i| if i == 0 { 1.0 } else { 0.0 }).collect();
        quant.insert(333, &unit);
        let hit = quant.search(&unit, 1);
        assert_eq!(hit[0].id, 333);
        assert!(hit[0].score > 0.999);
    }

    #[test]
    fn zero_k_and_empty() {
        let idx = FlatIndex::new(4);
        assert!(idx.search(&[1.0, 0.0, 0.0, 0.0], 3).is_empty());
        let mut idx = FlatIndex::new(4);
        idx.insert(1, &[1.0, 0.0, 0.0, 0.0]);
        assert!(idx.search(&[1.0, 0.0, 0.0, 0.0], 0).is_empty());
    }
}
