//! Small statistics helpers shared by metrics and the bench harness.

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile by linear interpolation on a *sorted* slice; `q` in [0, 100].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// One-pass summary of a sample: mean/std/min/max/p50/p95/p99.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, p50: 0.0, p95: 0.0, p99: 0.0 };
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            min: sorted[0],
            max: *sorted.last().unwrap(),
            p50: percentile(&sorted, 50.0),
            p95: percentile(&sorted, 95.0),
            p99: percentile(&sorted, 99.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!(s.p95 > s.p50 && s.p99 > s.p95);
    }
}
