//! Dense f32 vector kernels for the similarity hot path.
//!
//! `dot` is the inner loop of both the HNSW traversal and the flat-scan
//! rerank. It is written as independent accumulators so LLVM
//! auto-vectorizes it to SIMD without unsafe code or nightly features
//! (verified in the §Perf pass — see DESIGN.md §Perf / `bench_micro`).

/// Dot product with an 8-lane accumulator array: LLVM maps the inner
/// loop to one SIMD register of independent FMAs (verified ~9x faster
/// than the scalar/2-way form — see DESIGN.md §Perf / `bench_micro`).
///
/// Length mismatch is a hard panic in every build profile: the
/// chunked+zipped loops would otherwise silently drop the longer
/// vector's tail and return a plausible-but-wrong score, which a
/// similarity cache turns into wrong answers rather than crashes.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch {} vs {}", a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for i in 0..8 {
            acc[i] += xa[i] * xb[i];
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ra.iter().zip(rb) {
        tail += x * y;
    }
    acc.iter().sum::<f32>() + tail
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Cosine similarity of two raw (not necessarily normalized) vectors.
/// Zero vectors get similarity 0 rather than NaN.
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = norm(a);
    let nb = norm(b);
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

/// Normalize in place; zero vectors are left untouched.
pub fn l2_normalize(v: &mut [f32]) {
    let n = norm(v);
    if n > 0.0 {
        let inv = 1.0 / n;
        for x in v.iter_mut() {
            *x *= inv;
        }
    }
}

/// Normalized copy.
pub fn l2_normalized(v: &[f32]) -> Vec<f32> {
    let mut out = v.to_vec();
    l2_normalize(&mut out);
    out
}

/// `acc += s * v` (used by pooling in the native encoder).
///
/// Same contract as [`dot`]: mismatched lengths panic instead of
/// silently updating only a prefix of `acc`.
pub fn scale_add(acc: &mut [f32], v: &[f32], s: f32) {
    assert_eq!(acc.len(), v.len(), "scale_add: length mismatch {} vs {}", acc.len(), v.len());
    for (a, x) in acc.iter_mut().zip(v) {
        *a += s * x;
    }
}

// ---------------------------------------------------------------------------
// Int8 symmetric quantization (quantized candidate scan — DESIGN.md §Perf).
// ---------------------------------------------------------------------------

/// Quantize a vector to symmetric int8 codes plus a per-vector scale.
///
/// Format: `scale = max|v| / 127`, `code[i] = round(v[i] / scale)`
/// clamped to `[-127, 127]` (−128 is never produced, keeping the code
/// range symmetric), so `v[i] ≈ code[i] * scale`. The all-zero vector
/// gets `scale == 0.0` and all-zero codes; every quantized score
/// against it is exactly 0, matching the f32 dot. Quantization is a
/// pure function of the input vector, so codes can be re-derived
/// deterministically from the exact f32 copy after a snapshot/WAL
/// restart instead of being persisted.
pub fn quantize_i8(v: &[f32], codes: &mut Vec<i8>) -> f32 {
    codes.clear();
    codes.reserve(v.len());
    let max_abs = v.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    if max_abs == 0.0 {
        codes.extend(std::iter::repeat(0i8).take(v.len()));
        return 0.0;
    }
    let scale = max_abs / 127.0;
    let inv = 127.0 / max_abs;
    for &x in v {
        codes.push((x * inv).round().clamp(-127.0, 127.0) as i8);
    }
    scale
}

/// Widening-i32 dot product of two int8 code vectors, in the same
/// 8-lane independent-accumulator style as [`dot`] so LLVM
/// auto-vectorizes it. Products of `[-127, 127]` codes fit i32 for any
/// realistic dim (127² · dim < 2³¹ up to dim ≈ 133k).
///
/// The approximate similarity of vectors `a`/`b` with scales
/// `sa`/`sb` is `sa * sb * dot_i8(ca, cb) as f32`.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    assert_eq!(a.len(), b.len(), "dot_i8: length mismatch {} vs {}", a.len(), b.len());
    let mut acc = [0i32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for i in 0..8 {
            acc[i] += xa[i] as i32 * xb[i] as i32;
        }
    }
    let mut tail = 0i32;
    for (&x, &y) in ra.iter().zip(rb) {
        tail += x as i32 * y as i32;
    }
    acc.iter().sum::<i32>() + tail
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.1 - 5.0).collect();
        let b: Vec<f32> = (0..103).map(|i| ((i * 7 % 13) as f32) * 0.3).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn cosine_bounds_and_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![-4.0, 3.0, -2.0, 1.0];
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-6);
        let c = cosine(&a, &b);
        assert!((-1.0..=1.0).contains(&c));
    }

    #[test]
    fn zero_vector_cosine_is_zero() {
        assert_eq!(cosine(&[0.0; 8], &[1.0; 8]), 0.0);
    }

    #[test]
    fn normalize_unit_norm() {
        let mut v = vec![3.0, 4.0];
        l2_normalize(&mut v);
        assert!((norm(&v) - 1.0).abs() < 1e-6);
        assert!((v[0] - 0.6).abs() < 1e-6);
        let mut z = vec![0.0; 4];
        l2_normalize(&mut z);
        assert_eq!(z, vec![0.0; 4]);
    }

    #[test]
    fn dot_length_mismatch_panics_in_release_too() {
        // Regression: release builds used to silently drop the longer
        // vector's tail (chunks_exact + zip) and return a wrong score.
        let a = vec![1.0f32; 9];
        let b = vec![1.0f32; 8];
        let r = std::panic::catch_unwind(|| dot(&a, &b));
        assert!(r.is_err(), "dot must panic on length mismatch, not truncate");
        let r = std::panic::catch_unwind(|| {
            let mut acc = vec![0.0f32; 4];
            scale_add(&mut acc, &[1.0; 5], 2.0);
        });
        assert!(r.is_err(), "scale_add must panic on length mismatch");
    }

    #[test]
    fn quantize_roundtrip_error_is_bounded() {
        let v: Vec<f32> = (0..103).map(|i| ((i * 31 % 17) as f32 - 8.0) * 0.11).collect();
        let mut codes = Vec::new();
        let scale = quantize_i8(&v, &mut codes);
        assert_eq!(codes.len(), v.len());
        let max_abs = v.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        // Reconstruction error per element is at most half a step.
        for (&c, &x) in codes.iter().zip(&v) {
            assert!((c as f32 * scale - x).abs() <= scale * 0.5 + 1e-6, "x={x} c={c}");
            assert!((-127..=127).contains(&(c as i32)));
        }
        assert!((scale - max_abs / 127.0).abs() < 1e-9);
    }

    #[test]
    fn quantize_zero_vector_scores_zero() {
        let mut ca = Vec::new();
        let mut cb = Vec::new();
        let sa = quantize_i8(&[0.0; 16], &mut ca);
        let sb = quantize_i8(&[1.0; 16], &mut cb);
        assert_eq!(sa, 0.0);
        assert_eq!(sa * sb * dot_i8(&ca, &cb) as f32, 0.0);
    }

    #[test]
    fn dot_i8_matches_scalar_and_approximates_f32() {
        let a: Vec<f32> = (0..96).map(|i| ((i * 7 % 23) as f32 - 11.0) * 0.09).collect();
        let b: Vec<f32> = (0..96).map(|i| ((i * 13 % 19) as f32 - 9.0) * 0.07).collect();
        let (mut ca, mut cb) = (Vec::new(), Vec::new());
        let sa = quantize_i8(&a, &mut ca);
        let sb = quantize_i8(&b, &mut cb);
        let naive: i32 = ca.iter().zip(&cb).map(|(&x, &y)| x as i32 * y as i32).sum();
        assert_eq!(dot_i8(&ca, &cb), naive);
        let approx = sa * sb * naive as f32;
        let exact = dot(&a, &b);
        // int8 with per-vector scales keeps dot error small relative to
        // the vector norms (|err| <= ~(|a|+|b|) * step/2).
        assert!((approx - exact).abs() < 0.05 * norm(&a) * norm(&b) + 1e-3, "{approx} vs {exact}");
    }

    #[test]
    fn dot_i8_length_mismatch_panics() {
        let r = std::panic::catch_unwind(|| dot_i8(&[1, 2, 3], &[1, 2]));
        assert!(r.is_err());
    }

    #[test]
    fn normalized_dot_equals_cosine() {
        let a = vec![0.5f32, -1.5, 2.0, 0.25, 1.0];
        let b = vec![1.0f32, 0.5, -0.5, 2.0, -1.0];
        let c1 = cosine(&a, &b);
        let c2 = dot(&l2_normalized(&a), &l2_normalized(&b));
        assert!((c1 - c2).abs() < 1e-6);
    }
}
