//! Dense f32 vector kernels for the similarity hot path.
//!
//! `dot` is the inner loop of both the HNSW traversal and the flat-scan
//! rerank. It is written as independent accumulators so LLVM
//! auto-vectorizes it to SIMD without unsafe code or nightly features
//! (verified in the §Perf pass — see DESIGN.md §Perf / `bench_micro`).

/// Dot product with an 8-lane accumulator array: LLVM maps the inner
/// loop to one SIMD register of independent FMAs (verified ~9x faster
/// than the scalar/2-way form — see DESIGN.md §Perf / `bench_micro`).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for i in 0..8 {
            acc[i] += xa[i] * xb[i];
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ra.iter().zip(rb) {
        tail += x * y;
    }
    acc.iter().sum::<f32>() + tail
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Cosine similarity of two raw (not necessarily normalized) vectors.
/// Zero vectors get similarity 0 rather than NaN.
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = norm(a);
    let nb = norm(b);
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

/// Normalize in place; zero vectors are left untouched.
pub fn l2_normalize(v: &mut [f32]) {
    let n = norm(v);
    if n > 0.0 {
        let inv = 1.0 / n;
        for x in v.iter_mut() {
            *x *= inv;
        }
    }
}

/// Normalized copy.
pub fn l2_normalized(v: &[f32]) -> Vec<f32> {
    let mut out = v.to_vec();
    l2_normalize(&mut out);
    out
}

/// `acc += s * v` (used by pooling in the native encoder).
pub fn scale_add(acc: &mut [f32], v: &[f32], s: f32) {
    debug_assert_eq!(acc.len(), v.len());
    for (a, x) in acc.iter_mut().zip(v) {
        *a += s * x;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.1 - 5.0).collect();
        let b: Vec<f32> = (0..103).map(|i| ((i * 7 % 13) as f32) * 0.3).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn cosine_bounds_and_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![-4.0, 3.0, -2.0, 1.0];
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-6);
        let c = cosine(&a, &b);
        assert!((-1.0..=1.0).contains(&c));
    }

    #[test]
    fn zero_vector_cosine_is_zero() {
        assert_eq!(cosine(&[0.0; 8], &[1.0; 8]), 0.0);
    }

    #[test]
    fn normalize_unit_norm() {
        let mut v = vec![3.0, 4.0];
        l2_normalize(&mut v);
        assert!((norm(&v) - 1.0).abs() < 1e-6);
        assert!((v[0] - 0.6).abs() < 1e-6);
        let mut z = vec![0.0; 4];
        l2_normalize(&mut z);
        assert_eq!(z, vec![0.0; 4]);
    }

    #[test]
    fn normalized_dot_equals_cosine() {
        let a = vec![0.5f32, -1.5, 2.0, 0.25, 1.0];
        let b = vec![1.0f32, 0.5, -0.5, 2.0, -1.0];
        let c1 = cosine(&a, &b);
        let c2 = dot(&l2_normalized(&a), &l2_normalized(&b));
        assert!((c1 - c2).abs() < 1e-6);
    }
}
