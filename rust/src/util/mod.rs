//! Shared low-level utilities: the deterministic PRNG (bit-exact with the
//! Python compile path), dense vector math for the similarity hot path,
//! and small statistics helpers used by metrics and the benches.

mod rng;
mod stats;
mod vecmath;

pub use rng::{Rng, SplitMix64};
pub use stats::{mean, percentile, stddev, Summary};
pub use vecmath::{cosine, dot, l2_normalize, l2_normalized, norm, scale_add};
