//! Shared low-level utilities: the deterministic PRNG (bit-exact with the
//! Python compile path), dense vector math for the similarity hot path,
//! small statistics helpers used by metrics and the benches, and the
//! zero-dependency readiness-polling shim (`poll`) behind the
//! event-driven HTTP front-end.

#[cfg(unix)]
pub mod poll;
mod rng;
mod stats;
mod vecmath;

pub use rng::{Rng, SplitMix64};
pub use stats::{mean, percentile, stddev, Summary};
pub use vecmath::{cosine, dot, l2_normalize, l2_normalized, norm, scale_add};
