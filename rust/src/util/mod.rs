//! Shared low-level utilities: the deterministic PRNG (bit-exact with the
//! Python compile path), dense vector math for the similarity hot path,
//! small statistics helpers used by metrics and the benches, and the
//! zero-dependency readiness-polling shim (`poll`) behind the
//! event-driven HTTP front-end.

#[cfg(unix)]
pub mod poll;
mod rng;
mod stats;
mod vecmath;

pub use rng::{Rng, SplitMix64};
pub use stats::{mean, percentile, stddev, Summary};
pub use vecmath::{cosine, dot, dot_i8, l2_normalize, l2_normalized, norm, quantize_i8, scale_add};

/// `SEMCACHE_SCALAR_KERNELS=1` forces the scalar reference kernels on
/// the compute hot paths (naive matmul in the encoder, exact-f32
/// candidate scoring in the indexes), mirroring the `poll_fallback`
/// convention so CI can exercise both the optimized and reference
/// arms. Read once; the choice is process-wide.
pub fn scalar_kernels_forced() -> bool {
    static FORCED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *FORCED.get_or_init(|| {
        std::env::var("SEMCACHE_SCALAR_KERNELS").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
    })
}

/// Default reactor-thread count for the event-driven HTTP front-end:
/// one per core, capped at 8 (past that the accept path is never the
/// bottleneck and idle pollers just burn wakeups).
pub fn auto_reactors() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// Default batcher-dispatcher shard count: half the cores, capped at 4
/// — dispatchers only shepherd batches into the worker pool, so they
/// saturate long before reactors do.
pub fn auto_dispatchers() -> usize {
    (std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) / 2).clamp(1, 4)
}
