//! Deterministic PRNG, bit-exact with `python/compile/weights.py`.
//!
//! The encoder weights are *generated*, not trained: both the JAX model
//! (compile path) and the Rust native reference encoder derive every
//! parameter tensor from the same splitmix64 stream, so the two
//! implementations agree to float rounding without shipping a checkpoint.
//! Keep this file in lock-step with the Python twin — the pytest suite and
//! `rust/tests/parity.rs` both assert the cross-language contract.

/// splitmix64 (Steele et al.), the de-facto standard seed expander.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Derive an independent stream for a named substream (layer/tensor).
    /// fnv1a over the label, mixed into the seed — identical in Python.
    pub fn derive(seed: u64, label: &str) -> Self {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Self::new(seed ^ h)
    }

    /// Current internal state (for checkpoint/restore; pairs with
    /// [`SplitMix64::from_state`]).
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Resume a stream from a saved [`SplitMix64::state`] value.
    pub fn from_state(state: u64) -> Self {
        Self { state }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1): top 53 bits / 2^53 (same construction as numpy's
    /// float64 path, reproduced in weights.py).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / 9007199254740992.0)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller (deterministic pair consumption;
    /// both values of the pair are used, mirrored in Python).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f64) {
        let mut i = 0;
        while i < out.len() {
            // u1 in (0,1] to avoid ln(0).
            let u1 = 1.0 - self.next_f64();
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            out[i] = (r * theta.cos() * std) as f32;
            i += 1;
            if i < out.len() {
                out[i] = (r * theta.sin() * std) as f32;
                i += 1;
            }
        }
    }

    /// A fresh normal-filled vector.
    pub fn normal_vec(&mut self, n: usize, std: f64) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v, std);
        v
    }
}

/// Convenience RNG for the workload/simulation side (no cross-language
/// contract; just fast and deterministic).
#[derive(Debug, Clone)]
pub struct Rng(SplitMix64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(SplitMix64::new(seed))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.0.next_u64()
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        self.0.next_f64()
    }

    /// Uniform integer in [0, n). Rejection-free Lemire-style reduction is
    /// unnecessary here; modulo bias is negligible for simulation n << 2^64.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.0.uniform(lo, hi)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Exponential with the given mean (for Poisson arrivals).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.next_f64()).ln()
    }

    /// Standard normal scaled by `std` (latency jitter etc).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let mut pair = [0.0f32; 2];
        self.0.fill_normal(&mut pair, 1.0);
        mean + std * pair[0] as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// First outputs of splitmix64(0) — canonical vector from the
    /// reference implementation; weights.py asserts the same values.
    #[test]
    fn splitmix_reference_vector() {
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(r.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(r.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn derive_differs_by_label_and_is_stable() {
        let a = SplitMix64::derive(42, "layer0.wq").next_u64();
        let b = SplitMix64::derive(42, "layer0.wk").next_u64();
        let a2 = SplitMix64::derive(42, "layer0.wq").next_u64();
        assert_ne!(a, b);
        assert_eq!(a, a2);
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(7);
        let v = r.normal_vec(200_000, 2.0);
        let mean: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        let var: f64 =
            v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 4.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.range_f64(2.0, 3.0);
            assert!((2.0..3.0).contains(&x));
            let i = r.below(17);
            assert!(i < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 1.0, 8.0])] += 1;
        }
        assert!(counts[2] > counts[0] * 5);
        assert!(counts[2] > counts[1] * 5);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let m: f64 = (0..100_000).map(|_| r.exponential(5.0)).sum::<f64>() / 1e5;
        assert!((m - 5.0).abs() < 0.1, "mean {m}");
    }
}
