//! Zero-dependency readiness polling over raw file descriptors.
//!
//! The event-driven HTTP front-end ([`crate::coordinator`]'s reactor)
//! needs to watch hundreds-to-thousands of mostly-idle sockets with a
//! single thread. The offline build carries no `libc`/`mio` crates, so
//! this module declares the handful of syscalls itself via thin
//! `extern "C"` shims:
//!
//! * **epoll** (`epoll_create1`/`epoll_ctl`/`epoll_wait`) — the O(ready)
//!   Linux backend, used by default on Linux;
//! * **poll(2)** — the portable POSIX fallback (macOS/BSD CI builds, or
//!   forced via [`Poller::new`]`(force_fallback = true)` to test the
//!   fallback path on Linux). O(registered) per wait, which is fine for
//!   the fleet sizes CI exercises.
//!
//! Both backends speak the same [`Poller`] interface: register a raw fd
//! with a caller-chosen `u64` token and an [`Interest`], then [`Poller::wait`]
//! returns level-triggered [`PollEvent`]s. Level-triggered semantics keep
//! the reactor simple: an fd with unread data keeps reporting readable,
//! so a short read never strands a connection.
//!
//! The module also hosts two small socket/process helpers that need raw
//! syscalls and nothing else in the crate does: `SO_SNDBUF` access for
//! the short-write regression test, and a best-effort `RLIMIT_NOFILE`
//! raise for the high-fan-in bench.

use std::io;
use std::os::raw::{c_int, c_uint, c_ulong, c_void};
use std::os::unix::io::RawFd;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

/// Test-only fault injection: while non-zero, the next that-many
/// [`Poller::register`] calls (process-wide, across every poller) fail
/// with an injected error instead of reaching the backend. Lets tests
/// drive the reactor's register-failure accept path — which otherwise
/// needs real fd exhaustion — deterministically.
#[doc(hidden)]
pub static FAIL_NEXT_REGISTERS: AtomicUsize = AtomicUsize::new(0);

// ---------------------------------------------------------------------
// Raw syscall declarations (libc is linked by std; we only declare).
// ---------------------------------------------------------------------

/// Mirror of the kernel's `struct epoll_event`. The kernel packs it
/// **only on x86_64** (uapi: `#ifdef __x86_64__ #define EPOLL_PACKED
/// __attribute__((packed))`); on every other architecture it has
/// natural alignment (16 bytes, `data` at offset 8) — getting this
/// wrong garbles every token `epoll_wait` reports.
#[cfg(target_os = "linux")]
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy)]
struct EpollEventRaw {
    events: u32,
    data: u64,
}

#[repr(C)]
#[derive(Clone, Copy)]
struct PollFdRaw {
    fd: c_int,
    events: i16,
    revents: i16,
}

#[repr(C)]
struct RlimitRaw {
    cur: c_ulong,
    max: c_ulong,
}

extern "C" {
    #[cfg(target_os = "linux")]
    fn epoll_create1(flags: c_int) -> c_int;
    #[cfg(target_os = "linux")]
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEventRaw) -> c_int;
    #[cfg(target_os = "linux")]
    fn epoll_wait(epfd: c_int, events: *mut EpollEventRaw, maxevents: c_int, timeout: c_int)
        -> c_int;
    #[cfg(target_os = "linux")]
    fn close(fd: c_int) -> c_int;

    fn poll(fds: *mut PollFdRaw, nfds: c_ulong, timeout: c_int) -> c_int;

    fn setsockopt(
        fd: c_int,
        level: c_int,
        optname: c_int,
        optval: *const c_void,
        optlen: c_uint,
    ) -> c_int;
    fn getsockopt(
        fd: c_int,
        level: c_int,
        optname: c_int,
        optval: *mut c_void,
        optlen: *mut c_uint,
    ) -> c_int;

    fn getrlimit(resource: c_int, rlim: *mut RlimitRaw) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const RlimitRaw) -> c_int;
}

#[cfg(target_os = "linux")]
mod ep {
    use std::os::raw::c_int;
    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

#[cfg(target_os = "linux")]
const SOL_SOCKET: c_int = 1;
#[cfg(target_os = "linux")]
const SO_SNDBUF: c_int = 7;
#[cfg(not(target_os = "linux"))]
const SOL_SOCKET: c_int = 0xffff;
#[cfg(not(target_os = "linux"))]
const SO_SNDBUF: c_int = 0x1001;

#[cfg(target_os = "linux")]
const RLIMIT_NOFILE: c_int = 7;
#[cfg(not(target_os = "linux"))]
const RLIMIT_NOFILE: c_int = 8;

// ---------------------------------------------------------------------
// The backend-neutral interface.
// ---------------------------------------------------------------------

/// What readiness a registered fd is watched for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Interest {
    /// No readiness wanted (the fd stays registered; error/hangup events
    /// are still delivered — used while a request is in flight).
    None,
    Read,
    Write,
    ReadWrite,
}

impl Interest {
    fn wants_read(self) -> bool {
        matches!(self, Interest::Read | Interest::ReadWrite)
    }

    fn wants_write(self) -> bool {
        matches!(self, Interest::Write | Interest::ReadWrite)
    }
}

/// One readiness report from [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    /// The token the fd was registered with.
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Error or full hangup on the fd (delivered regardless of
    /// interest); the owner should tear the connection down.
    pub closed: bool,
}

/// Level-triggered readiness poller: epoll on Linux, `poll(2)` elsewhere
/// (or when the fallback is forced).
pub enum Poller {
    #[cfg(target_os = "linux")]
    Epoll(EpollPoller),
    Fallback(PollPoller),
}

impl Poller {
    /// Build the platform-preferred backend; `force_fallback` selects
    /// the portable `poll(2)` backend even where epoll is available (so
    /// the fallback stays exercised by Linux CI).
    pub fn new(force_fallback: bool) -> io::Result<Poller> {
        #[cfg(target_os = "linux")]
        {
            if !force_fallback {
                return Ok(Poller::Epoll(EpollPoller::new()?));
            }
        }
        let _ = force_fallback;
        Ok(Poller::Fallback(PollPoller::new()))
    }

    pub fn backend_name(&self) -> &'static str {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(_) => "epoll",
            Poller::Fallback(_) => "poll",
        }
    }

    pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        if FAIL_NEXT_REGISTERS
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| n.checked_sub(1))
            .is_ok()
        {
            return Err(io::Error::new(
                io::ErrorKind::Other,
                "injected register failure (FAIL_NEXT_REGISTERS)",
            ));
        }
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.register(fd, token, interest),
            Poller::Fallback(p) => p.register(fd, token, interest),
        }
    }

    pub fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.modify(fd, token, interest),
            Poller::Fallback(p) => p.modify(fd, token, interest),
        }
    }

    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.deregister(fd),
            Poller::Fallback(p) => p.deregister(fd),
        }
    }

    /// Wait for readiness, appending into `out` (cleared first). A
    /// signal interruption (`EINTR`) or timeout reports zero events,
    /// never an error — callers just loop.
    pub fn wait(&mut self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.wait(out, timeout),
            Poller::Fallback(p) => p.wait(out, timeout),
        }
    }
}

fn timeout_ms(timeout: Option<Duration>) -> c_int {
    match timeout {
        None => -1,
        Some(d) => d.as_millis().min(i32::MAX as u128) as c_int,
    }
}

// ---------------------------------------------------------------------
// epoll backend (Linux).
// ---------------------------------------------------------------------

#[cfg(target_os = "linux")]
pub struct EpollPoller {
    epfd: RawFd,
}

#[cfg(target_os = "linux")]
impl EpollPoller {
    fn new() -> io::Result<Self> {
        // SAFETY: plain syscall; a negative return is reported as errno.
        let epfd = unsafe { epoll_create1(ep::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self { epfd })
    }

    fn mask(interest: Interest) -> u32 {
        let mut m = 0u32;
        if interest.wants_read() {
            m |= ep::EPOLLIN | ep::EPOLLRDHUP;
        }
        if interest.wants_write() {
            m |= ep::EPOLLOUT;
        }
        m
    }

    fn ctl(&mut self, op: c_int, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        let mut ev = EpollEventRaw { events: Self::mask(interest), data: token };
        // SAFETY: `ev` outlives the call; DEL ignores the event but a
        // non-null pointer keeps pre-2.6.9 kernels happy too.
        let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        self.ctl(ep::EPOLL_CTL_ADD, fd, token, interest)
    }

    fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        self.ctl(ep::EPOLL_CTL_MOD, fd, token, interest)
    }

    fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        self.ctl(ep::EPOLL_CTL_DEL, fd, 0, Interest::None)
    }

    fn wait(&mut self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
        out.clear();
        let mut buf = [EpollEventRaw { events: 0, data: 0 }; 256];
        // SAFETY: `buf` is a valid writable array of `maxevents` entries.
        let n = unsafe { epoll_wait(self.epfd, buf.as_mut_ptr(), 256, timeout_ms(timeout)) };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        for raw in buf.iter().take(n as usize) {
            // Copy the packed fields out by value (no references into a
            // packed struct).
            let bits = raw.events;
            let token = raw.data;
            out.push(PollEvent {
                token,
                readable: bits & (ep::EPOLLIN | ep::EPOLLRDHUP) != 0,
                writable: bits & ep::EPOLLOUT != 0,
                closed: bits & (ep::EPOLLERR | ep::EPOLLHUP) != 0,
            });
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollPoller {
    fn drop(&mut self) {
        // SAFETY: `epfd` is an fd this struct owns exclusively.
        unsafe {
            close(self.epfd);
        }
    }
}

// ---------------------------------------------------------------------
// poll(2) fallback backend (portable).
// ---------------------------------------------------------------------

pub struct PollPoller {
    /// Registered fds in registration order; O(n) modify/deregister is
    /// fine at fallback-backend fleet sizes.
    entries: Vec<(RawFd, u64, Interest)>,
}

impl PollPoller {
    fn new() -> Self {
        Self { entries: Vec::new() }
    }

    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        if self.entries.iter().any(|(f, _, _)| *f == fd) {
            return Err(io::Error::new(io::ErrorKind::AlreadyExists, "fd already registered"));
        }
        self.entries.push((fd, token, interest));
        Ok(())
    }

    fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        for e in &mut self.entries {
            if e.0 == fd {
                e.1 = token;
                e.2 = interest;
                return Ok(());
            }
        }
        Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"))
    }

    fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        let before = self.entries.len();
        self.entries.retain(|(f, _, _)| *f != fd);
        if self.entries.len() == before {
            return Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"));
        }
        Ok(())
    }

    fn wait(&mut self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
        out.clear();
        if self.entries.is_empty() {
            // Nothing registered: just sleep out the timeout.
            if let Some(d) = timeout {
                std::thread::sleep(d.min(Duration::from_millis(50)));
            }
            return Ok(());
        }
        let mut fds: Vec<PollFdRaw> = self
            .entries
            .iter()
            .map(|(fd, _, interest)| {
                let mut events = 0i16;
                if interest.wants_read() {
                    events |= POLLIN;
                }
                if interest.wants_write() {
                    events |= POLLOUT;
                }
                PollFdRaw { fd: *fd, events, revents: 0 }
            })
            .collect();
        // SAFETY: `fds` is a valid writable array of `nfds` entries.
        let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as c_ulong, timeout_ms(timeout)) };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        for (raw, (_, token, _)) in fds.iter().zip(self.entries.iter()) {
            let r = raw.revents;
            if r == 0 {
                continue;
            }
            out.push(PollEvent {
                token: *token,
                readable: r & (POLLIN | POLLHUP) != 0,
                writable: r & POLLOUT != 0,
                closed: r & (POLLERR | POLLHUP | POLLNVAL) != 0,
            });
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Small raw-socket / process helpers.
// ---------------------------------------------------------------------

/// Set a socket's kernel send-buffer size (`SO_SNDBUF`). Used by the
/// short-write regression test to force partial writes deterministically.
pub fn set_send_buffer(fd: RawFd, bytes: usize) -> io::Result<()> {
    let val: c_int = bytes.min(i32::MAX as usize) as c_int;
    // SAFETY: `val` outlives the call; optlen matches the value's size.
    let rc = unsafe {
        setsockopt(
            fd,
            SOL_SOCKET,
            SO_SNDBUF,
            &val as *const c_int as *const c_void,
            std::mem::size_of::<c_int>() as c_uint,
        )
    };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

/// Read back a socket's kernel send-buffer size.
pub fn send_buffer(fd: RawFd) -> io::Result<usize> {
    let mut val: c_int = 0;
    let mut len: c_uint = std::mem::size_of::<c_int>() as c_uint;
    // SAFETY: `val`/`len` outlive the call and are properly sized.
    let rc =
        unsafe { getsockopt(fd, SOL_SOCKET, SO_SNDBUF, &mut val as *mut c_int as *mut c_void, &mut len) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(val.max(0) as usize)
}

/// Best-effort raise of the soft `RLIMIT_NOFILE` toward `want` (bounded
/// by the hard limit). Returns the effective soft limit afterwards; on
/// any failure the current (unchanged) limit is returned. Used by the
/// high-fan-in bench, which opens hundreds of loopback sockets.
pub fn raise_nofile_limit(want: u64) -> u64 {
    let mut lim = RlimitRaw { cur: 0, max: 0 };
    // SAFETY: `lim` is a valid writable struct.
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } < 0 {
        return 0;
    }
    let cur = lim.cur as u64;
    if cur >= want {
        return cur;
    }
    let target = want.min(lim.max as u64);
    let new = RlimitRaw { cur: target as c_ulong, max: lim.max };
    // SAFETY: `new` is a valid struct for the duration of the call.
    if unsafe { setrlimit(RLIMIT_NOFILE, &new) } < 0 {
        return cur;
    }
    target
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    fn check_backend(force_fallback: bool) {
        let mut poller = Poller::new(force_fallback).expect("build poller");
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        poller.register(listener.as_raw_fd(), 7, Interest::Read).unwrap();

        let mut events = Vec::new();
        // Nothing pending yet: a short wait reports no events.
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.iter().all(|e| e.token != 7 || !e.readable), "{events:?}");

        // A connecting client makes the listener readable.
        let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let mut saw_accept = false;
        for _ in 0..100 {
            poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
            if events.iter().any(|e| e.token == 7 && e.readable) {
                saw_accept = true;
                break;
            }
        }
        assert!(saw_accept, "listener never reported readable");
        let (server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();

        // A fresh connection with an empty send queue is writable; it is
        // readable only after the peer writes.
        poller.register(server_side.as_raw_fd(), 8, Interest::ReadWrite).unwrap();
        let mut saw_writable = false;
        let mut client = client;
        client.write_all(b"ping").unwrap();
        client.flush().unwrap();
        let mut saw_readable = false;
        for _ in 0..100 {
            poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
            for e in &events {
                if e.token == 8 && e.writable {
                    saw_writable = true;
                }
                if e.token == 8 && e.readable {
                    saw_readable = true;
                }
            }
            if saw_writable && saw_readable {
                break;
            }
        }
        assert!(saw_writable, "connection never reported writable");
        assert!(saw_readable, "connection never reported readable after peer write");

        // Interest::None silences readable/writable (error events only).
        poller.modify(server_side.as_raw_fd(), 8, Interest::None).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(
            events.iter().all(|e| e.token != 8 || (!e.readable && !e.writable) || e.closed),
            "Interest::None still reported plain readiness: {events:?}"
        );

        poller.deregister(server_side.as_raw_fd()).unwrap();
        poller.deregister(listener.as_raw_fd()).unwrap();
        // Double-deregister is an error, not UB.
        assert!(poller.deregister(listener.as_raw_fd()).is_err());
    }

    #[test]
    fn platform_backend_reports_readiness() {
        check_backend(false);
    }

    #[test]
    fn fallback_backend_reports_readiness() {
        check_backend(true);
    }

    #[test]
    fn fallback_is_forceable() {
        let p = Poller::new(true).unwrap();
        assert_eq!(p.backend_name(), "poll");
    }

    #[test]
    fn send_buffer_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        set_send_buffer(client.as_raw_fd(), 8 * 1024).unwrap();
        // The kernel rounds/doubles; just confirm it is small-ish and
        // readable back.
        let got = send_buffer(client.as_raw_fd()).unwrap();
        assert!(got > 0, "SO_SNDBUF read back as 0");
        assert!(got <= 1 << 20, "tiny request produced a {got}-byte buffer");
    }

    #[test]
    fn nofile_raise_is_best_effort() {
        let eff = raise_nofile_limit(64);
        assert!(eff >= 64 || eff > 0, "effective limit {eff}");
    }
}
