//! # GPT Semantic Cache
//!
//! A production-quality reproduction of *"GPT Semantic Cache: Reducing LLM
//! Costs and Latency via Semantic Embedding Caching"* (Regmi & Pun, 2024),
//! built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build time)** — the embedding encoder's fused
//!   attention kernel and the batched cosine-similarity scorer, written
//!   as Pallas kernels in `python/compile/kernels/`.
//! * **Layer 2 (JAX, build time)** — a MiniLM-style sentence encoder
//!   (`python/compile/model.py`) that calls the Pallas kernels and is lowered
//!   once to HLO text by `python/compile/aot.py`.
//! * **Layer 3 (Rust, runtime)** — this crate: the semantic cache itself
//!   (vector store, HNSW ANN index, TTL key-value store), the typed v1
//!   serving API ([`api::QueryRequest`] → [`api::QueryResponse`]), the
//!   serving coordinator (single-query [`coordinator::Server::serve`],
//!   the concurrent batch pipeline
//!   [`coordinator::Server::serve_batch`], and the cross-request
//!   micro-batching engine [`coordinator::batcher`] that coalesces
//!   concurrent in-flight queries on the wire path), the
//!   zero-dependency HTTP front-end ([`coordinator::http`], the
//!   `semcached` binary), the
//!   simulated LLM upstream, the synthetic workload generator, and the
//!   experiment harness that regenerates every table and figure of the
//!   paper.
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! encoder + scorer to `artifacts/*.hlo.txt` once, and the Rust binary loads
//! them through PJRT (the [`runtime`] module; requires the `pjrt` cargo
//! feature — the default offline build uses the [`embedding::NativeEncoder`]
//! twin of the same model instead).
//!
//! ## Quick start
//!
//! ```no_run
//! use semcache::cache::{SemanticCache, CacheConfig};
//! use semcache::embedding::{Encoder, NativeEncoder};
//!
//! let encoder = NativeEncoder::minilm_sim();
//! let cache = SemanticCache::new(CacheConfig::default());
//! let e = encoder.encode_text("how do I reset my password?");
//! assert!(cache.lookup(&e).is_none());
//! cache.try_insert("how do I reset my password?", &e, "Click 'forgot password'...").unwrap();
//! let e2 = encoder.encode_text("how can I reset my password");
//! assert!(cache.lookup(&e2).is_some());
//! ```
//!
//! Or served over the wire (`cargo run --release --bin semcached -- serve`):
//!
//! ```text
//! curl -s localhost:8080/v1/query -d '{"text": "how do I reset my password?"}'
//! ```

pub mod api;
pub mod cache;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod embedding;
pub mod error;
pub mod eviction;
pub mod experiments;
pub mod index;
pub mod json;
pub mod llm;
pub mod metrics;
pub mod persist;
pub mod runtime;
pub mod store;
pub mod tenancy;
pub mod testutil;
pub mod tokenizer;
pub mod util;
pub mod workload;
