//! `semcache` — the GPT Semantic Cache leader binary.
//!
//! Subcommands map one-to-one onto DESIGN.md §5's experiment index; run
//! `semcache help` for usage. Python is never invoked here: the encoder
//! artifacts are AOT-compiled by `make artifacts` and loaded via PJRT.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use semcache::error::{bail, Result};

use semcache::cache::CacheConfig;
use semcache::cli::{Args, USAGE};
use semcache::config::Config;
use semcache::coordinator::{Server, ServerConfig, TraceConfig, TraceRunner};
use semcache::embedding::build_encoder;
use semcache::experiments::{self, EvalContext, PaperEvalConfig, ScalingConfig};
use semcache::json;
use semcache::llm::{JudgeConfig, SimLlmConfig};
use semcache::runtime::{artifacts_available, artifacts_dir, ModelParams};
use semcache::workload::{DatasetConfig, WorkloadGenerator};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        "info" => cmd_info(),
        "dataset" => cmd_dataset(&args),
        "experiment" => cmd_experiment(&args),
        "sweep" => cmd_sweep(&args),
        "scaling" => cmd_scaling(&args),
        "serve" => cmd_serve(&args),
        other => bail!("unknown subcommand '{other}' (try `semcache help`)"),
    }
}

/// Assemble the typed config from file + CLI overrides (experiment-CLI
/// flags reserved).
fn load_config(args: &Args) -> Result<Config> {
    Config::from_args(args, &["scale", "out", "qps", "workers"])
}

fn dataset_config(args: &Args) -> Result<DatasetConfig> {
    Ok(match args.opt("scale").unwrap_or("paper") {
        "paper" => DatasetConfig::paper(),
        "small" => DatasetConfig::small(),
        "tiny" => DatasetConfig::tiny(),
        other => bail!("unknown --scale '{other}' (paper|small|tiny)"),
    })
}

fn out_dir(args: &Args) -> Result<PathBuf> {
    let dir = PathBuf::from(args.opt("out").unwrap_or("results"));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

fn write_report(dir: &Path, name: &str, md: &str, json_val: &json::Value) -> Result<()> {
    std::fs::write(dir.join(format!("{name}.md")), md)?;
    std::fs::write(dir.join(format!("{name}.json")), json::to_string_pretty(json_val))?;
    println!("{md}");
    println!("[wrote {}/{name}.md and .json]", dir.display());
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("gpt-semantic-cache {}", env!("CARGO_PKG_VERSION"));
    println!("artifacts dir: {}", artifacts_dir().display());
    println!("artifacts built: {}", artifacts_available());
    println!("pjrt runtime compiled in: {}", semcache::runtime::pjrt_enabled());
    if semcache::runtime::pjrt_ready() {
        let rt = semcache::runtime::Runtime::load(&artifacts_dir())?;
        println!("PJRT platform: {}", rt.platform_name());
        println!("compiled executables: {:?}", rt.names());
    }
    let p = ModelParams::default();
    println!(
        "encoder: {} layers x {}d (vocab {}, seq {}, heads {})",
        p.layers, p.dim, p.vocab_size, p.seq_len, p.heads
    );
    Ok(())
}

fn cmd_dataset(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let ds_cfg = dataset_config(args)?;
    let ds = WorkloadGenerator::new(cfg.workload_seed).generate(&ds_cfg);
    let dir = out_dir(args)?;
    let path = dir.join("dataset.json");
    std::fs::write(&path, json::to_string_pretty(&ds.to_json()))?;
    println!(
        "dataset: {} base QA pairs, {} test queries -> {}",
        ds.base.len(),
        ds.tests.len(),
        path.display()
    );
    Ok(())
}

fn build_context(args: &Args, cfg: &Config) -> Result<EvalContext> {
    let encoder = build_encoder(cfg)?;
    let ds_cfg = dataset_config(args)?;
    eprintln!(
        "[embedding {} texts through the {} encoder...]",
        (ds_cfg.base_per_category + ds_cfg.tests_per_category) * 4,
        cfg.encoder_kind
    );
    Ok(EvalContext::build(encoder.as_ref(), &ds_cfg, cfg.workload_seed))
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args.positional().first().map(|s| s.as_str()).unwrap_or("all");
    let cfg = load_config(args)?;
    let ctx = build_context(args, &cfg)?;
    let eval_cfg = PaperEvalConfig {
        cache: CacheConfig::from_app_config(&cfg)?,
        llm: SimLlmConfig::from_app_config(&cfg),
        judge: JudgeConfig::default(),
        cost: Default::default(),
    };
    eprintln!("[running paper evaluation protocol...]");
    let eval = experiments::run_paper_eval(&ctx, &eval_cfg);
    let dir = out_dir(args)?;
    let j = eval.to_json();
    match which {
        "table1" => write_report(&dir, "table1", &experiments::render_table1(&eval), &j)?,
        "fig2" => write_report(&dir, "fig2", &experiments::render_fig2(&eval), &j)?,
        "fig3" => write_report(&dir, "fig3", &experiments::render_fig3(&eval), &j)?,
        "fig4" => write_report(&dir, "fig4", &experiments::render_fig4(&eval), &j)?,
        "all" => {
            let mut md = String::new();
            md.push_str(&experiments::render_table1(&eval));
            md.push('\n');
            md.push_str(&experiments::render_fig2(&eval));
            md.push('\n');
            md.push_str(&experiments::render_fig3(&eval));
            md.push('\n');
            md.push_str(&experiments::render_fig4(&eval));
            write_report(&dir, "paper_eval", &md, &j)?;
        }
        other => bail!("unknown experiment '{other}' (table1|fig2|fig3|fig4|all)"),
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let ctx = build_context(args, &cfg)?;
    let rows = experiments::threshold_sweep(
        &ctx,
        &CacheConfig::from_app_config(&cfg)?,
        &JudgeConfig::default(),
        &experiments::sweep_grid(),
    );
    let dir = out_dir(args)?;
    let j = json::Value::Array(rows.iter().map(|r| r.to_json()).collect());
    write_report(&dir, "threshold_sweep", &experiments::render_sweep(&rows), &j)?;
    Ok(())
}

fn cmd_scaling(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let mut sc = ScalingConfig::default();
    if args.flag("fast") {
        sc.sizes = vec![1_000, 4_000, 16_000];
        sc.queries = 50;
    }
    sc.hnsw.m = cfg.hnsw_m;
    sc.hnsw.ef_construction = cfg.hnsw_ef_construction;
    sc.hnsw.ef_search = cfg.hnsw_ef_search;
    let rows = experiments::scaling_study(&sc);
    let dir = out_dir(args)?;
    let j = json::Value::Array(rows.iter().map(|r| r.to_json()).collect());
    write_report(&dir, "scaling", &experiments::render_scaling(&rows), &j)?;
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let encoder = build_encoder(&cfg)?;
    let ds_cfg = dataset_config(args)?;
    let ds = WorkloadGenerator::new(cfg.workload_seed).generate(&ds_cfg);
    let server = Arc::new(Server::new(encoder, ServerConfig::from_app_config(&cfg)?));
    eprintln!("[populating cache with {} QA pairs...]", ds.base.len());
    server.populate(&ds.base);
    server.register_ground_truth(&ds);
    let _hk = server.start_housekeeping(Duration::from_millis(cfg.housekeeping_ms));

    let qps: f64 = args.opt_parse("qps", cfg.trace_qps)?;
    let workers: usize = args.opt_parse("workers", cfg.workers)?;
    eprintln!(
        "[serving {} queries, {} workers, {} qps arrivals...]",
        ds.tests.len(),
        workers,
        if qps > 0.0 { qps.to_string() } else { "max".into() }
    );
    let runner = TraceRunner::new(server.clone());
    let report = runner.run(
        &ds.tests,
        &TraceConfig { workers, qps, use_cache: true, seed: cfg.workload_seed },
    );
    println!(
        "served {} queries in {:.2}s  ({:.0} qps wall)",
        report.replies.len(),
        report.wall_secs,
        report.throughput_qps
    );
    println!(
        "hits {} ({:.1}%)  misses {}",
        report.hits,
        100.0 * report.hits as f64 / report.replies.len().max(1) as f64,
        report.misses
    );
    println!(
        "latency ms: mean {:.2}  p50 {:.2}  p95 {:.2}  p99 {:.2}",
        report.latency.mean, report.latency.p50, report.latency.p95, report.latency.p99
    );
    let m = server.metrics().snapshot();
    let uncached_cost = {
        let per_call_in = m.llm_input_tokens as f64 / m.llm_calls.max(1) as f64;
        let per_call_out = m.llm_output_tokens as f64 / m.llm_calls.max(1) as f64;
        let c: semcache::metrics::CostModel = Default::default();
        m.requests as f64
            * (per_call_in * c.usd_per_1m_input_tokens + per_call_out * c.usd_per_1m_output_tokens)
            / 1e6
    };
    println!(
        "metrics: requests {}  llm_calls {}  positive rate {:.1}%  est. cost ${:.4} (vs ${:.4} uncached)",
        m.requests,
        m.llm_calls,
        100.0 * m.positive_rate(),
        m.cost_usd(&Default::default()),
        uncached_cost
    );
    Ok(())
}
