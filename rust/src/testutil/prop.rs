//! Choice-stream property testing.
//!
//! A [`Gen`] wraps a recorded-or-random stream of `u64` choices. Running a
//! property = drawing values through `Gen`. When a case fails, the
//! harness replays mutations of the recorded stream (truncations, zeroing
//! spans, halving values) and reports the smallest stream that still
//! fails — giving generic shrinking for free.

use crate::util::SplitMix64;

/// Generator handle passed to properties.
pub struct Gen {
    /// Recorded choices; replayed when index < recorded.len().
    recorded: Vec<u64>,
    index: usize,
    rng: SplitMix64,
}

impl Gen {
    fn fresh(seed: u64) -> Self {
        Self { recorded: Vec::new(), index: 0, rng: SplitMix64::new(seed) }
    }

    fn replay(stream: Vec<u64>) -> Self {
        Self { recorded: stream, index: 0, rng: SplitMix64::new(0) }
    }

    /// Draw a raw choice. In replay mode exhausted streams yield 0 — the
    /// canonical "smallest" value, which biases shrinking toward small
    /// cases.
    fn draw(&mut self) -> u64 {
        if self.index < self.recorded.len() {
            let v = self.recorded[self.index];
            self.index += 1;
            v
        } else {
            let v = self.rng.next_u64();
            self.recorded.push(v);
            self.index += 1;
            v
        }
    }

    /// Uniform usize in [0, n) (n=0 yields 0).
    pub fn usize_below(&mut self, n: usize) -> usize {
        if n == 0 {
            0
        } else {
            (self.draw() % n as u64) as usize
        }
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.usize_below(hi.saturating_sub(lo) + 1)
    }

    pub fn u64(&mut self) -> u64 {
        self.draw()
    }

    pub fn bool(&mut self) -> bool {
        self.draw() & 1 == 1
    }

    /// f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let unit = (self.draw() >> 11) as f64 / 9007199254740992.0;
        lo + (hi - lo) * unit as f32
    }

    /// A random f32 vector.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Pick an element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_below(items.len())]
    }

    /// A short ascii word (for key/query generation).
    pub fn word(&mut self) -> String {
        let len = self.usize_in(1, 8);
        (0..len).map(|_| (b'a' + self.usize_below(26) as u8) as char).collect()
    }
}

/// Harness configuration.
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_rounds: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 256, seed: 0x5eed, max_shrink_rounds: 500 }
    }
}

/// Run `prop` over random cases; panic with the shrunken counterexample's
/// choice stream on failure. `prop` returns `Err(reason)` to fail.
pub fn prop_check<F>(cfg: PropConfig, name: &str, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::fresh(seed);
        if let Err(first_reason) = prop(&mut g) {
            let stream = g.recorded.clone();
            let (small, reason) =
                shrink(stream, first_reason, cfg.max_shrink_rounds, &mut prop);
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x})\n\
                 reason: {reason}\n\
                 shrunken choice stream ({} draws): {:?}",
                small.len(),
                &small[..small.len().min(32)]
            );
        }
    }
}

fn shrink<F>(
    mut stream: Vec<u64>,
    mut reason: String,
    max_rounds: usize,
    prop: &mut F,
) -> (Vec<u64>, String)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let fails = |s: &[u64], prop: &mut F| -> Option<String> {
        let mut g = Gen::replay(s.to_vec());
        prop(&mut g).err()
    };
    let mut rounds = 0;
    let mut progress = true;
    while progress && rounds < max_rounds {
        progress = false;
        // 1. Truncate tail by halves.
        let mut cut = stream.len() / 2;
        while cut > 0 && rounds < max_rounds {
            rounds += 1;
            let cand = stream[..stream.len() - cut].to_vec();
            if let Some(r) = fails(&cand, prop) {
                stream = cand;
                reason = r;
                progress = true;
            } else {
                cut /= 2;
            }
        }
        // 2. Zero individual choices.
        let mut i = 0;
        while i < stream.len() && rounds < max_rounds {
            rounds += 1;
            if stream[i] != 0 {
                let mut cand = stream.clone();
                cand[i] = 0;
                if let Some(r) = fails(&cand, prop) {
                    stream = cand;
                    reason = r;
                    progress = true;
                }
            }
            i += 1;
        }
        // 3. Halve individual choices.
        let mut i = 0;
        while i < stream.len() && rounds < max_rounds {
            rounds += 1;
            if stream[i] > 1 {
                let mut cand = stream.clone();
                cand[i] /= 2;
                if let Some(r) = fails(&cand, prop) {
                    stream = cand;
                    reason = r;
                    progress = true;
                }
            }
            i += 1;
        }
    }
    (stream, reason)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop_check(PropConfig { cases: 64, ..Default::default() }, "sum-commutes", |g| {
            let a = g.usize_below(1000);
            let b = g.usize_below(1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop_check(
                PropConfig { cases: 64, ..Default::default() },
                "no-big-values",
                |g| {
                    let v = g.usize_below(1000);
                    if v < 500 {
                        Ok(())
                    } else {
                        Err(format!("v={v} too big"))
                    }
                },
            );
        }));
        let msg = match result {
            Err(e) => *e.downcast::<String>().expect("panic message"),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("no-big-values"));
        // Shrinker should land on a near-minimal counterexample (v=500 ⇒
        // a halved/zeroed stream reproducing it, e.g. raw choice 500..
        // 999+k*1000); just assert it reported *some* shrunken stream.
        assert!(msg.contains("shrunken choice stream"));
    }

    #[test]
    fn replay_is_deterministic() {
        let mut g1 = Gen::fresh(42);
        let seq1: Vec<u64> = (0..10).map(|_| g1.u64()).collect();
        let mut g2 = Gen::replay(g1.recorded.clone());
        let seq2: Vec<u64> = (0..10).map(|_| g2.u64()).collect();
        assert_eq!(seq1, seq2);
    }

    #[test]
    fn generators_in_bounds() {
        let mut g = Gen::fresh(7);
        for _ in 0..1000 {
            assert!(g.usize_in(3, 9) >= 3 && g.usize_in(3, 9) <= 9);
            let f = g.f32_in(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&f));
            let w = g.word();
            assert!(!w.is_empty() && w.len() <= 8);
        }
    }
}
