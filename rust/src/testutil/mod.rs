//! In-tree property-testing harness (no `proptest` in the offline build).
//!
//! [`prop_check`] runs a property over many seeded random cases; on
//! failure it *shrinks* by replaying the generator with progressively
//! truncated/zeroed choice streams (the "internal shrinking" approach of
//! Hypothesis): a test case is fully described by the `u64` choices it
//! drew, so shrinking the stream shrinks the case without any per-type
//! shrinker code.

mod prop;

pub use prop::{prop_check, Gen, PropConfig};
