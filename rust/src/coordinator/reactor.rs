//! Event-driven HTTP serving: a fleet of reactor threads, epoll/poll
//! readiness, nonblocking sockets, resumable per-connection state
//! machines.
//!
//! The threaded-accept front-end pins one pool worker per open
//! connection, so a few hundred idle keep-alive chatbot sessions starve
//! fresh queries — exactly the long-lived-session traffic shape the
//! paper's cache fronts. This module replaces the wire path with a
//! readiness loop, sharded over `reactors` threads once one reactor's
//! accept/parse throughput becomes the bottleneck:
//!
//! ```text
//!            ┌──────────── reactor 0 ────────────┐
//!  accept ──►│ nonblocking listener              │
//!  sockets ─►│   │ admit (global max_conns)      │
//!            │   ├─ keep 1/N locally             │
//!            │   └─ deal N-1/N round-robin ──────┼──► sibling inboxes
//!            └───────────────────────────────────┘    (+ wake byte)
//!            ┌─────────── reactor i (0..N) ──────────────────────────┐
//!            │ per-conn state machine: Reading ─► InFlight ─► Writing│
//!            │   (incremental RequestParser)        ▲        (partial│
//!            │                                      │         writes │
//!            └───── complete parsed requests ───────┼────── resume) ─┘
//!                         │ Work{reactor,token}     │ wakeup (pipe)
//!                         ▼                         │
//!                  request worker pool ─ responses ─┘
//!                    │ (route_begin)       (to the owning reactor's
//!                    │                      completion queue)
//!                    ├─ batched /v1/query ─► Batcher::submit_with
//!                    │     (callback fan-back; no thread waits)
//!                    └─ everything else  ─► served on the worker
//! ```
//!
//! **The fleet.** Every reactor owns its own [`Poller`], connection
//! table, completion queue, and wake pipe; connections never migrate, so
//! there is no cross-reactor locking on the hot path. Reactor 0 holds
//! the (nonblocking) listener and deals admitted connections round-robin
//! to the whole fleet through per-reactor inboxes (rotating listener
//! handoff) — a handed-off socket costs one `Mutex` push plus one wake
//! byte, once per connection lifetime. The shared request worker pool
//! routes each completion back to the owning reactor via its
//! `Work.reactor` index. `reactors == 1` is exactly the pre-sharding
//! single-threaded behavior.
//!
//! Connection lifecycle:
//!
//! * **Reading** — bytes are pulled until `EWOULDBLOCK` and fed to the
//!   shared incremental [`RequestParser`]; a slow-drip client costs a
//!   few buffered bytes, not a thread (each incomplete round bumps the
//!   `parse_stalls` counter, aggregate and per-reactor). A complete
//!   request moves the connection to *InFlight* and clears its readiness
//!   interest (pipelined bytes stay buffered; TCP backpressure throttles
//!   the rest).
//! * **InFlight** — exactly one request per connection is out with the
//!   worker pool; the response comes back over the owning reactor's
//!   completion queue plus a wake byte on its self-pipe.
//! * **Writing** — the serialized response is written as far as the
//!   socket allows; `EWOULDBLOCK` parks the connection on write
//!   readiness and resumes later (partial-write resumption). When the
//!   write finishes, buffered pipelined requests are served before the
//!   connection goes back to waiting on readable.
//!
//! Limits: `max_conns` bounds the fd table *globally* (an atomic
//! admission budget shared by the fleet; beyond it, accepted connections
//! are answered a complete `503` and closed — see
//! [`Reactor::refuse`]); `read_timeout` sweeps idle connections
//! (silent close at a request boundary, `408`/`400` mid-request — same
//! contract as the threaded mode). Every refusal path — over-budget,
//! `set_nonblocking` failure, poller registration failure — answers the
//! 503 and bumps `conns_rejected`; no connection is ever dropped
//! silently. Shutdown wakes every reactor, closes every connection, then
//! joins the worker pool.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Context, Result};
use crate::metrics::{Metrics, ReactorStats};
use crate::util::poll::{Interest, PollEvent, Poller};

use super::batcher::Batcher;
use super::http::{
    rejected_submit_response, route_begin, serialize_response, write_all_deadline, HttpRequest,
    HttpResponse, ParsePhase, ParseStep, RequestParser, Routed,
};
use super::Server;

const LISTENER_TOKEN: u64 = 0;
const WAKE_TOKEN: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Longest a refused connection's 503 write may stall before the
/// reactor gives up on it. The response is tens of bytes — a live peer
/// drains it in one write; only a dead or malicious one hits this.
const REFUSE_WRITE_LIMIT: Duration = Duration::from_millis(250);

/// Event-loop knobs (derived from [`super::http::HttpConfig`]).
#[derive(Clone)]
pub(super) struct ReactorConfig {
    pub(super) workers: usize,
    pub(super) reactors: usize,
    pub(super) max_body: usize,
    pub(super) max_conns: usize,
    pub(super) read_timeout: Duration,
    pub(super) poll_fallback: bool,
}

/// One complete parsed request on its way to the worker pool;
/// `reactor` routes the completion back to the connection's owner.
struct Work {
    reactor: usize,
    token: u64,
    req: HttpRequest,
}

/// One finished response on its way back to its reactor.
struct Completion {
    token: u64,
    resp: HttpResponse,
    keep_alive: bool,
}

type CompletionQueue = Arc<Mutex<Vec<Completion>>>;

/// Freshly accepted connections handed off to a sibling reactor by the
/// listener-owning one (rotating listener handoff).
type Inbox = Arc<Mutex<Vec<TcpStream>>>;

/// Wakes a reactor out of `poll`/`epoll_wait` by writing one byte to
/// its self-pipe. Nonblocking: a full pipe means a wake is already
/// pending, which is all we need.
#[derive(Clone)]
struct Waker {
    pipe: Arc<UnixStream>,
}

impl Waker {
    fn wake(&self) {
        let mut side: &UnixStream = &self.pipe;
        let _ = side.write(&[1u8]);
    }
}

/// How to reach one reactor from outside its thread: push work results
/// or fresh connections, then wake it.
struct ReactorLink {
    completions: CompletionQueue,
    waker: Waker,
    inbox: Inbox,
}

/// Fleet-wide state: the stop flag and the global connection-admission
/// budget (`open` counts admitted-but-not-torn-down connections across
/// every reactor, including ones still in a handoff inbox).
struct Shared {
    stop: AtomicBool,
    open: AtomicUsize,
}

/// Everything a request worker needs to serve and fan back.
struct WorkerCtx {
    server: Arc<Server>,
    batcher: Option<Arc<Batcher>>,
    links: Arc<Vec<ReactorLink>>,
}

/// Owns the reactor + worker threads; joined on [`EventLoopHandle::shutdown`].
pub(super) struct EventLoopHandle {
    shared: Arc<Shared>,
    wakers: Vec<Waker>,
    reactors: Vec<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl EventLoopHandle {
    /// Idempotent: stop every reactor, close every connection, join the
    /// workers. (The batcher is shut down afterwards by the owning
    /// [`super::http::HttpHandle`], once no worker can submit anymore.)
    pub(super) fn shutdown(&mut self) {
        if self.shared.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        for w in &self.wakers {
            w.wake();
        }
        for h in self.reactors.drain(..) {
            let _ = h.join();
        }
        // The reactor threads owned the work senders; with them gone
        // the workers drain the queue and exit.
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for EventLoopHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Start the event loop over an already-bound listener: `cfg.reactors`
/// reactor threads plus `cfg.workers` request workers. Returns once all
/// of them are running. Everything fallible (pollers, wake pipes,
/// registrations) happens before the first thread is spawned, so an
/// error never leaks half a fleet.
pub(super) fn serve_event_loop(
    server: Arc<Server>,
    batcher: Option<Arc<Batcher>>,
    listener: TcpListener,
    cfg: ReactorConfig,
) -> Result<EventLoopHandle> {
    listener.set_nonblocking(true).context("setting the listener nonblocking")?;
    let n_reactors = cfg.reactors.max(1);

    // Per-reactor plumbing, built up front: poller (+ registered wake
    // pipe; reactor 0 also gets the listener), completion queue, inbox.
    let mut pollers = Vec::with_capacity(n_reactors);
    let mut wake_rxs = Vec::with_capacity(n_reactors);
    let mut links = Vec::with_capacity(n_reactors);
    for id in 0..n_reactors {
        let mut poller = Poller::new(cfg.poll_fallback).context("building a readiness poller")?;
        let (wake_rx, wake_tx) = UnixStream::pair().context("creating a reactor wake pipe")?;
        wake_rx.set_nonblocking(true).context("wake pipe nonblocking")?;
        wake_tx.set_nonblocking(true).context("wake pipe nonblocking")?;
        poller
            .register(wake_rx.as_raw_fd(), WAKE_TOKEN, Interest::Read)
            .context("registering the wake pipe")?;
        if id == 0 {
            poller
                .register(listener.as_raw_fd(), LISTENER_TOKEN, Interest::Read)
                .context("registering the listener")?;
        }
        pollers.push(poller);
        wake_rxs.push(wake_rx);
        links.push(ReactorLink {
            completions: Arc::new(Mutex::new(Vec::new())),
            waker: Waker { pipe: Arc::new(wake_tx) },
            inbox: Arc::new(Mutex::new(Vec::new())),
        });
    }
    let links = Arc::new(links);
    let shared = Arc::new(Shared { stop: AtomicBool::new(false), open: AtomicUsize::new(0) });
    let (work_tx, work_rx) = mpsc::channel::<Work>();
    let work_rx = Arc::new(Mutex::new(work_rx));

    let ctx = Arc::new(WorkerCtx { server: server.clone(), batcher, links: links.clone() });
    let mut workers = Vec::with_capacity(cfg.workers);
    for w in 0..cfg.workers {
        let rx = work_rx.clone();
        let ctx = ctx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("http-request-{w}"))
            .spawn(move || worker_loop(rx, ctx))
            .expect("spawn http request worker");
        workers.push(handle);
    }

    let mut listener = Some(listener);
    let mut reactors = Vec::with_capacity(n_reactors);
    for (id, (poller, wake_rx)) in pollers.into_iter().zip(wake_rxs).enumerate() {
        let reactor = Reactor {
            id,
            cfg: cfg.clone(),
            poller,
            listener: if id == 0 { listener.take() } else { None },
            next_handoff: 0,
            conns: HashMap::new(),
            next_token: FIRST_CONN_TOKEN,
            work_tx: work_tx.clone(),
            completions: links[id].completions.clone(),
            inbox: links[id].inbox.clone(),
            links: links.clone(),
            wake_rx,
            shared: shared.clone(),
            metrics: server.metrics(),
            stats: server.metrics().register_reactor(),
        };
        let handle = std::thread::Builder::new()
            .name(format!("http-reactor-{id}"))
            .spawn(move || reactor.run())
            .expect("spawn http reactor");
        reactors.push(handle);
    }
    // The per-reactor clones are the only senders left: when the last
    // reactor exits, the work channel disconnects and the workers drain.
    drop(work_tx);

    let wakers = links.iter().map(|l| l.waker.clone()).collect();
    Ok(EventLoopHandle { shared, wakers, reactors, workers })
}

// ---------------------------------------------------------------------
// Worker pool: complete requests in, completions + a wake byte out.
// ---------------------------------------------------------------------

fn worker_loop(rx: Arc<Mutex<Receiver<Work>>>, ctx: Arc<WorkerCtx>) {
    loop {
        // Hold the receiver lock only while waiting for the next item;
        // a disconnected channel (reactors gone) ends the worker.
        let work = rx.lock().unwrap().recv();
        let work = match work {
            Ok(w) => w,
            Err(_) => break,
        };
        let (reactor, token) = (work.reactor, work.token);
        let ctx2 = ctx.clone();
        // A panicking handler must not shrink the pool or strand the
        // connection: catch, answer 500, keep serving.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            handle_work(ctx2, work)
        }));
        if outcome.is_err() {
            eprintln!("[semcached] request handler panicked; worker recovered");
            ctx.server.metrics().record_http_error();
            complete(&ctx, reactor, token, HttpResponse::error(500, "internal handler error"), false);
        }
    }
}

fn handle_work(ctx: Arc<WorkerCtx>, work: Work) {
    let keep_alive = work.req.keep_alive;
    match route_begin(&ctx.server, ctx.batcher.is_some(), &work.req) {
        Routed::Ready(resp) => complete(&ctx, work.reactor, work.token, resp, keep_alive),
        Routed::BatchedQuery(q) => {
            let batcher = ctx.batcher.as_ref().expect("batched route without a batcher").clone();
            let cb_ctx = ctx.clone();
            let (reactor, token) = (work.reactor, work.token);
            // The worker is free as soon as the submit lands: the
            // dispatcher invokes this callback with the response, which
            // re-enters the owning reactor as a completion + wakeup.
            let submitted = batcher.submit_with(&q, move |qr| {
                let status = super::http::query_response_status(&qr);
                if status >= 400 {
                    cb_ctx.server.metrics().record_http_error();
                }
                let resp = HttpResponse::json(status, &qr.to_json());
                complete(&cb_ctx, reactor, token, resp, keep_alive);
            });
            if let Err(e) = submitted {
                let resp = rejected_submit_response(&ctx.server, &q, &e);
                complete(&ctx, work.reactor, work.token, resp, keep_alive);
            }
        }
    }
}

fn complete(ctx: &WorkerCtx, reactor: usize, token: u64, resp: HttpResponse, keep_alive: bool) {
    let link = &ctx.links[reactor];
    {
        // `unwrap_or_else(into_inner)`: a poisoned queue (reactor thread
        // panicked mid-push) must not cascade panics into the batcher's
        // dispatcher via this callback.
        let mut q = link.completions.lock().unwrap_or_else(|e| e.into_inner());
        q.push(Completion { token, resp, keep_alive });
    }
    link.waker.wake();
}

// ---------------------------------------------------------------------
// The reactor proper.
// ---------------------------------------------------------------------

enum ConnState {
    /// Waiting for (more of) a request.
    Reading,
    /// A complete request is with the worker pool; readiness interest is
    /// cleared until its completion arrives.
    InFlight,
    /// A response is (partially) written; waiting for write readiness.
    Writing,
}

struct Conn {
    stream: TcpStream,
    parser: RequestParser,
    state: ConnState,
    write_buf: Vec<u8>,
    write_pos: usize,
    /// Whether the connection survives the current response.
    keep_alive_after: bool,
    /// Peer closed its write side (half-close): serve what is buffered,
    /// then close after the response.
    saw_eof: bool,
    last_activity: Instant,
    interest: Interest,
}

impl Conn {
    fn new(stream: TcpStream, max_body: usize) -> Self {
        Self {
            stream,
            parser: RequestParser::new(max_body),
            state: ConnState::Reading,
            write_buf: Vec::new(),
            write_pos: 0,
            keep_alive_after: true,
            saw_eof: false,
            last_activity: Instant::now(),
            interest: Interest::Read,
        }
    }
}

enum Verdict {
    Keep,
    Close,
}

struct Reactor {
    id: usize,
    cfg: ReactorConfig,
    poller: Poller,
    /// Only reactor 0 holds the listener; the rest receive their
    /// connections through `inbox`.
    listener: Option<TcpListener>,
    /// Round-robin cursor for dealing accepted connections to the fleet
    /// (listener owner only).
    next_handoff: usize,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    work_tx: Sender<Work>,
    completions: CompletionQueue,
    inbox: Inbox,
    links: Arc<Vec<ReactorLink>>,
    wake_rx: UnixStream,
    shared: Arc<Shared>,
    metrics: Arc<Metrics>,
    /// This reactor's block in the `/v1/metrics` `reactors` array;
    /// bumped alongside the aggregate counters so per-reactor values
    /// always sum to the aggregates.
    stats: Arc<ReactorStats>,
}

impl Reactor {
    fn run(mut self) {
        let mut events: Vec<PollEvent> = Vec::new();
        let mut last_sweep = Instant::now();
        loop {
            if self.shared.stop.load(Ordering::SeqCst) {
                break;
            }
            if self.poller.wait(&mut events, Some(Duration::from_millis(100))).is_err() {
                // A broken poller cannot serve anything; bail out rather
                // than spin. (Never observed outside fd exhaustion.)
                eprintln!("[semcached] reactor {} poller failed; event loop exiting", self.id);
                break;
            }
            if self.shared.stop.load(Ordering::SeqCst) {
                break;
            }
            for ev in events.drain(..) {
                match ev.token {
                    LISTENER_TOKEN if self.listener.is_some() => {
                        if ev.readable || ev.closed {
                            self.accept_ready();
                        }
                    }
                    WAKE_TOKEN => self.drain_wake(),
                    token => self.conn_event(token, ev),
                }
            }
            // Admit handed-off connections even if the wake byte raced
            // ahead of the inbox push; the check is one uncontended lock.
            self.drain_inbox();
            self.pump_completions();
            if last_sweep.elapsed() >= Duration::from_millis(200) {
                self.sweep_idle();
                last_sweep = Instant::now();
            }
        }
        // Teardown: close every connection so the open-connections gauge
        // returns to zero, and release undelivered handoffs' budget.
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            if let Some(conn) = self.conns.remove(&t) {
                self.teardown(conn);
            }
        }
        let leftover: Vec<TcpStream> = std::mem::take(&mut *self.inbox.lock().unwrap());
        for stream in leftover {
            // Admitted into the budget but never opened as a connection:
            // release the slot; no conn_open/closed pair to record.
            self.shared.open.fetch_sub(1, Ordering::SeqCst);
            drop(stream);
        }
    }

    /// Accept until the listener would block, admitting each connection
    /// into the global budget and dealing it round-robin across the
    /// fleet (self included). Listener owner only.
    fn accept_ready(&mut self) {
        loop {
            let accepted = match &self.listener {
                Some(l) => l.accept(),
                None => return,
            };
            match accepted {
                Ok((stream, _)) => {
                    // Claim a budget slot first; only the acceptor
                    // increments, but teardowns decrement concurrently
                    // from every reactor.
                    let prev = self.shared.open.fetch_add(1, Ordering::SeqCst);
                    if prev >= self.cfg.max_conns {
                        self.shared.open.fetch_sub(1, Ordering::SeqCst);
                        // Over the connection budget: answer a complete
                        // 503 and close, instead of growing the fd
                        // table without bound.
                        self.refuse(stream, "connection limit reached");
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        // A connection this reactor cannot drive is
                        // still answered and counted, never dropped on
                        // the floor (the write below copes with a
                        // blocking socket; a 503 fits any send buffer).
                        self.shared.open.fetch_sub(1, Ordering::SeqCst);
                        self.refuse(stream, "connection setup failed");
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let target = self.next_handoff;
                    self.next_handoff = (self.next_handoff + 1) % self.links.len();
                    if target == self.id {
                        self.admit(stream);
                    } else {
                        let link = &self.links[target];
                        link.inbox.lock().unwrap().push(stream);
                        link.waker.wake();
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                // Transient accept failure (e.g. fd exhaustion): retry on
                // the next readiness report instead of spinning.
                Err(_) => break,
            }
        }
    }

    /// Take ownership of an admitted (budget-counted, nonblocking)
    /// connection: register it with this reactor's poller and add it to
    /// the table. Registration failure refunds the budget slot and
    /// answers 503 — the fd-exhaustion case must be visible to the
    /// client and the metrics, not a silent drop.
    fn admit(&mut self, stream: TcpStream) {
        let token = self.next_token;
        self.next_token += 1;
        if self.poller.register(stream.as_raw_fd(), token, Interest::Read).is_err() {
            self.shared.open.fetch_sub(1, Ordering::SeqCst);
            self.refuse(stream, "connection setup failed");
            return;
        }
        self.metrics.record_conn_open();
        self.stats.conn_open();
        self.conns.insert(token, Conn::new(stream, self.cfg.max_body));
    }

    /// Refuse a connection with a best-effort *complete* 503: the whole
    /// response is written (retrying short writes up to
    /// [`REFUSE_WRITE_LIMIT`]) and the write side shut down, so the
    /// client sees a typed refusal rather than a truncated response or
    /// a bare RST. Always recorded as `conns_rejected`.
    fn refuse(&self, stream: TcpStream, reason: &str) {
        self.metrics.record_conn_rejected();
        let resp = HttpResponse::error(503, reason);
        let bytes = serialize_response(&resp, false);
        let mut stream = stream;
        let _ = stream.set_nonblocking(true);
        let _ = write_all_deadline(&mut stream, &bytes, REFUSE_WRITE_LIMIT);
        let _ = stream.shutdown(std::net::Shutdown::Write);
        // Dropping the stream closes it after the FIN.
    }

    /// Admit connections handed off by the listener-owning reactor.
    fn drain_inbox(&mut self) {
        loop {
            // Take the batch under the lock, admit outside it: `admit`
            // can block briefly in `refuse` and must not hold up the
            // acceptor.
            let pending: Vec<TcpStream> = {
                let mut inbox = self.inbox.lock().unwrap();
                if inbox.is_empty() {
                    return;
                }
                std::mem::take(&mut *inbox)
            };
            for stream in pending {
                self.admit(stream);
            }
        }
    }

    fn drain_wake(&mut self) {
        let mut buf = [0u8; 256];
        loop {
            let mut side: &UnixStream = &self.wake_rx;
            match side.read(&mut buf) {
                Ok(0) => break,
                Ok(_) => {}
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => break, // WouldBlock: drained
            }
        }
    }

    fn conn_event(&mut self, token: u64, ev: PollEvent) {
        let mut conn = match self.conns.remove(&token) {
            Some(c) => c,
            None => return,
        };
        let verdict = if ev.closed {
            // Hard error/hangup: any pending write would fail.
            Verdict::Close
        } else {
            let mut v = Verdict::Keep;
            if ev.readable && matches!(conn.state, ConnState::Reading) {
                v = self.drive_read(token, &mut conn);
            }
            if matches!(v, Verdict::Keep)
                && ev.writable
                && matches!(conn.state, ConnState::Writing)
            {
                v = self.drive_write(token, &mut conn);
            }
            v
        };
        match verdict {
            Verdict::Keep => {
                self.conns.insert(token, conn);
            }
            Verdict::Close => self.teardown(conn),
        }
    }

    /// Pull bytes from the socket (bounded per readiness round), feed
    /// the parser, and act on the outcome. Only meaningful in `Reading`
    /// state.
    fn drive_read(&mut self, token: u64, conn: &mut Conn) -> Verdict {
        // Per-round read budget: one firehose client must not pin the
        // reactor in this loop (or grow the parser buffer unboundedly)
        // while every other connection waits. Level-triggered readiness
        // re-reports the fd, so leftover bytes are picked up on the
        // next round — after the fleet got its turn.
        let mut budget: usize = 64 * 1024;
        let mut got_bytes = false;
        while budget > 0 {
            let mut chunk = [0u8; 16384];
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    conn.saw_eof = true;
                    break;
                }
                Ok(n) => {
                    got_bytes = true;
                    budget = budget.saturating_sub(n);
                    conn.parser.push(&chunk[..n]);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => return Verdict::Close,
            }
        }
        if got_bytes {
            conn.last_activity = Instant::now();
        }
        match self.advance_parser(token, conn) {
            Verdict::Close => return Verdict::Close,
            Verdict::Keep => {}
        }
        if got_bytes
            && matches!(conn.state, ConnState::Reading)
            && !matches!(conn.parser.phase(), ParsePhase::Idle)
        {
            // Bytes arrived and the request is still incomplete: a
            // slow-drip (or just slow) client.
            self.metrics.record_parse_stall();
            self.stats.parse_stall();
        }
        if conn.saw_eof && matches!(conn.state, ConnState::Reading) {
            return self.resolve_eof(token, conn);
        }
        Verdict::Keep
    }

    /// The peer finished sending and no request is in flight: resolve
    /// the parser at EOF (same contract as the blocking driver).
    fn resolve_eof(&mut self, token: u64, conn: &mut Conn) -> Verdict {
        match conn.parser.finish_eof() {
            ParseStep::Close | ParseStep::NeedMore => Verdict::Close,
            ParseStep::Request(req) => self.dispatch(token, conn, req),
            ParseStep::Error(resp) => {
                self.metrics.record_http_request();
                self.metrics.record_http_error();
                self.start_write(token, conn, resp, false)
            }
        }
    }

    /// Advance the parser as far as the buffered bytes allow; dispatch
    /// at most one request (per-connection ordering).
    fn advance_parser(&mut self, token: u64, conn: &mut Conn) -> Verdict {
        if !matches!(conn.state, ConnState::Reading) {
            return Verdict::Keep;
        }
        match conn.parser.next_step() {
            ParseStep::NeedMore => {
                self.want_interest(token, conn, Interest::Read);
                Verdict::Keep
            }
            ParseStep::Request(req) => self.dispatch(token, conn, req),
            ParseStep::Close => Verdict::Close,
            ParseStep::Error(resp) => {
                // A malformed request still counts as one request, so
                // http_errors never exceeds http_requests (same
                // accounting as the threaded driver).
                self.metrics.record_http_request();
                self.metrics.record_http_error();
                self.start_write(token, conn, resp, false)
            }
        }
    }

    /// Hand one complete request to the worker pool and park the
    /// connection (no readiness interest until the completion arrives).
    fn dispatch(&mut self, token: u64, conn: &mut Conn, req: HttpRequest) -> Verdict {
        conn.state = ConnState::InFlight;
        conn.last_activity = Instant::now();
        self.want_interest(token, conn, Interest::None);
        if self.work_tx.send(Work { reactor: self.id, token, req }).is_err() {
            // Only possible when the pool is gone (shutdown mid-flight).
            return Verdict::Close;
        }
        Verdict::Keep
    }

    /// Begin (or restart) writing a response on this connection.
    fn start_write(
        &mut self,
        token: u64,
        conn: &mut Conn,
        resp: HttpResponse,
        keep_alive: bool,
    ) -> Verdict {
        // A half-closed peer (saw_eof) gets no *new* requests in, but
        // pipelined input already buffered must still be served — the
        // blocking driver answers every buffered request before closing,
        // and the modes must not diverge. Only the final response (no
        // buffered input left) advertises and performs the close.
        let staying_open = keep_alive && (!conn.saw_eof || conn.parser.has_buffered());
        conn.write_buf = serialize_response(&resp, staying_open);
        conn.write_pos = 0;
        conn.keep_alive_after = staying_open;
        conn.state = ConnState::Writing;
        conn.last_activity = Instant::now();
        self.drive_write(token, conn)
    }

    /// Push response bytes until done or the socket pushes back; resume
    /// from the same offset on the next writable event.
    fn drive_write(&mut self, token: u64, conn: &mut Conn) -> Verdict {
        while conn.write_pos < conn.write_buf.len() {
            match conn.stream.write(&conn.write_buf[conn.write_pos..]) {
                Ok(0) => return Verdict::Close,
                Ok(n) => {
                    conn.write_pos += n;
                    conn.last_activity = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    self.want_interest(token, conn, Interest::Write);
                    return Verdict::Keep;
                }
                Err(_) => return Verdict::Close,
            }
        }
        let _ = conn.stream.flush();
        conn.write_buf.clear();
        conn.write_pos = 0;
        if !conn.keep_alive_after || self.shared.stop.load(Ordering::SeqCst) {
            return Verdict::Close;
        }
        conn.state = ConnState::Reading;
        // Serve pipelined requests already buffered before going back to
        // waiting on readable.
        if let Verdict::Close = self.advance_parser(token, conn) {
            return Verdict::Close;
        }
        if matches!(conn.state, ConnState::Reading) {
            if conn.saw_eof {
                // No more bytes will come: resolve leftover buffered
                // input at EOF (a truncated pipelined request is still
                // answered 400, exactly like the blocking driver).
                return self.resolve_eof(token, conn);
            }
            self.want_interest(token, conn, Interest::Read);
        }
        Verdict::Keep
    }

    /// Apply finished responses from the worker pool / batcher callbacks.
    fn pump_completions(&mut self) {
        let pending: Vec<Completion> = {
            let mut q = self.completions.lock().unwrap_or_else(|e| e.into_inner());
            std::mem::take(&mut *q)
        };
        for c in pending {
            let mut conn = match self.conns.remove(&c.token) {
                Some(conn) => conn,
                None => continue, // connection died while in flight
            };
            if !matches!(conn.state, ConnState::InFlight) {
                // Defensive: a completion for a connection that is not
                // waiting on one is dropped rather than corrupting the
                // write stream.
                self.conns.insert(c.token, conn);
                continue;
            }
            match self.start_write(c.token, &mut conn, c.resp, c.keep_alive) {
                Verdict::Keep => {
                    self.conns.insert(c.token, conn);
                }
                Verdict::Close => self.teardown(conn),
            }
        }
    }

    /// Close connections idle past `read_timeout`. Waiting at a request
    /// boundary closes silently (like the threaded driver's read
    /// timeout); a stall mid-request is answered 408/400 best-effort.
    fn sweep_idle(&mut self) {
        let now = Instant::now();
        let timeout = self.cfg.read_timeout;
        let expired: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| {
                !matches!(c.state, ConnState::InFlight)
                    && now.duration_since(c.last_activity) >= timeout
            })
            .map(|(t, _)| *t)
            .collect();
        for token in expired {
            let mut conn = match self.conns.remove(&token) {
                Some(c) => c,
                None => continue,
            };
            let verdict = match conn.state {
                ConnState::Reading => match conn.parser.stall_response() {
                    None => Verdict::Close, // idle boundary: silent, like the threaded driver
                    Some(resp) => {
                        self.metrics.record_http_request();
                        self.metrics.record_http_error();
                        self.start_write(token, &mut conn, resp, false)
                    }
                },
                // A peer that stopped draining its response.
                ConnState::Writing => Verdict::Close,
                ConnState::InFlight => Verdict::Keep, // filtered out above
            };
            match verdict {
                Verdict::Keep => {
                    self.conns.insert(token, conn);
                }
                Verdict::Close => self.teardown(conn),
            }
        }
    }

    fn want_interest(&mut self, token: u64, conn: &mut Conn, want: Interest) {
        if conn.interest != want
            && self.poller.modify(conn.stream.as_raw_fd(), token, want).is_ok()
        {
            conn.interest = want;
        }
    }

    fn teardown(&mut self, conn: Conn) {
        let _ = self.poller.deregister(conn.stream.as_raw_fd());
        self.shared.open.fetch_sub(1, Ordering::SeqCst);
        self.metrics.record_conn_closed();
        self.stats.conn_closed();
        // Dropping `conn` closes the socket.
    }
}
