//! Upstream resilience: deadlines, retries, circuit breaker, shedding.
//!
//! Sits between the serving workflow ([`super::Server::serve`] /
//! `serve_batch`) and the simulated LLM. Every miss that must go
//! upstream is routed through [`Resilience::call`], which:
//!
//! 1. consults a closed/open/half-open **circuit breaker** — an open
//!    breaker refuses instantly (no upstream attempt) until
//!    `breaker_open_ms` has elapsed, then admits half-open probes and
//!    closes again after `breaker_halfopen_probes` consecutive
//!    successes;
//! 2. enforces an **in-flight cap** (`max_inflight`): excess misses are
//!    shed immediately instead of queueing behind a dying upstream;
//! 3. runs a bounded **retry loop** (`max_retries`) with jittered
//!    exponential backoff, honoring any server-advertised `retry-after`
//!    and never sleeping past the request's **deadline**;
//! 4. propagates the remaining deadline budget into each attempt
//!    ([`SimLlm::call_within`]), so an injected hang costs at most the
//!    budget, not the hang.
//!
//! The caller decides what an [`UpstreamUnavailable`] means: the server
//! degrades to a relaxed-threshold cache answer when one exists
//! (`Outcome::Degraded`), else rejects with
//! [`crate::api::REASON_UPSTREAM_UNAVAILABLE`]. This module never
//! answers from the cache itself — it only brokers upstream access.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::llm::{LlmError, LlmResponse, SimLlm};
use crate::metrics::{BreakerState, Metrics};
use crate::util::Rng;

/// Tuning knobs, mapped 1:1 from the `upstream_*` config keys.
#[derive(Debug, Clone)]
pub struct ResilienceConfig {
    /// Default end-to-end deadline per request, ms (0 = none; requests
    /// override via `deadline_ms`).
    pub deadline_ms: u64,
    /// Retries per miss after the first attempt.
    pub max_retries: u32,
    /// First backoff, ms; doubles per retry (jittered ±50%).
    pub backoff_base_ms: u64,
    /// Backoff ceiling, ms.
    pub backoff_max_ms: u64,
    /// Consecutive failures that trip the breaker open.
    pub breaker_failures: u32,
    /// Open-state hold before half-open probes are admitted, ms.
    pub breaker_open_ms: u64,
    /// Consecutive half-open successes required to close.
    pub breaker_halfopen_probes: u32,
    /// In-flight upstream call cap (0 = uncapped).
    pub max_inflight: usize,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        let c = crate::config::Config::default();
        Self::from_app_config(&c)
    }
}

impl ResilienceConfig {
    pub fn from_app_config(cfg: &crate::config::Config) -> Self {
        Self {
            deadline_ms: cfg.upstream_deadline_ms,
            max_retries: cfg.upstream_max_retries,
            backoff_base_ms: cfg.upstream_backoff_base_ms,
            backoff_max_ms: cfg.upstream_backoff_max_ms,
            breaker_failures: cfg.upstream_breaker_failures,
            breaker_open_ms: cfg.upstream_breaker_open_ms,
            breaker_halfopen_probes: cfg.upstream_breaker_halfopen_probes,
            max_inflight: cfg.upstream_max_inflight,
        }
    }

    /// The absolute deadline for a request accepted at `start`, with the
    /// per-request override taking precedence over the configured
    /// default. `None` = unbounded.
    pub fn deadline_from(&self, start: Instant, override_ms: Option<u64>) -> Option<Instant> {
        let ms = override_ms.unwrap_or(self.deadline_ms);
        if ms == 0 {
            None
        } else {
            Some(start + Duration::from_millis(ms))
        }
    }
}

/// Why an upstream call was not answered.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum UpstreamUnavailable {
    /// The breaker was (or tripped) open.
    BreakerOpen,
    /// The in-flight cap shed this call before any attempt.
    Shed,
    /// The request's deadline ran out (before or between attempts).
    DeadlineExhausted,
    /// Every attempt in the retry budget failed; carries the last error.
    RetriesExhausted(LlmError),
}

impl UpstreamUnavailable {
    pub fn describe(&self) -> String {
        match self {
            UpstreamUnavailable::BreakerOpen => "circuit breaker open".into(),
            UpstreamUnavailable::Shed => "shed at upstream in-flight cap".into(),
            UpstreamUnavailable::DeadlineExhausted => "request deadline exhausted".into(),
            UpstreamUnavailable::RetriesExhausted(e) => format!("retries exhausted ({e})"),
        }
    }
}

/// The result of one resilient upstream call.
#[derive(Debug)]
pub enum UpstreamOutcome {
    Answered(LlmResponse),
    Unavailable(UpstreamUnavailable),
}

struct Breaker {
    state: BreakerState,
    consecutive_failures: u32,
    halfopen_successes: u32,
    opened_at: Option<Instant>,
}

/// The resilience layer. One instance per [`super::Server`]; thread-safe
/// (every serve/dispatch thread calls into the same breaker and cap).
pub struct Resilience {
    cfg: ResilienceConfig,
    metrics: Arc<Metrics>,
    breaker: Mutex<Breaker>,
    inflight: AtomicUsize,
    rng: Mutex<Rng>,
}

struct InflightGuard<'a>(&'a AtomicUsize);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

impl Resilience {
    pub fn new(cfg: ResilienceConfig, metrics: Arc<Metrics>) -> Self {
        Self {
            cfg,
            metrics,
            breaker: Mutex::new(Breaker {
                state: BreakerState::Closed,
                consecutive_failures: 0,
                halfopen_successes: 0,
                opened_at: None,
            }),
            inflight: AtomicUsize::new(0),
            rng: Mutex::new(Rng::new(0xB0FF)),
        }
    }

    pub fn config(&self) -> &ResilienceConfig {
        &self.cfg
    }

    pub fn breaker_state(&self) -> BreakerState {
        self.breaker.lock().unwrap().state
    }

    /// Route one miss upstream under the full resilience policy.
    pub fn call(
        &self,
        llm: &SimLlm,
        question: &str,
        ground_truth: Option<&str>,
        deadline: Option<Instant>,
    ) -> UpstreamOutcome {
        if !self.admit() {
            return UpstreamOutcome::Unavailable(UpstreamUnavailable::BreakerOpen);
        }
        let _guard = match self.try_acquire() {
            Some(g) => g,
            None => {
                self.metrics.record_upstream_shed();
                return UpstreamOutcome::Unavailable(UpstreamUnavailable::Shed);
            }
        };
        let attempts = 1 + self.cfg.max_retries;
        for attempt in 0..attempts {
            let budget_ms = match deadline {
                None => None,
                Some(d) => {
                    let left = d.saturating_duration_since(Instant::now()).as_millis() as u64;
                    if left == 0 {
                        return UpstreamOutcome::Unavailable(
                            UpstreamUnavailable::DeadlineExhausted,
                        );
                    }
                    Some(left)
                }
            };
            if attempt > 0 {
                self.metrics.record_upstream_retry();
            }
            match llm.call_within(question, ground_truth, budget_ms) {
                Ok(resp) => {
                    self.on_success();
                    return UpstreamOutcome::Answered(resp);
                }
                Err(err) => {
                    self.metrics.record_upstream_error();
                    if self.on_failure() {
                        // The breaker tripped on this failure: stop
                        // burning retry budget against a dead upstream.
                        return UpstreamOutcome::Unavailable(UpstreamUnavailable::BreakerOpen);
                    }
                    if attempt + 1 == attempts {
                        return UpstreamOutcome::Unavailable(
                            UpstreamUnavailable::RetriesExhausted(err),
                        );
                    }
                    let wait_ms = self.backoff_ms(attempt, err.retry_after_ms());
                    if let Some(d) = deadline {
                        if Instant::now() + Duration::from_millis(wait_ms) >= d {
                            return UpstreamOutcome::Unavailable(
                                UpstreamUnavailable::DeadlineExhausted,
                            );
                        }
                    }
                    if wait_ms > 0 {
                        std::thread::sleep(Duration::from_millis(wait_ms));
                    }
                }
            }
        }
        unreachable!("retry loop always returns")
    }

    /// Jittered exponential backoff before retry `attempt + 1`, floored
    /// at any server-advertised `retry-after`.
    fn backoff_ms(&self, attempt: u32, retry_after_ms: Option<u64>) -> u64 {
        let exp = self
            .cfg
            .backoff_base_ms
            .saturating_mul(1u64 << attempt.min(20))
            .min(self.cfg.backoff_max_ms);
        let jittered = (exp as f64 * self.rng.lock().unwrap().range_f64(0.5, 1.5)) as u64;
        jittered.max(retry_after_ms.unwrap_or(0))
    }

    fn try_acquire(&self) -> Option<InflightGuard<'_>> {
        if self.cfg.max_inflight == 0 {
            self.inflight.fetch_add(1, Ordering::Relaxed);
            return Some(InflightGuard(&self.inflight));
        }
        let cap = self.cfg.max_inflight;
        let mut cur = self.inflight.load(Ordering::Relaxed);
        loop {
            if cur >= cap {
                return None;
            }
            match self.inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(InflightGuard(&self.inflight)),
                Err(actual) => cur = actual,
            }
        }
    }

    /// May this call proceed upstream? Flips an expired open breaker to
    /// half-open (probing) as a side effect.
    fn admit(&self) -> bool {
        let mut b = self.breaker.lock().unwrap();
        match b.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open => {
                let expired = b
                    .opened_at
                    .map(|t| t.elapsed() >= Duration::from_millis(self.cfg.breaker_open_ms))
                    .unwrap_or(true);
                if expired {
                    b.state = BreakerState::HalfOpen;
                    b.halfopen_successes = 0;
                    self.metrics.record_breaker_transition(BreakerState::HalfOpen);
                    true
                } else {
                    false
                }
            }
        }
    }

    fn on_success(&self) {
        let mut b = self.breaker.lock().unwrap();
        match b.state {
            BreakerState::Closed => b.consecutive_failures = 0,
            BreakerState::HalfOpen => {
                b.halfopen_successes += 1;
                if b.halfopen_successes >= self.cfg.breaker_halfopen_probes {
                    b.state = BreakerState::Closed;
                    b.consecutive_failures = 0;
                    b.opened_at = None;
                    self.metrics.record_breaker_transition(BreakerState::Closed);
                }
            }
            // A success can land while another thread's failure opened
            // the breaker; leave the open state authoritative.
            BreakerState::Open => {}
        }
    }

    /// Record one failed attempt; returns true when this failure tripped
    /// the breaker open.
    fn on_failure(&self) -> bool {
        let mut b = self.breaker.lock().unwrap();
        match b.state {
            BreakerState::Closed => {
                b.consecutive_failures += 1;
                if b.consecutive_failures >= self.cfg.breaker_failures {
                    b.state = BreakerState::Open;
                    b.opened_at = Some(Instant::now());
                    self.metrics.record_breaker_transition(BreakerState::Open);
                    return true;
                }
                false
            }
            BreakerState::HalfOpen => {
                // A failed probe slams the breaker shut for another full
                // open window.
                b.state = BreakerState::Open;
                b.opened_at = Some(Instant::now());
                b.halfopen_successes = 0;
                self.metrics.record_breaker_transition(BreakerState::Open);
                true
            }
            BreakerState::Open => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::{FaultPlan, SimLlmConfig};

    fn fast_cfg() -> ResilienceConfig {
        ResilienceConfig {
            deadline_ms: 0,
            max_retries: 1,
            backoff_base_ms: 0,
            backoff_max_ms: 0,
            breaker_failures: 3,
            breaker_open_ms: 40,
            breaker_halfopen_probes: 2,
            max_inflight: 0,
        }
    }

    fn llm() -> SimLlm {
        SimLlm::new(SimLlmConfig::default())
    }

    #[test]
    fn healthy_upstream_answers_first_attempt() {
        let m = Arc::new(Metrics::new());
        let r = Resilience::new(fast_cfg(), m.clone());
        let llm = llm();
        match r.call(&llm, "q", Some("a"), None) {
            UpstreamOutcome::Answered(resp) => assert_eq!(resp.text, "a"),
            other => panic!("expected answer, got {other:?}"),
        }
        assert_eq!(llm.calls(), 1);
        assert_eq!(m.snapshot().upstream_errors, 0);
        assert_eq!(r.breaker_state(), BreakerState::Closed);
    }

    #[test]
    fn transient_error_is_retried_to_success() {
        let m = Arc::new(Metrics::new());
        let r = Resilience::new(fast_cfg(), m.clone());
        let llm = llm();
        // Call 0 lands in the outage window, call 1 survives.
        llm.set_fault_plan(FaultPlan {
            outage_from_call: 0,
            outage_until_call: 1,
            ..FaultPlan::default()
        });
        match r.call(&llm, "q", Some("a"), None) {
            UpstreamOutcome::Answered(resp) => assert_eq!(resp.text, "a"),
            other => panic!("expected retried answer, got {other:?}"),
        }
        assert_eq!(llm.calls(), 2);
        let s = m.snapshot();
        assert_eq!(s.upstream_errors, 1);
        assert_eq!(s.upstream_retries, 1);
    }

    #[test]
    fn retries_exhausted_carries_last_error() {
        let m = Arc::new(Metrics::new());
        let cfg = ResilienceConfig { breaker_failures: 100, ..fast_cfg() };
        let r = Resilience::new(cfg, m.clone());
        let llm = llm();
        llm.set_fault_plan(FaultPlan::full_outage());
        match r.call(&llm, "q", Some("a"), None) {
            UpstreamOutcome::Unavailable(UpstreamUnavailable::RetriesExhausted(
                LlmError::Outage,
            )) => {}
            other => panic!("expected RetriesExhausted(Outage), got {other:?}"),
        }
        assert_eq!(llm.calls(), 2, "1 attempt + 1 retry");
        assert_eq!(m.snapshot().upstream_errors, 2);
    }

    #[test]
    fn breaker_opens_and_refuses_without_upstream_attempts() {
        let m = Arc::new(Metrics::new());
        let cfg = ResilienceConfig { breaker_open_ms: 60_000, ..fast_cfg() };
        let r = Resilience::new(cfg, m.clone());
        let llm = llm();
        llm.set_fault_plan(FaultPlan::full_outage());
        // breaker_failures = 3: the second call's first failure trips it.
        let _ = r.call(&llm, "q", Some("a"), None);
        let _ = r.call(&llm, "q", Some("a"), None);
        assert_eq!(r.breaker_state(), BreakerState::Open);
        let calls_before = llm.calls();
        match r.call(&llm, "q", Some("a"), None) {
            UpstreamOutcome::Unavailable(UpstreamUnavailable::BreakerOpen) => {}
            other => panic!("expected BreakerOpen, got {other:?}"),
        }
        assert_eq!(llm.calls(), calls_before, "open breaker must not touch the upstream");
        let s = m.snapshot();
        assert_eq!(s.breaker_state, BreakerState::Open);
        assert_eq!(s.breaker_opens, 1);
    }

    #[test]
    fn breaker_recovers_open_to_half_open_to_closed() {
        let m = Arc::new(Metrics::new());
        let r = Resilience::new(fast_cfg(), m.clone());
        let llm = llm();
        llm.set_fault_plan(FaultPlan::full_outage());
        while r.breaker_state() != BreakerState::Open {
            let _ = r.call(&llm, "q", Some("a"), None);
        }
        // Upstream heals; after the open window, probes close the breaker.
        llm.set_fault_plan(FaultPlan::default());
        std::thread::sleep(Duration::from_millis(50));
        for _ in 0..2 {
            match r.call(&llm, "q", Some("a"), None) {
                UpstreamOutcome::Answered(_) => {}
                other => panic!("probe should answer, got {other:?}"),
            }
        }
        assert_eq!(r.breaker_state(), BreakerState::Closed);
        let s = m.snapshot();
        assert_eq!(s.breaker_state, BreakerState::Closed);
        assert!(s.breaker_opens >= 1);
        assert_eq!(s.breaker_half_opens, 1);
        assert_eq!(s.breaker_closes, 1);
    }

    #[test]
    fn failed_half_open_probe_reopens() {
        let m = Arc::new(Metrics::new());
        let r = Resilience::new(fast_cfg(), m.clone());
        let llm = llm();
        llm.set_fault_plan(FaultPlan::full_outage());
        while r.breaker_state() != BreakerState::Open {
            let _ = r.call(&llm, "q", Some("a"), None);
        }
        std::thread::sleep(Duration::from_millis(50));
        // Still down: the probe fails and the breaker slams shut again.
        let _ = r.call(&llm, "q", Some("a"), None);
        assert_eq!(r.breaker_state(), BreakerState::Open);
        assert!(m.snapshot().breaker_opens >= 2);
    }

    #[test]
    fn expired_deadline_refuses_before_any_attempt() {
        let m = Arc::new(Metrics::new());
        let r = Resilience::new(fast_cfg(), m.clone());
        let llm = llm();
        let past = Instant::now() - Duration::from_millis(10);
        match r.call(&llm, "q", Some("a"), Some(past)) {
            UpstreamOutcome::Unavailable(UpstreamUnavailable::DeadlineExhausted) => {}
            other => panic!("expected DeadlineExhausted, got {other:?}"),
        }
        assert_eq!(llm.calls(), 0);
    }

    #[test]
    fn deadline_bounds_injected_hangs() {
        let m = Arc::new(Metrics::new());
        let cfg = ResilienceConfig { max_retries: 0, ..fast_cfg() };
        let r = Resilience::new(cfg, m.clone());
        let llm = llm();
        llm.set_fault_plan(FaultPlan {
            hang_prob: 1.0,
            hang_ms: 120_000,
            ..FaultPlan::default()
        });
        let deadline = Instant::now() + Duration::from_millis(500);
        match r.call(&llm, "q", Some("a"), Some(deadline)) {
            UpstreamOutcome::Unavailable(UpstreamUnavailable::RetriesExhausted(
                LlmError::Timeout { budget_ms },
            )) => assert!(budget_ms <= 500, "budget {budget_ms} > deadline"),
            other => panic!("expected Timeout at the deadline, got {other:?}"),
        }
    }

    #[test]
    fn inflight_cap_sheds_excess_misses() {
        let m = Arc::new(Metrics::new());
        let cfg = ResilienceConfig { max_inflight: 2, ..fast_cfg() };
        let r = Resilience::new(cfg, m.clone());
        let llm = llm();
        // Saturate the cap, then a real call must shed.
        let g1 = r.try_acquire().expect("slot 1");
        let _g2 = r.try_acquire().expect("slot 2");
        assert!(r.try_acquire().is_none(), "cap reached");
        match r.call(&llm, "q", Some("a"), None) {
            UpstreamOutcome::Unavailable(UpstreamUnavailable::Shed) => {}
            other => panic!("expected Shed, got {other:?}"),
        }
        assert_eq!(llm.calls(), 0);
        assert_eq!(m.snapshot().upstream_shed, 1);
        // Releasing a slot readmits traffic.
        drop(g1);
        match r.call(&llm, "q", Some("a"), None) {
            UpstreamOutcome::Answered(_) => {}
            other => panic!("expected answer after release, got {other:?}"),
        }
    }

    #[test]
    fn backoff_honors_retry_after() {
        let m = Arc::new(Metrics::new());
        let cfg = ResilienceConfig { backoff_base_ms: 1, backoff_max_ms: 1, ..fast_cfg() };
        let r = Resilience::new(cfg, m);
        assert!(r.backoff_ms(0, Some(250)) >= 250, "retry-after floors the backoff");
        assert!(r.backoff_ms(0, None) <= 2, "jittered base stays near 1ms");
    }

    #[test]
    fn deadline_from_prefers_request_override() {
        let cfg = ResilienceConfig { deadline_ms: 1_000, ..fast_cfg() };
        let t0 = Instant::now();
        let d = cfg.deadline_from(t0, None).expect("configured default");
        assert_eq!(d, t0 + Duration::from_millis(1_000));
        let d = cfg.deadline_from(t0, Some(200)).expect("override");
        assert_eq!(d, t0 + Duration::from_millis(200));
        let cfg = ResilienceConfig { deadline_ms: 0, ..fast_cfg() };
        assert!(cfg.deadline_from(t0, None).is_none(), "0 = unbounded");
        assert!(cfg.deadline_from(t0, Some(300)).is_some());
    }
}
