//! The `Server`: cache-fronted query handling.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::cache::{CacheConfig, CachedEntry, SemanticCache};
use crate::embedding::Encoder;
use crate::llm::{Judge, JudgeConfig, SimLlm, SimLlmConfig};
use crate::metrics::Metrics;
use crate::workload::{Dataset, QaPair};

/// Server construction knobs.
pub struct ServerConfig {
    pub cache: CacheConfig,
    pub llm: SimLlmConfig,
    pub judge: JudgeConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            cache: CacheConfig::default(),
            llm: SimLlmConfig::default(),
            judge: JudgeConfig::default(),
        }
    }
}

/// Where a reply came from.
#[derive(Debug, Clone, PartialEq)]
pub enum ReplySource {
    /// Served from the semantic cache (similarity score attached).
    Cache { score: f32 },
    /// Fetched from the (simulated) LLM API.
    Llm,
}

/// One answered query with its latency breakdown.
#[derive(Debug, Clone)]
pub struct Reply {
    pub response: String,
    pub source: ReplySource,
    /// End-to-end latency: measured compute + simulated LLM time, ms.
    pub total_ms: f64,
    pub embed_ms: f64,
    pub index_ms: f64,
    /// Simulated upstream latency (0 for cache hits).
    pub llm_ms: f64,
    /// Judge verdict for cache hits when ground truth was provided.
    pub judged_positive: Option<bool>,
    /// Cluster of the cached entry that served a hit.
    pub matched_cluster: Option<u64>,
}

/// Thread-safe serving facade. Clone-cheap via `Arc<Server>`.
pub struct Server {
    encoder: Arc<dyn Encoder>,
    cache: SemanticCache,
    llm: SimLlm,
    judge: Judge,
    metrics: Arc<Metrics>,
    /// Ground-truth answers by cluster (populated from the workload) so
    /// simulated LLM calls return the *right* answer for their cluster.
    ground_truth: RwLock<HashMap<u64, String>>,
    /// Per-request threshold override (adaptive-threshold experiments).
    threshold_override: Mutex<Option<f32>>,
    housekeeping_stop: Arc<AtomicBool>,
}

impl Server {
    pub fn new(encoder: Arc<dyn Encoder>, cfg: ServerConfig) -> Self {
        Self {
            encoder,
            cache: SemanticCache::new(cfg.cache),
            llm: SimLlm::new(cfg.llm),
            judge: Judge::new(cfg.judge),
            metrics: Arc::new(Metrics::new()),
            ground_truth: RwLock::new(HashMap::new()),
            threshold_override: Mutex::new(None),
            housekeeping_stop: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    pub fn cache(&self) -> &SemanticCache {
        &self.cache
    }

    pub fn encoder(&self) -> &dyn Encoder {
        self.encoder.as_ref()
    }

    pub fn llm(&self) -> &SimLlm {
        &self.llm
    }

    /// Override the similarity threshold for subsequent requests
    /// (sweep/adaptive experiments); `None` restores the config value.
    pub fn set_threshold(&self, t: Option<f32>) {
        *self.threshold_override.lock().unwrap() = t;
    }

    pub fn effective_threshold(&self) -> f32 {
        self.threshold_override
            .lock()
            .unwrap()
            .unwrap_or(self.cache.config().threshold)
    }

    /// Pre-populate the cache from the workload's base QA pairs,
    /// batch-encoding questions through the embedding backend
    /// (paper §3.1 "Dataset Preparation and Cache Population").
    pub fn populate(&self, pairs: &[QaPair]) {
        {
            let mut gt = self.ground_truth.write().unwrap();
            for p in pairs {
                gt.insert(p.answer_group, p.answer.clone());
            }
        }
        const CHUNK: usize = 64;
        for chunk in pairs.chunks(CHUNK) {
            let texts: Vec<&str> = chunk.iter().map(|p| p.question.as_str()).collect();
            let embeddings = self.encoder.encode_batch(&texts);
            for (p, e) in chunk.iter().zip(embeddings) {
                self.cache.insert_entry(
                    &e,
                    CachedEntry {
                        question: p.question.clone(),
                        response: p.answer.clone(),
                        cluster: p.answer_group,
                    },
                );
            }
        }
    }

    /// Register ground truth for the whole dataset (answers for novel
    /// test clusters too, so misses insert the right response).
    pub fn register_ground_truth(&self, ds: &Dataset) {
        let mut gt = self.ground_truth.write().unwrap();
        for p in &ds.base {
            gt.insert(p.answer_group, p.answer.clone());
        }
    }

    /// Handle one query through the full workflow. `cluster` is the
    /// ground-truth identity when known (evaluation traces); production
    /// callers pass `None`.
    pub fn handle(&self, text: &str, cluster: Option<u64>) -> Reply {
        self.metrics.record_request();
        let threshold = self.effective_threshold();

        // 1. Embed (measured).
        let t0 = Instant::now();
        let embedding = self.encoder.encode_text(text);
        let embed_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.metrics.record_embedding(crate::llm::approx_tokens(text));
        self.metrics.observe_embed_ms(embed_ms);

        // 2. ANN lookup (measured).
        let t1 = Instant::now();
        let hit = self.cache.lookup_with_threshold(&embedding, threshold);
        let index_ms = t1.elapsed().as_secs_f64() * 1e3;
        self.metrics.observe_index_ms(index_ms);

        if let Some(hit) = hit {
            // 3a. Cache hit: validate when ground truth is available.
            self.metrics.record_hit();
            let judged = cluster.map(|c| {
                let ok = self.judge.validate(c, hit.entry.cluster);
                self.metrics.record_judgement(ok);
                ok
            });
            let total_ms = embed_ms + index_ms;
            self.metrics.observe_total_ms(total_ms);
            return Reply {
                response: hit.entry.response.clone(),
                source: ReplySource::Cache { score: hit.score },
                total_ms,
                embed_ms,
                index_ms,
                llm_ms: 0.0,
                judged_positive: judged,
                matched_cluster: Some(hit.entry.cluster),
            };
        }

        // 3b. Miss: call the (simulated) LLM, insert, reply.
        self.metrics.record_miss();
        let ground_truth = cluster.and_then(|c| {
            self.ground_truth.read().unwrap().get(&c).cloned()
        });
        let resp = self.llm.call(text, ground_truth.as_deref());
        self.metrics.record_llm_call(resp.input_tokens, resp.output_tokens);
        self.metrics.observe_llm_ms(resp.latency_ms);

        let t2 = Instant::now();
        self.cache.insert_entry(
            &embedding,
            CachedEntry {
                question: text.to_string(),
                response: resp.text.clone(),
                cluster: cluster.unwrap_or(0),
            },
        );
        let insert_ms = t2.elapsed().as_secs_f64() * 1e3;

        let total_ms = embed_ms + index_ms + resp.latency_ms + insert_ms;
        self.metrics.observe_total_ms(total_ms);
        Reply {
            response: resp.text,
            source: ReplySource::Llm,
            total_ms,
            embed_ms,
            index_ms,
            llm_ms: resp.latency_ms,
            judged_positive: None,
            matched_cluster: None,
        }
    }

    /// The traditional (no-cache) path: always call the LLM. Used for the
    /// Figure 2/3 baselines.
    pub fn handle_without_cache(&self, text: &str, cluster: Option<u64>) -> Reply {
        let ground_truth =
            cluster.and_then(|c| self.ground_truth.read().unwrap().get(&c).cloned());
        let resp = self.llm.call(text, ground_truth.as_deref());
        Reply {
            response: resp.text,
            source: ReplySource::Llm,
            total_ms: resp.latency_ms,
            embed_ms: 0.0,
            index_ms: 0.0,
            llm_ms: resp.latency_ms,
            judged_positive: None,
            matched_cluster: None,
        }
    }

    /// Spawn the housekeeping thread (TTL sweep + index rebuild check).
    /// Returns a guard; dropping it stops the thread.
    pub fn start_housekeeping(self: &Arc<Self>, interval: Duration) -> HousekeepingGuard {
        let stop = self.housekeeping_stop.clone();
        stop.store(false, Ordering::SeqCst);
        let server = self.clone();
        let handle = std::thread::Builder::new()
            .name("housekeeping".into())
            .spawn(move || {
                while !server.housekeeping_stop.load(Ordering::SeqCst) {
                    std::thread::sleep(interval);
                    server.cache.housekeep();
                }
            })
            .expect("spawn housekeeping");
        HousekeepingGuard { stop: self.housekeeping_stop.clone(), handle: Some(handle) }
    }
}

/// Stops the housekeeping thread on drop.
pub struct HousekeepingGuard {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Drop for HousekeepingGuard {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::NativeEncoder;
    use crate::runtime::ModelParams;
    use crate::workload::{DatasetConfig, WorkloadGenerator};

    fn small_encoder() -> Arc<dyn Encoder> {
        let mut p = ModelParams::default();
        p.layers = 1;
        p.vocab_size = 1024;
        p.dim = 96;
        p.hidden = 192;
        p.heads = 4;
        Arc::new(NativeEncoder::new(p))
    }

    fn server() -> Arc<Server> {
        Arc::new(Server::new(small_encoder(), ServerConfig::default()))
    }

    #[test]
    fn miss_then_hit_same_query() {
        let s = server();
        let r1 = s.handle("how do i reset my password", None);
        assert_eq!(r1.source, ReplySource::Llm);
        let r2 = s.handle("how do i reset my password", None);
        assert!(matches!(r2.source, ReplySource::Cache { .. }));
        assert_eq!(r2.response, r1.response, "cached response equals original");
        assert!(r2.total_ms < r1.total_ms, "cache path faster than llm path");
        let m = s.metrics().snapshot();
        assert_eq!(m.requests, 2);
        assert_eq!(m.cache_hits, 1);
        assert_eq!(m.llm_calls, 1);
    }

    #[test]
    fn paraphrase_hits_and_is_judged_positive() {
        let s = server();
        let r1 = s.handle("how do i reset my password", Some(42));
        assert_eq!(r1.source, ReplySource::Llm);
        let r2 = s.handle("how can i reset my password", Some(42));
        assert!(matches!(r2.source, ReplySource::Cache { .. }), "paraphrase should hit");
        assert_eq!(r2.judged_positive, Some(true));
        assert_eq!(r2.matched_cluster, Some(42));
    }

    #[test]
    fn populate_then_serve_ground_truth() {
        let s = server();
        let ds = WorkloadGenerator::new(3).generate(&DatasetConfig::tiny());
        s.populate(&ds.base);
        assert_eq!(s.cache().len(), ds.base.len());
        // Exact cached question must hit and return its stored answer.
        let p = &ds.base[0];
        let r = s.handle(&p.question, Some(p.answer_group));
        assert!(matches!(r.source, ReplySource::Cache { .. }));
        assert_eq!(r.response, p.answer);
        assert_eq!(r.judged_positive, Some(true));
    }

    #[test]
    fn without_cache_baseline_always_calls_llm() {
        let s = server();
        for _ in 0..3 {
            let r = s.handle_without_cache("same question every time", None);
            assert_eq!(r.source, ReplySource::Llm);
            assert!(r.llm_ms > 0.0);
        }
    }

    #[test]
    fn threshold_override_changes_gating() {
        let s = server();
        s.handle("tell me about the acme laptop", Some(1));
        // An unrelated query under an absurdly lenient threshold hits.
        s.set_threshold(Some(-1.0));
        let r = s.handle("completely different topic entirely", Some(2));
        assert!(matches!(r.source, ReplySource::Cache { .. }));
        assert_eq!(r.judged_positive, Some(false), "wrong-cluster hit judged negative");
        s.set_threshold(None);
        assert_eq!(s.effective_threshold(), 0.8);
    }

    #[test]
    fn housekeeping_thread_runs_and_stops() {
        let s = server();
        let guard = s.start_housekeeping(Duration::from_millis(5));
        std::thread::sleep(Duration::from_millis(30));
        drop(guard); // must join cleanly
    }

    #[test]
    fn concurrent_handles_are_safe() {
        let s = server();
        let mut joins = Vec::new();
        for t in 0..4 {
            let s = s.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..20 {
                    s.handle(&format!("thread {t} query {i}"), None);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(s.metrics().snapshot().requests, 80);
    }
}
