//! The `Server`: cache-fronted query handling over the typed v1 API.
//!
//! [`Server::serve`] answers one [`QueryRequest`] through the full
//! workflow (embed → ANN lookup → hit | LLM + insert) and
//! [`Server::serve_batch`] pipelines a whole batch (chunked batch
//! embedding, parallel fan-out over a scoped worker pool, deterministic
//! in-input-order merge). The pre-v1 `handle`/`handle_batch` surface is
//! kept as thin shims that build a request and flatten the response
//! back into a [`Reply`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::api::{
    AdminRequest, AdminResponse, LatencyBreakdown, Outcome, QueryRequest, QueryResponse,
    REASON_UPSTREAM_UNAVAILABLE,
};
use crate::cache::{CacheConfig, CachedEntry, SemanticCache};
use crate::coordinator::batcher::{
    BatchConfig, Batcher, BatchExecutor, MAX_BATCH_SIZE_LIMIT, MAX_WAIT_US_LIMIT,
};
use crate::coordinator::resilience::{
    Resilience, ResilienceConfig, UpstreamOutcome, UpstreamUnavailable,
};
use crate::embedding::Encoder;
use crate::error::{bail, Result};
use crate::json::{obj, Value};
use crate::llm::{Judge, JudgeConfig, SimLlm, SimLlmConfig};
use crate::metrics::Metrics;
use crate::persist::{PersistConfig, Persistence, RecoveryReport, SnapshotStats};
use crate::workload::{Dataset, QaPair};

/// Server construction knobs.
#[derive(Clone)]
pub struct ServerConfig {
    pub cache: CacheConfig,
    pub llm: SimLlmConfig,
    pub judge: JudgeConfig,
    /// Worker threads used by [`Server::serve_batch`].
    pub workers: usize,
    /// Cross-request micro-batching window policy, used by the batcher
    /// spawned via [`Server::start_batcher`] (the HTTP front-end's
    /// default query path).
    pub batch: BatchConfig,
    /// Durability settings; `None` serves purely in memory (the default).
    /// With `Some`, [`Server::try_new`] recovers state from the data dir
    /// at startup and journals every cache mutation.
    pub persist: Option<PersistConfig>,
    /// Upstream fault policy: deadlines, retries, breaker, shedding
    /// (see [`crate::coordinator::resilience`]).
    pub resilience: ResilienceConfig,
    /// Relaxed similarity gate used to answer from the cache while the
    /// upstream is unavailable (degraded mode). Must be no stricter than
    /// useful — a miss at the normal gate is retried at this one.
    pub degraded_threshold: f32,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            cache: CacheConfig::default(),
            llm: SimLlmConfig::default(),
            judge: JudgeConfig::default(),
            workers: 4,
            batch: BatchConfig::default(),
            persist: None,
            resilience: ResilienceConfig::default(),
            degraded_threshold: crate::config::Config::default().degraded_threshold,
        }
    }
}

impl ServerConfig {
    /// A validating builder:
    /// `ServerConfig::builder().workers(8).build()?`.
    pub fn builder() -> ServerConfigBuilder {
        ServerConfigBuilder { cfg: ServerConfig::default() }
    }

    /// Validate this config and every nested component config.
    pub fn validate(&self) -> Result<()> {
        self.cache.validate()?;
        self.llm.validate()?;
        if self.workers == 0 {
            bail!("server workers must be >= 1");
        }
        self.batch.validate()?;
        if let Some(p) = &self.persist {
            if p.snapshot_interval_secs == 0 {
                bail!("snapshot_interval_secs must be >= 1");
            }
        }
        if !self.degraded_threshold.is_finite()
            || !(-1.0..=1.0).contains(&self.degraded_threshold)
        {
            bail!(
                "degraded_threshold must be a finite cosine in [-1, 1], got {}",
                self.degraded_threshold
            );
        }
        if self.resilience.breaker_failures == 0 {
            bail!("upstream breaker_failures must be >= 1");
        }
        if self.resilience.breaker_halfopen_probes == 0 {
            bail!("upstream breaker_halfopen_probes must be >= 1");
        }
        Ok(())
    }

    /// Assemble a validated server config from the app-level
    /// [`crate::config::Config`] (shared by both binaries).
    pub fn from_app_config(cfg: &crate::config::Config) -> Result<ServerConfig> {
        ServerConfig::builder()
            .cache(CacheConfig::from_app_config(cfg)?)
            .llm(SimLlmConfig::from_app_config(cfg))
            .judge(JudgeConfig::default())
            .workers(cfg.workers)
            // The app-level `max_batch`/`batch_window_us` keys predate
            // the request batcher (they also tune the embedding
            // micro-batcher), so out-of-range values are clamped here
            // rather than rejected — a config that started a pre-batcher
            // daemon must keep starting one. The dedicated
            // `semcached serve --batch-*` flags validate strictly.
            .batch(BatchConfig {
                max_batch_size: cfg.max_batch.clamp(1, MAX_BATCH_SIZE_LIMIT),
                max_wait_us: cfg.batch_window_us.min(MAX_WAIT_US_LIMIT),
                ..BatchConfig::default()
            })
            .persist(PersistConfig::from_app_config(cfg))
            .resilience(ResilienceConfig::from_app_config(cfg))
            .degraded_threshold(cfg.degraded_threshold)
            .build()
    }
}

/// Builder for [`ServerConfig`]; `build` validates the result.
#[derive(Clone)]
pub struct ServerConfigBuilder {
    cfg: ServerConfig,
}

impl ServerConfigBuilder {
    pub fn cache(mut self, cache: CacheConfig) -> Self {
        self.cfg.cache = cache;
        self
    }

    pub fn llm(mut self, llm: SimLlmConfig) -> Self {
        self.cfg.llm = llm;
        self
    }

    pub fn judge(mut self, judge: JudgeConfig) -> Self {
        self.cfg.judge = judge;
        self
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    pub fn batch(mut self, batch: BatchConfig) -> Self {
        self.cfg.batch = batch;
        self
    }

    pub fn persist(mut self, persist: Option<PersistConfig>) -> Self {
        self.cfg.persist = persist;
        self
    }

    pub fn resilience(mut self, resilience: ResilienceConfig) -> Self {
        self.cfg.resilience = resilience;
        self
    }

    pub fn degraded_threshold(mut self, t: f32) -> Self {
        self.cfg.degraded_threshold = t;
        self
    }

    pub fn build(self) -> Result<ServerConfig> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

/// Upper bound on texts per unit of batch work: each worker encodes one
/// chunk through `Encoder::encode_batch` (amortizing the embedding call
/// exactly like [`Server::populate`] does) before fanning its lookups
/// out. Small batches use smaller chunks so the pool still spreads the
/// work across every worker.
const BATCH_CHUNK: usize = 32;

/// Threshold-override encoding for the legacy global override: bit 32
/// set means "override present, f32 bits in the low word".
const OVERRIDE_SET: u64 = 1 << 32;

/// Where a reply came from (pre-v1 surface; see [`Outcome`]).
#[derive(Debug, Clone, PartialEq)]
pub enum ReplySource {
    /// Served from the semantic cache (similarity score attached).
    Cache { score: f32 },
    /// Fetched from the (simulated) LLM API.
    Llm,
}

/// One answered query with its latency breakdown (pre-v1 surface; the
/// typed API returns [`QueryResponse`] instead).
#[derive(Debug, Clone)]
pub struct Reply {
    pub response: String,
    pub source: ReplySource,
    /// End-to-end latency: measured compute + simulated LLM time, ms.
    pub total_ms: f64,
    pub embed_ms: f64,
    pub index_ms: f64,
    /// Simulated upstream latency (0 for cache hits).
    pub llm_ms: f64,
    /// Judge verdict for cache hits when ground truth was provided.
    pub judged_positive: Option<bool>,
    /// Cluster of the cached entry that served a hit.
    pub matched_cluster: Option<u64>,
}

impl Reply {
    /// Flatten a typed [`QueryResponse`] into the pre-v1 reply shape
    /// (`Rejected` outcomes map to the LLM source with an empty body).
    pub fn from_response(resp: QueryResponse) -> Self {
        let source = match resp.outcome {
            Outcome::Hit { score, .. } | Outcome::Degraded { score, .. } => {
                ReplySource::Cache { score }
            }
            Outcome::Miss { .. } | Outcome::Rejected { .. } => ReplySource::Llm,
        };
        Self {
            response: resp.response,
            source,
            total_ms: resp.latency.total_ms,
            embed_ms: resp.latency.embed_ms,
            index_ms: resp.latency.index_ms,
            llm_ms: resp.latency.llm_ms,
            judged_positive: resp.judged_positive,
            matched_cluster: resp.matched_cluster,
        }
    }
}

/// Thread-safe serving facade. Clone-cheap via `Arc<Server>`.
pub struct Server {
    encoder: Arc<dyn Encoder>,
    cache: SemanticCache,
    llm: SimLlm,
    judge: Judge,
    metrics: Arc<Metrics>,
    /// Worker-pool width for the batch pipeline.
    workers: usize,
    /// Window policy handed to batchers spawned off this server.
    batch_cfg: BatchConfig,
    /// Ground-truth answers by cluster (populated from the workload) so
    /// simulated LLM calls return the *right* answer for their cluster.
    ground_truth: RwLock<HashMap<u64, String>>,
    /// Legacy global threshold override (see [`Server::set_threshold`]);
    /// 0 = unset, else `OVERRIDE_SET | f32 bits`. Per-request options
    /// are the v1 way to vary the gate.
    threshold_override: AtomicU64,
    housekeeping_stop: Arc<AtomicBool>,
    snapshot_stop: Arc<AtomicBool>,
    /// Durability engine when serving with a data dir.
    persist: Option<Arc<Persistence>>,
    /// What startup recovery restored (all-zero without persistence).
    recovery: RecoveryReport,
    /// Upstream fault policy: every miss goes through here.
    resilience: Resilience,
    /// Relaxed gate for degraded-mode cache answers.
    degraded_threshold: f32,
}

impl Server {
    /// Build an in-memory server. Panics only if `cfg.persist` is set
    /// and its data dir is unusable — construction with persistence
    /// should go through [`Server::try_new`] instead.
    pub fn new(encoder: Arc<dyn Encoder>, cfg: ServerConfig) -> Self {
        Self::try_new(encoder, cfg).expect("in-memory server construction cannot fail")
    }

    /// Build a server, recovering persisted state first when
    /// `cfg.persist` is set (snapshot load + WAL replay; see
    /// [`crate::persist`]). Fails only on unusable data dirs — corrupt
    /// WAL/snapshot *contents* degrade to partial recovery, not errors.
    pub fn try_new(encoder: Arc<dyn Encoder>, cfg: ServerConfig) -> Result<Self> {
        let metrics = Arc::new(Metrics::new());
        let (cache, persist, recovery) = match &cfg.persist {
            Some(pcfg) => {
                let (cache, p, report) = Persistence::open(
                    pcfg,
                    cfg.cache.clone(),
                    Arc::new(crate::store::SystemClock),
                    metrics.clone(),
                )?;
                (cache, Some(p), report)
            }
            None => (SemanticCache::new(cfg.cache.clone()), None, RecoveryReport::default()),
        };
        Ok(Self {
            encoder,
            cache,
            llm: SimLlm::new(cfg.llm),
            judge: Judge::new(cfg.judge),
            resilience: Resilience::new(cfg.resilience, metrics.clone()),
            degraded_threshold: cfg.degraded_threshold,
            metrics,
            workers: cfg.workers.max(1),
            batch_cfg: cfg.batch,
            ground_truth: RwLock::new(HashMap::new()),
            threshold_override: AtomicU64::new(0),
            housekeeping_stop: Arc::new(AtomicBool::new(false)),
            snapshot_stop: Arc::new(AtomicBool::new(false)),
            persist,
            recovery,
        })
    }

    /// The durability engine, when serving with a data dir.
    pub fn persistence(&self) -> Option<Arc<Persistence>> {
        self.persist.clone()
    }

    /// What startup recovery restored (all-zero without persistence).
    pub fn recovery(&self) -> &RecoveryReport {
        &self.recovery
    }

    /// Write a durability snapshot now (admin `snapshot` verb and the
    /// periodic snapshotter both route through here).
    pub fn snapshot_now(&self) -> Result<SnapshotStats> {
        match &self.persist {
            Some(p) => p.snapshot(&self.cache),
            None => bail!("snapshot requires the daemon to serve with --data-dir"),
        }
    }

    /// Spawn the periodic snapshot thread (no-op without persistence).
    /// Returns a guard; dropping it stops the thread promptly (the wait
    /// is sliced so a long interval never delays shutdown).
    pub fn start_snapshotter(self: &Arc<Self>, interval: Duration) -> SnapshotGuard {
        let stop = self.snapshot_stop.clone();
        stop.store(false, Ordering::SeqCst);
        let server = self.clone();
        let handle = std::thread::Builder::new()
            .name("snapshotter".into())
            .spawn(move || {
                let tick = Duration::from_millis(50).min(interval);
                let mut elapsed = Duration::ZERO;
                while !server.snapshot_stop.load(Ordering::SeqCst) {
                    std::thread::sleep(tick);
                    elapsed += tick;
                    if elapsed >= interval {
                        elapsed = Duration::ZERO;
                        if server.persist.is_some() {
                            if let Err(e) = server.snapshot_now() {
                                eprintln!("semcache: periodic snapshot failed: {e:#}");
                            }
                        }
                    }
                }
            })
            .expect("spawn snapshotter");
        SnapshotGuard { stop: self.snapshot_stop.clone(), handle: Some(handle) }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    pub fn cache(&self) -> &SemanticCache {
        &self.cache
    }

    pub fn encoder(&self) -> &dyn Encoder {
        self.encoder.as_ref()
    }

    pub fn llm(&self) -> &SimLlm {
        &self.llm
    }

    /// The upstream resilience layer (breaker state, policy knobs).
    pub fn resilience(&self) -> &Resilience {
        &self.resilience
    }

    /// The relaxed similarity gate used for degraded-mode answers.
    pub fn degraded_threshold(&self) -> f32 {
        self.degraded_threshold
    }

    /// The micro-batching window policy this server was built with.
    pub fn batch_config(&self) -> &BatchConfig {
        &self.batch_cfg
    }

    /// Spawn a cross-request micro-batching engine over this server
    /// (see [`crate::coordinator::batcher`]): concurrent callers
    /// `submit` single requests, the batcher coalesces them into
    /// [`Server::serve_batch`] calls under the configured
    /// (max_batch_size, max_wait_us) window. This is the HTTP
    /// front-end's default query path.
    pub fn start_batcher(self: &Arc<Self>) -> Result<Arc<Batcher>> {
        Batcher::start(self.clone(), self.metrics(), self.batch_cfg.clone())
    }

    /// [`Server::start_batcher`] with an explicit dispatcher-shard
    /// count, overriding the server's configured window policy:
    /// submissions are hash-routed on their coalescing key across
    /// `dispatchers` dispatcher threads (clamped to
    /// `1..=`[`crate::coordinator::batcher::MAX_DISPATCHERS_LIMIT`]), so
    /// a hot key can never serialize the others. The HTTP front-end
    /// wires `HttpConfig::dispatchers` through here.
    pub fn start_batcher_sharded(self: &Arc<Self>, dispatchers: usize) -> Result<Arc<Batcher>> {
        let cfg = BatchConfig {
            dispatchers: dispatchers.clamp(1, crate::coordinator::batcher::MAX_DISPATCHERS_LIMIT),
            ..self.batch_cfg.clone()
        };
        Batcher::start(self.clone(), self.metrics(), cfg)
    }

    /// Override the similarity threshold for every subsequent request;
    /// `None` restores the config value.
    #[deprecated(
        since = "0.2.0",
        note = "use QueryRequest::with_threshold for per-request thresholds"
    )]
    pub fn set_threshold(&self, t: Option<f32>) {
        let enc = match t {
            Some(v) => OVERRIDE_SET | v.to_bits() as u64,
            None => 0,
        };
        self.threshold_override.store(enc, Ordering::Relaxed);
    }

    /// The threshold used when a request carries no per-request override.
    pub fn effective_threshold(&self) -> f32 {
        let enc = self.threshold_override.load(Ordering::Relaxed);
        if enc & OVERRIDE_SET != 0 {
            f32::from_bits(enc as u32)
        } else {
            self.cache.config().threshold
        }
    }

    /// Pre-populate the cache from the workload's base QA pairs,
    /// batch-encoding questions through the embedding backend
    /// (paper §3.1 "Dataset Preparation and Cache Population").
    pub fn populate(&self, pairs: &[QaPair]) {
        {
            let mut gt = self.ground_truth.write().unwrap();
            for p in pairs {
                gt.insert(p.answer_group, p.answer.clone());
            }
        }
        const CHUNK: usize = 64;
        for chunk in pairs.chunks(CHUNK) {
            let texts: Vec<&str> = chunk.iter().map(|p| p.question.as_str()).collect();
            let embeddings = self.encoder.encode_batch(&texts);
            for (p, e) in chunk.iter().zip(embeddings) {
                self.cache
                    .try_insert_entry(
                        &e,
                        CachedEntry {
                            question: p.question.clone(),
                            response: p.answer.clone(),
                            cluster: p.answer_group,
                            latency_ms: 0.0,
                        },
                    )
                    .expect("populate insert (encoder produced an embedding)");
            }
        }
    }

    /// Register ground truth for the whole dataset (answers for novel
    /// test clusters too, so misses insert the right response).
    pub fn register_ground_truth(&self, ds: &Dataset) {
        let mut gt = self.ground_truth.write().unwrap();
        for p in &ds.base {
            gt.insert(p.answer_group, p.answer.clone());
        }
    }

    /// Serve one typed request through the full workflow. This is the
    /// transport-agnostic core every front-end routes through: the
    /// in-process [`Server::handle`] shim, [`Server::serve_batch`], and
    /// the `semcached` HTTP daemon ([`crate::coordinator::http`]).
    pub fn serve(&self, req: &QueryRequest) -> QueryResponse {
        let accepted = Instant::now();
        self.metrics.record_request();
        if let Err(e) = req.validate() {
            self.metrics.record_rejected();
            return QueryResponse::rejected(req, format!("{e:#}"));
        }

        // 1. Embed (measured): memo tier first (unless the request opts
        // out), cold forward pass otherwise.
        let t0 = Instant::now();
        let outcome = self
            .encoder
            .encode_batch_tracked(&[req.text.as_str()], req.options.embed_bypass)
            .pop()
            .expect("one embedding");
        let embed_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.metrics.record_embedding(crate::llm::approx_tokens(&req.text));
        self.metrics.observe_embed_ms(embed_ms);
        self.metrics.record_embed_cache(outcome.memo_hit);
        if outcome.memo_hit {
            self.metrics.observe_embed_memo_ms(embed_ms);
        }

        let deadline = self.resilience.config().deadline_from(accepted, req.options.deadline_ms);
        self.serve_embedded(req, &outcome.embedding, embed_ms, outcome.memo_hit, deadline)
    }

    /// Steps 2..3 of the workflow for a request whose embedding is
    /// already computed (`embed_ms` is the — possibly amortized — cost
    /// attributed to it). Shared by [`Server::serve`] and the batch
    /// workers. The request is assumed validated. `deadline` is the
    /// absolute budget propagated from where the request was accepted
    /// (the HTTP edge via the batcher's enqueue instant, or `serve()`
    /// entry); only the upstream leg of a miss consults it.
    fn serve_embedded(
        &self,
        req: &QueryRequest,
        embedding: &[f32],
        embed_ms: f64,
        embed_cached: bool,
        deadline: Option<Instant>,
    ) -> QueryResponse {
        // The request's `client_tag` selects the tenant namespace; the
        // similarity gate resolves per-request override → tenant
        // override → server-wide threshold.
        let tenant = crate::tenancy::normalize_tag(req.client_tag.as_deref());
        let threshold = req
            .options
            .threshold
            .or_else(|| self.cache.tenant_threshold(tenant))
            .unwrap_or_else(|| self.effective_threshold());

        // 2. ANN lookup (measured), scoped to the tenant's partitions.
        let t1 = Instant::now();
        let hit = self.cache.lookup_with_opts_for(tenant, embedding, threshold, req.options.top_k);
        let index_ms = t1.elapsed().as_secs_f64() * 1e3;
        self.metrics.observe_index_ms(index_ms);
        if self.cache.config().quantized_scan && !crate::util::scalar_kernels_forced() {
            self.metrics.record_quantized_lookup();
        }

        if let Some(hit) = hit {
            // 3a. Cache hit: validate when ground truth is available.
            self.metrics.record_hit();
            let judged = req.cluster.map(|c| {
                let ok = self.judge.validate(c, hit.entry.cluster);
                self.metrics.record_judgement(ok);
                ok
            });
            let total_ms = embed_ms + index_ms;
            self.metrics.observe_total_ms(total_ms);
            return QueryResponse {
                response: hit.entry.response.clone(),
                outcome: Outcome::Hit { score: hit.score, entry_id: hit.id },
                latency: LatencyBreakdown {
                    total_ms,
                    embed_ms,
                    index_ms,
                    llm_ms: 0.0,
                    embed_cached,
                    degraded: false,
                },
                judged_positive: judged,
                matched_cluster: Some(hit.entry.cluster),
                client_tag: req.client_tag.clone(),
            };
        }

        // 3b. Miss: go upstream through the resilience layer (deadline,
        // retries, breaker, shedding), insert, reply. An unavailable
        // upstream degrades to a relaxed-threshold cache answer instead.
        let ground_truth =
            req.cluster.and_then(|c| self.ground_truth.read().unwrap().get(&c).cloned());
        let resp = match self.resilience.call(
            &self.llm,
            &req.text,
            ground_truth.as_deref(),
            deadline,
        ) {
            UpstreamOutcome::Answered(resp) => resp,
            UpstreamOutcome::Unavailable(why) => {
                return self.serve_degraded(req, embedding, embed_ms, index_ms, embed_cached, tenant, &why);
            }
        };
        self.metrics.record_miss();
        self.metrics.record_llm_call(resp.input_tokens, resp.output_tokens);
        self.metrics.observe_llm_ms(resp.latency_ms);

        let t2 = Instant::now();
        let inserted = self.cache.try_insert_entry_ttl_for(
            tenant,
            embedding,
            CachedEntry {
                question: req.text.clone(),
                response: resp.text.clone(),
                cluster: req.cluster.unwrap_or(0),
                // Cost-aware eviction scores entries by the simulated
                // upstream latency a future hit on them would save.
                latency_ms: resp.latency_ms,
            },
            req.options.ttl_ms,
        );
        let insert_ms = t2.elapsed().as_secs_f64() * 1e3;

        let outcome = match inserted {
            Ok(id) => Outcome::Miss { inserted_id: id },
            Err(e) => {
                self.metrics.record_rejected();
                Outcome::Rejected { reason: format!("{e:#}") }
            }
        };
        let total_ms = embed_ms + index_ms + resp.latency_ms + insert_ms;
        self.metrics.observe_total_ms(total_ms);
        QueryResponse {
            response: resp.text,
            outcome,
            latency: LatencyBreakdown {
                total_ms,
                embed_ms,
                index_ms,
                llm_ms: resp.latency_ms,
                embed_cached,
                degraded: false,
            },
            judged_positive: None,
            matched_cluster: None,
            client_tag: req.client_tag.clone(),
        }
    }

    /// Degraded mode: the upstream is unavailable (`why`), so retry the
    /// lookup at the relaxed [`ServerConfig::degraded_threshold`] gate
    /// and answer from the best candidate when one exists — explicitly
    /// marked (`Outcome::Degraded`, `latency.degraded`) so it is never
    /// passed off as a fresh or first-class cached answer. With no
    /// candidate the request is rejected with
    /// [`REASON_UPSTREAM_UNAVAILABLE`] (the HTTP front-end maps that
    /// prefix to 503 + `Retry-After`). Nothing is inserted, so an outage
    /// can never pollute the cache or the WAL.
    fn serve_degraded(
        &self,
        req: &QueryRequest,
        embedding: &[f32],
        embed_ms: f64,
        index_ms: f64,
        embed_cached: bool,
        tenant: &str,
        why: &UpstreamUnavailable,
    ) -> QueryResponse {
        let t = Instant::now();
        let hit = self.cache.lookup_with_opts_for(
            tenant,
            embedding,
            self.degraded_threshold,
            req.options.top_k,
        );
        let relaxed_ms = t.elapsed().as_secs_f64() * 1e3;
        let index_ms = index_ms + relaxed_ms;
        match hit {
            Some(hit) => {
                self.metrics.record_degraded_hit();
                let judged = req.cluster.map(|c| {
                    let ok = self.judge.validate(c, hit.entry.cluster);
                    self.metrics.record_judgement(ok);
                    ok
                });
                let total_ms = embed_ms + index_ms;
                self.metrics.observe_total_ms(total_ms);
                QueryResponse {
                    response: hit.entry.response.clone(),
                    outcome: Outcome::Degraded { score: hit.score, entry_id: hit.id },
                    latency: LatencyBreakdown {
                        total_ms,
                        embed_ms,
                        index_ms,
                        llm_ms: 0.0,
                        embed_cached,
                        degraded: true,
                    },
                    judged_positive: judged,
                    matched_cluster: Some(hit.entry.cluster),
                    client_tag: req.client_tag.clone(),
                }
            }
            None => {
                self.metrics.record_rejected();
                QueryResponse::rejected(
                    req,
                    format!("{REASON_UPSTREAM_UNAVAILABLE}: {}", why.describe()),
                )
            }
        }
    }

    /// Handle one query through the full workflow (pre-v1 shim over
    /// [`Server::serve`]). `cluster` is the ground-truth identity when
    /// known (evaluation traces); production callers pass `None`.
    pub fn handle(&self, text: &str, cluster: Option<u64>) -> Reply {
        let mut req = QueryRequest::new(text);
        req.cluster = cluster;
        Reply::from_response(self.serve(&req))
    }

    /// The traditional (no-cache) path: always call the LLM. Used for the
    /// Figure 2/3 baselines.
    pub fn handle_without_cache(&self, text: &str, cluster: Option<u64>) -> Reply {
        let ground_truth =
            cluster.and_then(|c| self.ground_truth.read().unwrap().get(&c).cloned());
        // The baseline has no cache to degrade to; an injected upstream
        // fault surfaces as an error-shaped reply (benchmarks run with a
        // no-op fault plan, so this path only fires in chaos tests).
        let resp = match self.llm.call(text, ground_truth.as_deref()) {
            Ok(r) => r,
            Err(e) => {
                return Reply {
                    response: format!("<{REASON_UPSTREAM_UNAVAILABLE}: {e}>"),
                    source: ReplySource::Llm,
                    total_ms: 0.0,
                    embed_ms: 0.0,
                    index_ms: 0.0,
                    llm_ms: 0.0,
                    judged_positive: None,
                    matched_cluster: None,
                }
            }
        };
        Reply {
            response: resp.text,
            source: ReplySource::Llm,
            total_ms: resp.latency_ms,
            embed_ms: 0.0,
            index_ms: 0.0,
            llm_ms: resp.latency_ms,
            judged_positive: None,
            matched_cluster: None,
        }
    }

    /// Serve a batch of typed requests concurrently; responses come back
    /// in input order. Pipelined equivalent of a sequential
    /// `reqs.iter().map(|r| self.serve(r))` loop, with one caveat:
    /// in-flight misses are not deduplicated, so if a batch contains
    /// duplicate (or near-duplicate) *novel* queries, workers racing on
    /// them may each call the LLM and insert their own entry — where the
    /// sequential loop would miss once and then hit. See
    /// [`Server::serve_batch_with_workers`] for the pipeline stages.
    pub fn serve_batch(&self, reqs: &[QueryRequest]) -> Vec<QueryResponse> {
        self.serve_batch_with_workers(reqs, self.workers)
    }

    /// The batch serving pipeline with an explicit pool width:
    ///
    /// 1. **Chunked embedding** — the input is split into work units of
    ///    up to `BATCH_CHUNK` queries (shrunk when the batch is small,
    ///    so every worker still gets work); each worker encodes a whole
    ///    unit through `Encoder::encode_batch`, amortizing the embedding
    ///    call the same way [`Server::populate`] does. Requests that
    ///    fail validation are answered `Rejected` without being encoded.
    /// 2. **Concurrent fan-out** — `workers` scoped threads claim units
    ///    off an atomic cursor and run lookup → (miss: LLM + insert) per
    ///    query; the cache's read-mostly `RwLock` sharding lets all
    ///    workers search one partition's ANN index in parallel.
    /// 3. **Deterministic merge** — responses are reassembled in input
    ///    order regardless of which worker finished first.
    ///
    /// Per-stage latency lands in [`Metrics`]: per-query embed/index/llm
    /// histograms as usual, plus per-batch `lat_batch_embed` (summed
    /// chunk embedding wall), `lat_batch_merge`, and `lat_batch_total`.
    pub fn serve_batch_with_workers(
        &self,
        reqs: &[QueryRequest],
        workers: usize,
    ) -> Vec<QueryResponse> {
        self.serve_batch_tracked(reqs, workers, &[], &AtomicUsize::new(0))
    }

    /// [`Server::serve_batch_with_workers`] with an accounting-progress
    /// counter: `recorded` is bumped once per query whose `request` +
    /// outcome (hit/miss/degraded/rejected) metrics are both recorded,
    /// and the bump is adjacent to those recordings, so a worker
    /// panicking mid-batch leaves `recorded` equal to the number of
    /// fully accounted queries. The batcher reads it to keep
    /// `cache_hits + cache_misses + degraded_hits + rejected == requests`
    /// exact when it rejects the remainder of a failed dispatch.
    ///
    /// `accepted` carries each request's edge-accept instant (the
    /// batcher's enqueue time) so upstream deadlines include time spent
    /// queued; when empty (direct `serve_batch` callers) every request
    /// is treated as accepted at batch start.
    fn serve_batch_tracked(
        &self,
        reqs: &[QueryRequest],
        workers: usize,
        accepted: &[Instant],
        recorded: &AtomicUsize,
    ) -> Vec<QueryResponse> {
        if reqs.is_empty() {
            return Vec::new();
        }
        let t_batch = Instant::now();
        // Shrink the chunk so a small batch still spans the whole pool
        // (32 queries at 8 workers -> 4-query chunks, not one chunk).
        let workers = workers.max(1).min(reqs.len());
        let chunk_size = BATCH_CHUNK.min(reqs.len().div_ceil(workers)).max(1);
        let n_chunks = reqs.len().div_ceil(chunk_size);
        let next_chunk = AtomicUsize::new(0);
        let slots: Mutex<Vec<(usize, QueryResponse)>> =
            Mutex::new(Vec::with_capacity(reqs.len()));
        let embed_wall_ms = Mutex::new(0.0f64);

        std::thread::scope(|scope| {
            for _ in 0..workers {
                let next_chunk = &next_chunk;
                let slots = &slots;
                let embed_wall_ms = &embed_wall_ms;
                scope.spawn(move || loop {
                    let c = next_chunk.fetch_add(1, Ordering::Relaxed);
                    if c >= n_chunks {
                        break;
                    }
                    let start = c * chunk_size;
                    let end = (start + chunk_size).min(reqs.len());
                    let chunk = &reqs[start..end];

                    // Stage 1: amortized embedding for the unit's valid
                    // requests; invalid ones carry their rejection
                    // reason (validated once) and are not encoded.
                    let mut rejections: Vec<Option<String>> = chunk
                        .iter()
                        .map(|r| r.validate().err().map(|e| format!("{e:#}")))
                        .collect();
                    let texts: Vec<&str> = chunk
                        .iter()
                        .zip(&rejections)
                        .filter(|(_, rejected)| rejected.is_none())
                        .map(|(r, _)| r.text.as_str())
                        .collect();
                    // `embed_bypass` is a per-request flag but encoding
                    // is per-chunk; bypass requests are rare (a
                    // benchmark escape hatch), so a mixed chunk falls
                    // back to per-request tracked encodes instead of
                    // complicating the amortized path.
                    let any_bypass = chunk
                        .iter()
                        .zip(&rejections)
                        .any(|(r, rej)| rej.is_none() && r.options.embed_bypass);
                    let t0 = Instant::now();
                    let encoded: Vec<crate::embedding::EncodeOutcome> = if texts.is_empty() {
                        Vec::new()
                    } else if !any_bypass {
                        self.encoder.encode_batch_tracked(&texts, false)
                    } else {
                        chunk
                            .iter()
                            .zip(&rejections)
                            .filter(|(_, rejected)| rejected.is_none())
                            .flat_map(|(r, _)| {
                                self.encoder.encode_batch_tracked(
                                    &[r.text.as_str()],
                                    r.options.embed_bypass,
                                )
                            })
                            .collect()
                    };
                    let chunk_ms = t0.elapsed().as_secs_f64() * 1e3;
                    *embed_wall_ms.lock().unwrap() += chunk_ms;
                    let per_query_ms =
                        if texts.is_empty() { 0.0 } else { chunk_ms / texts.len() as f64 };
                    // `lat_embed_memo` must hold memo-hit latency *only*:
                    // in a mixed chunk the amortized per-query time is
                    // dominated by co-chunked cold forward passes, so
                    // record it for hits only when the whole chunk was
                    // served from the memo (single-query chunks — the
                    // serve() path's shape — always qualify).
                    let chunk_all_memo_hits =
                        !encoded.is_empty() && encoded.iter().all(|o| o.memo_hit);

                    // Stage 2: lookup / miss fan-out.
                    let mut done = Vec::with_capacity(chunk.len());
                    let mut next_embedding = 0;
                    for (off, req) in chunk.iter().enumerate() {
                        let i = start + off;
                        if let Some(reason) = rejections[off].take() {
                            self.metrics.record_request();
                            self.metrics.record_rejected();
                            recorded.fetch_add(1, Ordering::SeqCst);
                            done.push((i, QueryResponse::rejected(req, reason)));
                            continue;
                        }
                        let outcome = &encoded[next_embedding];
                        next_embedding += 1;
                        self.metrics.record_embedding(crate::llm::approx_tokens(&req.text));
                        self.metrics.observe_embed_ms(per_query_ms);
                        self.metrics.record_embed_cache(outcome.memo_hit);
                        if outcome.memo_hit && chunk_all_memo_hits {
                            self.metrics.observe_embed_memo_ms(per_query_ms);
                        }
                        let deadline = self.resilience.config().deadline_from(
                            accepted.get(i).copied().unwrap_or(t_batch),
                            req.options.deadline_ms,
                        );
                        let resp = self.serve_embedded(
                            req,
                            &outcome.embedding,
                            per_query_ms,
                            outcome.memo_hit,
                            deadline,
                        );
                        // `request` is recorded only once the outcome is
                        // too (serve_embedded records hit/miss), and the
                        // progress bump rides right behind both, so a
                        // panic can't leave a half-accounted query.
                        self.metrics.record_request();
                        recorded.fetch_add(1, Ordering::SeqCst);
                        done.push((i, resp));
                    }
                    slots.lock().unwrap().extend(done);
                });
            }
        });

        // Stage 3: deterministic in-order merge.
        let t_merge = Instant::now();
        let mut slots = slots.into_inner().unwrap();
        slots.sort_by_key(|(i, _)| *i);
        debug_assert_eq!(slots.len(), reqs.len());
        let responses: Vec<QueryResponse> = slots.into_iter().map(|(_, r)| r).collect();

        self.metrics.record_batch(reqs.len() as u64);
        self.metrics.observe_batch_embed_ms(embed_wall_ms.into_inner().unwrap());
        self.metrics.observe_batch_merge_ms(t_merge.elapsed().as_secs_f64() * 1e3);
        self.metrics.observe_batch_total_ms(t_batch.elapsed().as_secs_f64() * 1e3);
        responses
    }

    /// Serve a batch of plain texts (pre-v1 shim over
    /// [`Server::serve_batch`]); replies come back in input order.
    pub fn handle_batch(&self, texts: &[&str]) -> Vec<Reply> {
        self.handle_batch_clustered(texts, &vec![None; texts.len()])
    }

    /// [`Server::handle_batch`] with per-query ground-truth clusters
    /// (evaluation traces). `clusters` must be as long as `texts`.
    pub fn handle_batch_clustered(&self, texts: &[&str], clusters: &[Option<u64>]) -> Vec<Reply> {
        self.handle_batch_with_workers(texts, clusters, self.workers)
    }

    /// [`Server::handle_batch_clustered`] with an explicit pool width
    /// (pre-v1 shim over [`Server::serve_batch_with_workers`]).
    pub fn handle_batch_with_workers(
        &self,
        texts: &[&str],
        clusters: &[Option<u64>],
        workers: usize,
    ) -> Vec<Reply> {
        assert_eq!(texts.len(), clusters.len(), "one cluster slot per query");
        let reqs: Vec<QueryRequest> = texts
            .iter()
            .zip(clusters)
            .map(|(t, c)| {
                let mut r = QueryRequest::new(*t);
                r.cluster = *c;
                r
            })
            .collect();
        self.serve_batch_with_workers(&reqs, workers)
            .into_iter()
            .map(Reply::from_response)
            .collect()
    }

    /// Execute an administrative operation (the `/v1/admin` endpoint).
    pub fn admin(&self, req: &AdminRequest) -> AdminResponse {
        match req {
            AdminRequest::Flush => {
                // Flush empties the embedding memo tier too (benchmark /
                // privacy hygiene); `removed` counts semantic-cache
                // entries, as before the tier existed.
                self.encoder.memo_flush();
                AdminResponse::Flushed { removed: self.cache.clear() }
            }
            AdminRequest::Housekeep => {
                let (expired, rebuilt) = self.cache.housekeep();
                AdminResponse::Housekept { expired, rebuilt }
            }
            AdminRequest::Snapshot => match self.snapshot_now() {
                Ok(s) => AdminResponse::Snapshotted { entries: s.entries, bytes: s.bytes },
                Err(e) => AdminResponse::Unsupported { reason: format!("{e:#}") },
            },
            AdminRequest::Stats => AdminResponse::Stats(self.stats_json()),
            AdminRequest::Fault(plan) => {
                // Replace the upstream fault schedule wholesale (an
                // empty plan clears injection); echoes the full plan so
                // operators see exactly what is now in force.
                self.llm.set_fault_plan(plan.clone());
                AdminResponse::FaultSet { plan: self.llm.fault_plan() }
            }
        }
    }

    /// Metrics snapshot plus serving state, as one JSON document (the
    /// `/v1/metrics` endpoint).
    pub fn stats_json(&self) -> Value {
        let memo = match self.encoder.memo_counters() {
            Some(c) => obj([
                ("hits", c.hits.into()),
                ("misses", c.misses.into()),
                ("insertions", c.insertions.into()),
                ("evictions", c.evictions.into()),
                ("entries", c.entries.into()),
            ]),
            None => Value::Null,
        };
        let tenants: std::collections::BTreeMap<String, Value> = self
            .cache
            .tenant_stats()
            .into_iter()
            .map(|t| (t.name.clone(), t.to_json()))
            .collect();
        obj([
            ("metrics", self.metrics.snapshot().to_json()),
            ("cache_entries", self.cache.len().into()),
            ("cache_bytes", self.cache.bytes().into()),
            ("cache_max_bytes", self.cache.max_bytes().into()),
            ("tenants", Value::Object(tenants)),
            ("embed_memo", memo),
            ("threshold", (self.effective_threshold() as f64).into()),
            ("degraded_threshold", (self.degraded_threshold as f64).into()),
            ("workers", self.workers.into()),
        ])
    }

    /// Spawn the housekeeping thread (TTL sweep + index rebuild check).
    /// Returns a guard; dropping it stops the thread.
    pub fn start_housekeeping(self: &Arc<Self>, interval: Duration) -> HousekeepingGuard {
        let stop = self.housekeeping_stop.clone();
        stop.store(false, Ordering::SeqCst);
        let server = self.clone();
        let handle = std::thread::Builder::new()
            .name("housekeeping".into())
            .spawn(move || {
                while !server.housekeeping_stop.load(Ordering::SeqCst) {
                    std::thread::sleep(interval);
                    server.cache.housekeep();
                }
            })
            .expect("spawn housekeeping");
        HousekeepingGuard { stop: self.housekeeping_stop.clone(), handle: Some(handle) }
    }
}

impl BatchExecutor for Server {
    fn execute(&self, reqs: &[QueryRequest]) -> Vec<QueryResponse> {
        self.serve_batch(reqs)
    }

    /// [`BatchExecutor::execute`] with exact accounting progress: the
    /// batcher's failed-dispatch rejection path reads `recorded` to
    /// avoid double-counting queries this server already recorded
    /// before a mid-batch panic.
    fn execute_tracked(&self, reqs: &[QueryRequest], recorded: &AtomicUsize) -> Vec<QueryResponse> {
        self.serve_batch_tracked(reqs, self.workers, &[], recorded)
    }

    /// [`BatchExecutor::execute_tracked`] with each request's original
    /// enqueue instant, so deadlines measured from the HTTP edge survive
    /// the trip through the batcher's queue and window.
    fn execute_tracked_since(
        &self,
        reqs: &[QueryRequest],
        accepted: &[Instant],
        recorded: &AtomicUsize,
    ) -> Vec<QueryResponse> {
        self.serve_batch_tracked(reqs, self.workers, accepted, recorded)
    }

    /// Answer an identical in-flight twin from its representative's
    /// result, mirroring what a sequential `serve()` of the duplicate
    /// right after the representative would have produced:
    ///
    /// * rep hit  → dup hits the same entry with the same score (equal
    ///   text ⇒ equal embedding ⇒ equal cosine);
    /// * rep miss → dup hits the entry the representative just inserted
    ///   (equal text ⇒ cosine 1.0 against it);
    /// * rep degraded → dup degrades onto the same relaxed-gate entry
    ///   (still marked degraded — coalescing must not launder it into a
    ///   first-class hit);
    /// * rep rejected → dup rejected for the same reason.
    ///
    /// Metrics mirror the sequential path (request + hit/degraded +
    /// judgement); embedding tokens and LLM calls are *not* recorded —
    /// the whole point of coalescing is that the duplicate never pays
    /// them.
    fn coalesce(
        &self,
        dup: &QueryRequest,
        rep: &QueryRequest,
        rep_resp: &QueryResponse,
    ) -> QueryResponse {
        self.metrics.record_request();
        let (outcome, entry_cluster) = match &rep_resp.outcome {
            Outcome::Hit { score, entry_id } => {
                (Outcome::Hit { score: *score, entry_id: *entry_id }, rep_resp.matched_cluster)
            }
            Outcome::Miss { inserted_id } => (
                Outcome::Hit { score: 1.0, entry_id: *inserted_id },
                Some(rep.cluster.unwrap_or(0)),
            ),
            Outcome::Degraded { score, entry_id } => (
                Outcome::Degraded { score: *score, entry_id: *entry_id },
                rep_resp.matched_cluster,
            ),
            Outcome::Rejected { reason } => (Outcome::Rejected { reason: reason.clone() }, None),
        };
        if matches!(outcome, Outcome::Rejected { .. }) {
            self.metrics.record_rejected();
            return QueryResponse {
                response: rep_resp.response.clone(),
                outcome,
                latency: LatencyBreakdown::default(),
                judged_positive: None,
                matched_cluster: None,
                client_tag: dup.client_tag.clone(),
            };
        }
        let degraded = matches!(outcome, Outcome::Degraded { .. });
        if degraded {
            self.metrics.record_degraded_hit();
        } else {
            self.metrics.record_hit();
        }
        let judged = dup.cluster.map(|c| {
            let ok = self.judge.validate(c, entry_cluster.unwrap_or(0));
            self.metrics.record_judgement(ok);
            ok
        });
        // Truthful accounting: the duplicate's marginal serving cost is
        // ~zero (no embed, no lookup, no LLM).
        self.metrics.observe_total_ms(0.0);
        QueryResponse {
            response: rep_resp.response.clone(),
            outcome,
            latency: LatencyBreakdown { degraded, ..LatencyBreakdown::default() },
            judged_positive: judged,
            matched_cluster: entry_cluster,
            client_tag: dup.client_tag.clone(),
        }
    }
}

/// Stops the housekeeping thread on drop.
pub struct HousekeepingGuard {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Drop for HousekeepingGuard {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Stops the periodic snapshot thread on drop.
pub struct SnapshotGuard {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Drop for SnapshotGuard {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::NativeEncoder;
    use crate::runtime::ModelParams;
    use crate::workload::{DatasetConfig, WorkloadGenerator};

    fn small_encoder() -> Arc<dyn Encoder> {
        let mut p = ModelParams::default();
        p.layers = 1;
        p.vocab_size = 1024;
        p.dim = 96;
        p.hidden = 192;
        p.heads = 4;
        Arc::new(NativeEncoder::new(p))
    }

    fn server() -> Arc<Server> {
        Arc::new(Server::new(small_encoder(), ServerConfig::default()))
    }

    #[test]
    fn miss_then_hit_same_query() {
        let s = server();
        let r1 = s.handle("how do i reset my password", None);
        assert_eq!(r1.source, ReplySource::Llm);
        let r2 = s.handle("how do i reset my password", None);
        assert!(matches!(r2.source, ReplySource::Cache { .. }));
        assert_eq!(r2.response, r1.response, "cached response equals original");
        assert!(r2.total_ms < r1.total_ms, "cache path faster than llm path");
        let m = s.metrics().snapshot();
        assert_eq!(m.requests, 2);
        assert_eq!(m.cache_hits, 1);
        assert_eq!(m.llm_calls, 1);
    }

    #[test]
    fn serve_returns_typed_outcomes() {
        let s = server();
        let req = QueryRequest::new("how do i reset my password").with_client_tag("t-1");
        let r1 = s.serve(&req);
        let inserted = match r1.outcome {
            Outcome::Miss { inserted_id } => inserted_id,
            ref other => panic!("first serve must miss, got {other:?}"),
        };
        assert!(inserted >= 1, "ids start at 1");
        assert_eq!(r1.client_tag.as_deref(), Some("t-1"));
        // Same tenant: the paraphrase must carry the same tag to see the
        // entry (client_tag namespaces the cache).
        let r2 = s.serve(&QueryRequest::new("how can i reset my password").with_client_tag("t-1"));
        match r2.outcome {
            Outcome::Hit { score, entry_id } => {
                assert!(score >= s.effective_threshold());
                assert_eq!(entry_id, inserted, "hit resolves to the inserted entry");
            }
            ref other => panic!("second serve must hit, got {other:?}"),
        }
        assert_eq!(r2.response, r1.response);
        assert_eq!(r2.latency.llm_ms, 0.0, "hits never pay the LLM");
    }

    #[test]
    fn serve_rejects_invalid_requests_without_panicking() {
        let s = server();
        let blank = QueryRequest::new("   ");
        let r = s.serve(&blank);
        assert!(matches!(r.outcome, Outcome::Rejected { .. }), "blank text rejected");
        let bad = QueryRequest::new("ok question").with_top_k(0);
        let r = s.serve(&bad);
        match r.outcome {
            Outcome::Rejected { ref reason } => assert!(reason.contains("top_k")),
            ref other => panic!("expected rejection, got {other:?}"),
        }
        let m = s.metrics().snapshot();
        assert_eq!(m.requests, 2);
        assert_eq!(m.rejected, 2);
        assert_eq!(m.llm_calls, 0, "rejected requests never reach the LLM");
    }

    #[test]
    fn paraphrase_hits_and_is_judged_positive() {
        let s = server();
        let r1 = s.handle("how do i reset my password", Some(42));
        assert_eq!(r1.source, ReplySource::Llm);
        let r2 = s.handle("how can i reset my password", Some(42));
        assert!(matches!(r2.source, ReplySource::Cache { .. }), "paraphrase should hit");
        assert_eq!(r2.judged_positive, Some(true));
        assert_eq!(r2.matched_cluster, Some(42));
    }

    #[test]
    fn populate_then_serve_ground_truth() {
        let s = server();
        let ds = WorkloadGenerator::new(3).generate(&DatasetConfig::tiny());
        s.populate(&ds.base);
        assert_eq!(s.cache().len(), ds.base.len());
        // Exact cached question must hit and return its stored answer.
        let p = &ds.base[0];
        let r = s.handle(&p.question, Some(p.answer_group));
        assert!(matches!(r.source, ReplySource::Cache { .. }));
        assert_eq!(r.response, p.answer);
        assert_eq!(r.judged_positive, Some(true));
    }

    #[test]
    fn without_cache_baseline_always_calls_llm() {
        let s = server();
        for _ in 0..3 {
            let r = s.handle_without_cache("same question every time", None);
            assert_eq!(r.source, ReplySource::Llm);
            assert!(r.llm_ms > 0.0);
        }
    }

    #[test]
    fn per_request_threshold_changes_gating() {
        let s = server();
        s.handle("tell me about the acme laptop", Some(1));
        // An unrelated query under an absurdly lenient per-request
        // threshold hits; the server-wide gate is untouched.
        let lenient = QueryRequest::new("completely different topic entirely")
            .with_cluster(2)
            .with_threshold(-1.0);
        let r = s.serve(&lenient);
        assert!(r.is_hit());
        assert_eq!(r.judged_positive, Some(false), "wrong-cluster hit judged negative");
        assert_eq!(s.effective_threshold(), 0.8, "per-request option leaves the gate alone");
    }

    #[test]
    fn client_tags_are_isolated_tenant_namespaces() {
        let s = server();
        let r1 = s.serve(&QueryRequest::new("how do i reset my password").with_client_tag("alice"));
        assert!(matches!(r1.outcome, Outcome::Miss { .. }));
        // Bob's identical question cannot see Alice's entry.
        let r2 = s.serve(&QueryRequest::new("how do i reset my password").with_client_tag("bob"));
        assert!(matches!(r2.outcome, Outcome::Miss { .. }), "cross-tenant lookup must miss");
        // Alice's paraphrase still hits her own entry.
        let r3 = s.serve(&QueryRequest::new("how can i reset my password").with_client_tag("alice"));
        assert!(r3.is_hit(), "{:?}", r3.outcome);
        // The stats document carries a per-tenant block plus the byte
        // gauges.
        let stats = s.stats_json();
        assert!(s.cache().bytes() > 0);
        assert_eq!(stats.get("cache_bytes").as_u64(), Some(s.cache().bytes()));
        assert_eq!(stats.get("cache_max_bytes").as_u64(), Some(0));
        let alice = stats.get("tenants").get("alice");
        assert_eq!(alice.get("hits").as_u64(), Some(1));
        assert_eq!(alice.get("misses").as_u64(), Some(1));
        let bob = stats.get("tenants").get("bob");
        assert_eq!(bob.get("hits").as_u64(), Some(0));
        assert_eq!(bob.get("misses").as_u64(), Some(1));
    }

    #[test]
    fn tenant_threshold_override_gates_that_tenant_only() {
        let cache = CacheConfig::builder()
            .tenant(
                "lenient",
                crate::tenancy::TenantOverrides {
                    similarity_threshold: Some(-1.0),
                    ..Default::default()
                },
            )
            .build()
            .unwrap();
        let cfg = ServerConfig::builder().cache(cache).build().unwrap();
        let s = Arc::new(Server::new(small_encoder(), cfg));
        s.serve(&QueryRequest::new("tell me about the acme laptop").with_client_tag("lenient"));
        s.serve(&QueryRequest::new("tell me about the acme laptop").with_client_tag("strict"));
        // Same unrelated follow-up: the lenient tenant's override
        // admits it, the strict tenant stays on the global gate.
        let r = s.serve(
            &QueryRequest::new("completely different topic entirely").with_client_tag("lenient"),
        );
        assert!(r.is_hit(), "tenant override must admit the match: {:?}", r.outcome);
        let r = s.serve(
            &QueryRequest::new("completely different topic entirely").with_client_tag("strict"),
        );
        assert!(!r.is_hit(), "global gate still applies to other tenants");
        // A per-request threshold beats the tenant override.
        let r = s.serve(
            &QueryRequest::new("yet another unrelated topic instead")
                .with_client_tag("lenient")
                .with_threshold(0.999),
        );
        assert!(!r.is_hit(), "per-request threshold wins over the tenant override");
    }

    #[test]
    fn cost_aware_miss_records_llm_latency_on_the_entry() {
        let s = server();
        let r = s.serve(&QueryRequest::new("how do i reset my password"));
        assert!(matches!(r.outcome, Outcome::Miss { .. }));
        assert!(r.latency.llm_ms > 0.0);
        let e = s.encoder().encode_text("how do i reset my password");
        let hit = s.cache().lookup(&e).expect("inserted entry must hit");
        assert_eq!(
            hit.entry.latency_ms, r.latency.llm_ms,
            "entry carries the simulated upstream latency it saves"
        );
    }

    #[test]
    fn legacy_global_threshold_override_still_works() {
        let s = server();
        s.handle("tell me about the acme laptop", Some(1));
        #[allow(deprecated)]
        s.set_threshold(Some(-1.0));
        assert_eq!(s.effective_threshold(), -1.0);
        let r = s.handle("completely different topic entirely", Some(2));
        assert!(matches!(r.source, ReplySource::Cache { .. }));
        #[allow(deprecated)]
        s.set_threshold(None);
        assert_eq!(s.effective_threshold(), 0.8);
    }

    #[test]
    fn server_config_builder_validates() {
        let cfg = ServerConfig::builder()
            .cache(CacheConfig::builder().threshold(0.7).build().unwrap())
            .workers(8)
            .build()
            .unwrap();
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.cache.threshold, 0.7);
        assert!(ServerConfig::builder().workers(0).build().is_err(), "workers == 0");
        let bad_cache = CacheConfig { threshold: f32::NAN, ..Default::default() };
        assert!(
            ServerConfig::builder().cache(bad_cache).build().is_err(),
            "nested cache config validated"
        );
        let bad_llm = SimLlmConfig { rtt_ms: f64::NAN, ..Default::default() };
        assert!(
            ServerConfig::builder().llm(bad_llm).build().is_err(),
            "nested llm config validated"
        );
        let bad_batch = BatchConfig { max_batch_size: 0, ..Default::default() };
        assert!(
            ServerConfig::builder().batch(bad_batch).build().is_err(),
            "batch max_batch_size == 0 rejected"
        );
        let bad_wait = BatchConfig { max_wait_us: u64::MAX, ..Default::default() };
        assert!(
            ServerConfig::builder().batch(bad_wait).build().is_err(),
            "batch max_wait_us out of range rejected"
        );
    }

    #[test]
    fn from_app_config_clamps_legacy_batch_keys() {
        // `max_batch`/`batch_window_us` predate the request batcher and
        // were unbounded; a config that started a pre-batcher daemon
        // must keep starting one (values clamp, not error).
        let mut cfg = crate::config::Config::default();
        cfg.max_batch = 100_000;
        cfg.batch_window_us = 10_000_000;
        let sc = ServerConfig::from_app_config(&cfg).unwrap();
        assert_eq!(sc.batch.max_batch_size, MAX_BATCH_SIZE_LIMIT);
        assert_eq!(sc.batch.max_wait_us, MAX_WAIT_US_LIMIT);
    }

    #[test]
    fn batcher_over_server_misses_then_hits() {
        let s = server();
        let b = s.start_batcher().unwrap();
        let r1 = b.submit(&QueryRequest::new("how do i reset my password")).unwrap();
        assert!(matches!(r1.outcome, Outcome::Miss { .. }), "{:?}", r1.outcome);
        let r2 = b.submit(&QueryRequest::new("how can i reset my password")).unwrap();
        assert!(r2.is_hit(), "{:?}", r2.outcome);
        assert_eq!(r2.response, r1.response);
        b.shutdown();
        let m = s.metrics().snapshot();
        assert_eq!(m.requests, 2);
        assert_eq!(m.cache_hits, 1);
        assert_eq!(m.batcher_dispatches, 2, "sequential submits dispatch one by one");
    }

    #[test]
    fn coalesced_duplicate_resolves_as_hit_on_reps_entry() {
        let s = server();
        let rep = QueryRequest::new("novel coalesce probe").with_cluster(7);
        let rep_resp = s.serve(&rep);
        let inserted = match rep_resp.outcome {
            Outcome::Miss { inserted_id } => inserted_id,
            ref o => panic!("expected miss, got {o:?}"),
        };
        // Coalescing only ever pairs requests from the same tenant (the
        // batcher keys on client_tag), so the dup shares the rep's
        // namespace: both untagged here.
        let dup = QueryRequest::new("novel coalesce probe").with_cluster(7);
        let dup_resp = BatchExecutor::coalesce(s.as_ref(), &dup, &rep, &rep_resp);
        match dup_resp.outcome {
            Outcome::Hit { score, entry_id } => {
                assert_eq!(entry_id, inserted);
                assert!((score - 1.0).abs() < 1e-6);
            }
            ref o => panic!("expected hit, got {o:?}"),
        }
        assert_eq!(dup_resp.response, rep_resp.response);
        assert_eq!(dup_resp.judged_positive, Some(true));
        assert_eq!(dup_resp.matched_cluster, Some(7));
        assert_eq!(dup_resp.client_tag, None, "dup's own (absent) tag echoed");
        let m = s.metrics().snapshot();
        assert_eq!(m.requests, 2);
        assert_eq!(m.cache_hits, 1);
        assert_eq!(m.cache_misses, 1);
        assert_eq!(m.llm_calls, 1, "the duplicate never reached the LLM");
    }

    #[test]
    fn housekeeping_thread_runs_and_stops() {
        let s = server();
        let guard = s.start_housekeeping(Duration::from_millis(5));
        std::thread::sleep(Duration::from_millis(30));
        drop(guard); // must join cleanly
    }

    #[test]
    fn snapshotter_thread_runs_and_stops() {
        let s = server();
        // Without persistence the ticks are no-ops; the guard must still
        // stop a long-interval thread promptly (sliced wait).
        let guard = s.start_snapshotter(Duration::from_secs(3600));
        std::thread::sleep(Duration::from_millis(20));
        let t0 = Instant::now();
        drop(guard);
        assert!(t0.elapsed() < Duration::from_secs(5), "guard must not wait out the interval");
    }

    #[test]
    fn snapshot_admin_without_data_dir_is_unsupported() {
        let s = server();
        match s.admin(&AdminRequest::Snapshot) {
            AdminResponse::Unsupported { reason } => {
                assert!(reason.contains("--data-dir"), "unhelpful reason: {reason}")
            }
            other => panic!("expected Unsupported, got {other:?}"),
        }
    }

    #[test]
    fn persistence_roundtrip_across_server_instances() {
        let dir = std::env::temp_dir()
            .join(format!("semcache-server-persist-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let pcfg = || {
            Some(crate::persist::PersistConfig {
                data_dir: dir.clone(),
                snapshot_interval_secs: 60,
                wal_sync: crate::persist::WalSync::Os,
            })
        };
        let cfg = ServerConfig::builder().persist(pcfg()).build().unwrap();
        let s = Arc::new(Server::try_new(small_encoder(), cfg).unwrap());
        assert_eq!(s.recovery().entries, 0, "cold start");
        let r1 = s.handle("how do i reset my password", None);
        assert_eq!(r1.source, ReplySource::Llm);
        // Admin snapshot covers the first entry; the second rides the WAL.
        match s.admin(&AdminRequest::Snapshot) {
            AdminResponse::Snapshotted { entries, bytes } => {
                assert_eq!(entries, 1);
                assert!(bytes > 0);
            }
            other => panic!("expected Snapshotted, got {other:?}"),
        }
        let r2 = s.handle("a completely different question about gadgets", None);
        drop(s);

        let cfg = ServerConfig::builder().persist(pcfg()).build().unwrap();
        let s2 = Arc::new(Server::try_new(small_encoder(), cfg).unwrap());
        assert!(s2.recovery().snapshot_loaded);
        assert_eq!(s2.recovery().entries, 2, "snapshot entry + WAL entry");
        assert_eq!(s2.metrics().snapshot().recovered_entries, 2);
        // Paraphrase of the snapshotted entry hits with its original response.
        let h = s2.handle("how can i reset my password", None);
        assert!(matches!(h.source, ReplySource::Cache { .. }), "recovered entry must hit");
        assert_eq!(h.response, r1.response);
        // Exact repeat of the WAL-replayed entry hits too.
        let h2 = s2.handle("a completely different question about gadgets", None);
        assert!(matches!(h2.source, ReplySource::Cache { .. }));
        assert_eq!(h2.response, r2.response);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn handle_batch_empty_and_order() {
        let s = server();
        assert!(s.handle_batch(&[]).is_empty());
        // Populate distinct QA pairs, then batch-query exact questions in
        // a known order: reply i must carry answer i.
        let pairs: Vec<QaPair> = (0..50)
            .map(|i| QaPair {
                cluster: i,
                answer_group: i,
                category: crate::workload::Category::PythonBasics,
                question: format!("question about topic number {i} alpha beta"),
                answer: format!("answer payload {i}"),
            })
            .collect();
        s.populate(&pairs);
        let texts: Vec<String> =
            (0..50).rev().map(|i| format!("question about topic number {i} alpha beta")).collect();
        let refs: Vec<&str> = texts.iter().map(|t| t.as_str()).collect();
        let replies = s.handle_batch(&refs);
        assert_eq!(replies.len(), 50);
        for (k, r) in replies.iter().enumerate() {
            let i = 49 - k; // texts were reversed
            assert!(matches!(r.source, ReplySource::Cache { .. }), "query {k} missed");
            assert_eq!(r.response, format!("answer payload {i}"), "reply out of order");
        }
        let m = s.metrics().snapshot();
        assert_eq!(m.batches, 1);
        assert_eq!(m.batch_queries, 50);
        assert_eq!(m.requests, 50);
        assert_eq!(m.cache_hits, 50);
        assert!(m.lat_batch_total.n == 1 && m.lat_batch_embed.n == 1);
    }

    #[test]
    fn serve_batch_mixes_valid_and_rejected_in_order() {
        let s = server();
        let reqs = vec![
            QueryRequest::new("a perfectly fine question"),
            QueryRequest::new("   "),
            QueryRequest::new("another fine question").with_top_k(0),
            QueryRequest::new("a perfectly fine question"),
        ];
        // One worker => one chunk processed in order, so the repeat of
        // request 0 deterministically hits its freshly inserted entry.
        let out = s.serve_batch_with_workers(&reqs, 1);
        assert_eq!(out.len(), 4);
        assert!(matches!(out[0].outcome, Outcome::Miss { .. }));
        assert!(matches!(out[1].outcome, Outcome::Rejected { .. }));
        assert!(matches!(out[2].outcome, Outcome::Rejected { .. }));
        assert!(matches!(out[3].outcome, Outcome::Hit { .. }), "repeat of request 0 hits");
        let m = s.metrics().snapshot();
        assert_eq!(m.requests, 4);
        assert_eq!(m.rejected, 2);
    }

    #[test]
    fn handle_batch_agrees_with_sequential_handles() {
        // Same trace served by two identically-seeded servers: the batch
        // pipeline must agree with N sequential handle() calls on source
        // and response for every index. Ground truth makes miss responses
        // deterministic; fresh queries are pairwise-distinct so batch
        // interleaving cannot turn a miss into a hit.
        let build = || {
            let s = server();
            let cached: Vec<QaPair> = (0..20)
                .map(|i| QaPair {
                    cluster: i,
                    answer_group: i,
                    category: crate::workload::Category::PythonBasics,
                    question: format!("how do i configure gadget model {i} firmware"),
                    answer: format!("cached answer {i}"),
                })
                .collect();
            // Ground truth for the novel clusters too, so misses insert a
            // deterministic response; only `cached` is in the cache.
            let novel: Vec<QaPair> = (0..20)
                .map(|j| QaPair {
                    cluster: 1000 + j,
                    answer_group: 1000 + j,
                    category: crate::workload::Category::PythonBasics,
                    question: format!("unique{j} zebra{j} quasar{j} lantern{j}"),
                    answer: format!("novel answer {j}"),
                })
                .collect();
            s.populate(&cached);
            let all = Dataset {
                base: cached.iter().chain(&novel).cloned().collect(),
                tests: Vec::new(),
            };
            s.register_ground_truth(&all);
            s
        };

        // Trace: paraphrases of cached questions interleaved with novel ones.
        let mut texts = Vec::new();
        let mut clusters = Vec::new();
        for k in 0..20u64 {
            texts.push(format!("how can i configure gadget model {k} firmware"));
            clusters.push(Some(k));
            texts.push(format!("unique{k} zebra{k} quasar{k} lantern{k}"));
            clusters.push(Some(1000 + k));
        }
        let refs: Vec<&str> = texts.iter().map(|t| t.as_str()).collect();

        let sequential = build();
        let seq: Vec<Reply> =
            refs.iter().zip(&clusters).map(|(t, c)| sequential.handle(t, *c)).collect();
        let batched = build();
        let bat = batched.handle_batch_with_workers(&refs, &clusters, 4);

        assert_eq!(seq.len(), bat.len());
        for (i, (a, b)) in seq.iter().zip(&bat).enumerate() {
            assert_eq!(
                matches!(a.source, ReplySource::Cache { .. }),
                matches!(b.source, ReplySource::Cache { .. }),
                "source diverged at {i}: {:?} vs {:?}",
                a.source,
                b.source
            );
            assert_eq!(a.response, b.response, "response diverged at {i}");
            assert_eq!(a.judged_positive, b.judged_positive, "verdict diverged at {i}");
        }
        assert_eq!(
            sequential.metrics().snapshot().cache_hits,
            batched.metrics().snapshot().cache_hits
        );
    }

    #[test]
    fn handle_batch_race_free_under_concurrent_populate() {
        // Multi-writer populate racing concurrent batch lookups: no
        // panics/deadlocks, and every entry is present afterwards.
        let s = server();
        let chunks: Vec<Vec<QaPair>> = (0..4)
            .map(|w| {
                (0..25)
                    .map(|i| {
                        let id = (w * 100 + i) as u64;
                        QaPair {
                            cluster: id,
                            answer_group: id,
                            category: crate::workload::Category::PythonBasics,
                            question: format!("writer {w} item {i} gamma delta epsilon"),
                            answer: format!("a{id}"),
                        }
                    })
                    .collect()
            })
            .collect();
        std::thread::scope(|scope| {
            for chunk in &chunks {
                let s = s.clone();
                scope.spawn(move || s.populate(chunk));
            }
            for t in 0..2 {
                let s = s.clone();
                scope.spawn(move || {
                    let texts: Vec<String> =
                        (0..40).map(|i| format!("reader {t} probe {i} omega")).collect();
                    let refs: Vec<&str> = texts.iter().map(|x| x.as_str()).collect();
                    let replies = s.handle_batch(&refs);
                    assert_eq!(replies.len(), 40);
                });
            }
        });
        // All 100 populated entries must be retrievable exactly.
        for chunk in &chunks {
            for p in chunk {
                let e = s.encoder().encode_text(&p.question);
                let hit = s.cache().lookup(&e).expect("populated entry must hit");
                assert_eq!(hit.entry.cluster, p.answer_group);
            }
        }
        assert!(s.cache().len() >= 100, "populated entries lost");
    }

    #[test]
    fn concurrent_handles_are_safe() {
        let s = server();
        let mut joins = Vec::new();
        for t in 0..4 {
            let s = s.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..20 {
                    s.handle(&format!("thread {t} query {i}"), None);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(s.metrics().snapshot().requests, 80);
    }
}
