//! Concurrent trace driver: replay a test-query trace against a server
//! with a worker pool and (optionally) Poisson-paced arrivals. Produces
//! the throughput/latency report used by Figure 3 and the serving demo.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::util::{Rng, Summary};
use crate::workload::TestQuery;

use super::server::{Reply, ReplySource, Server};

/// Trace execution knobs.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub workers: usize,
    /// Poisson arrival rate (queries/sec); 0 = replay as fast as possible.
    pub qps: f64,
    /// Route through the cache (true) or the traditional path (false).
    pub use_cache: bool,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self { workers: 4, qps: 0.0, use_cache: true, seed: 0xACE }
    }
}

/// Aggregate results of a trace replay.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub replies: Vec<(usize, Reply)>,
    /// Wall-clock of the whole replay, seconds.
    pub wall_secs: f64,
    /// Requests per wall-clock second.
    pub throughput_qps: f64,
    /// Summary over per-request total latency (virtual+measured), ms.
    pub latency: Summary,
    pub hits: usize,
    pub misses: usize,
}

/// Runs traces against an `Arc<Server>`.
pub struct TraceRunner {
    server: Arc<Server>,
}

impl TraceRunner {
    pub fn new(server: Arc<Server>) -> Self {
        Self { server }
    }

    pub fn run(&self, queries: &[TestQuery], cfg: &TraceConfig) -> TraceReport {
        let next = AtomicUsize::new(0);
        let replies: std::sync::Mutex<Vec<(usize, Reply)>> =
            std::sync::Mutex::new(Vec::with_capacity(queries.len()));
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for w in 0..cfg.workers.max(1) {
                let next = &next;
                let replies = &replies;
                let server = self.server.clone();
                let mut rng = Rng::new(cfg.seed ^ (w as u64));
                let cfg = cfg.clone();
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= queries.len() {
                        break;
                    }
                    if cfg.qps > 0.0 {
                        // Per-worker thinning of the Poisson process.
                        let worker_rate = cfg.qps / cfg.workers.max(1) as f64;
                        let gap = rng.exponential(1000.0 / worker_rate);
                        std::thread::sleep(std::time::Duration::from_micros(
                            (gap * 1e3) as u64,
                        ));
                    }
                    let q = &queries[i];
                    let reply = if cfg.use_cache {
                        server.handle(&q.text, Some(q.answer_group))
                    } else {
                        server.handle_without_cache(&q.text, Some(q.answer_group))
                    };
                    replies.lock().unwrap().push((i, reply));
                });
            }
        });
        let wall_secs = t0.elapsed().as_secs_f64();
        let mut replies = replies.into_inner().unwrap();
        replies.sort_by_key(|(i, _)| *i);
        let lat: Vec<f64> = replies.iter().map(|(_, r)| r.total_ms).collect();
        let hits = replies
            .iter()
            .filter(|(_, r)| matches!(r.source, ReplySource::Cache { .. }))
            .count();
        let misses = replies.len() - hits;
        TraceReport {
            throughput_qps: replies.len() as f64 / wall_secs.max(1e-9),
            latency: Summary::of(&lat),
            wall_secs,
            hits,
            misses,
            replies,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServerConfig;
    use crate::embedding::NativeEncoder;
    use crate::runtime::ModelParams;
    use crate::workload::{Category, TestQuery};

    fn tiny_server() -> Arc<Server> {
        let mut p = ModelParams::default();
        p.layers = 1;
        p.vocab_size = 512;
        p.dim = 64;
        p.hidden = 128;
        p.heads = 4;
        Arc::new(Server::new(
            Arc::new(NativeEncoder::new(p)),
            ServerConfig::default(),
        ))
    }

    fn queries(n: usize) -> Vec<TestQuery> {
        (0..n)
            .map(|i| TestQuery {
                text: format!("synthetic query number {}", i % 10),
                cluster: (i % 10) as u64,
                answer_group: (i % 10) as u64,
                category: Category::PythonBasics,
                novel: false,
            })
            .collect()
    }

    #[test]
    fn replay_covers_every_query_once() {
        let r = TraceRunner::new(tiny_server()).run(&queries(50), &TraceConfig::default());
        assert_eq!(r.replies.len(), 50);
        // Indices are exactly 0..50 after sort.
        for (expect, (i, _)) in r.replies.iter().enumerate() {
            assert_eq!(*i, expect);
        }
        assert_eq!(r.hits + r.misses, 50);
        // 10 distinct texts, 50 queries: repeats must hit.
        assert!(r.hits >= 30, "hits {} too low", r.hits);
        assert!(r.throughput_qps > 0.0);
    }

    #[test]
    fn no_cache_mode_never_hits() {
        let cfg = TraceConfig { use_cache: false, ..Default::default() };
        let r = TraceRunner::new(tiny_server()).run(&queries(20), &cfg);
        assert_eq!(r.hits, 0);
        assert_eq!(r.misses, 20);
    }

    #[test]
    fn single_worker_matches_multi_worker_counts() {
        let one = TraceRunner::new(tiny_server())
            .run(&queries(30), &TraceConfig { workers: 1, ..Default::default() });
        let four = TraceRunner::new(tiny_server())
            .run(&queries(30), &TraceConfig { workers: 4, ..Default::default() });
        assert_eq!(one.replies.len(), four.replies.len());
        // Hit counts may differ by interleaving, but only slightly: every
        // repeated text after its first appearance should hit in both.
        assert!((one.hits as i64 - four.hits as i64).abs() <= 8);
    }
}
