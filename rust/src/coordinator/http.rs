//! Zero-dependency HTTP/1.1 front-end for the typed serving API.
//!
//! The wire format is the [`crate::api`] types via the in-tree
//! [`crate::json`] codec — no external crates anywhere. Two serving
//! modes share one protocol implementation (the incremental
//! [`RequestParser`] state machine below):
//!
//! * **Event loop (default).** A single reactor thread watches every
//!   connection with `epoll` (portable `poll(2)` fallback) via
//!   [`crate::util::poll`]; sockets are nonblocking, requests are parsed
//!   incrementally as bytes arrive, responses resume across partial
//!   writes, and a small worker pool receives only *complete* parsed
//!   requests. Thousands of idle keep-alive connections cost one fd
//!   each — no pinned threads (see [`super::reactor`]).
//! * **Threaded accept** (`HttpConfig::event_loop = false`, the
//!   `--threaded-accept` escape hatch). The pre-ISSUE-5 design: one
//!   accept thread feeds a fixed pool of blocking connection workers.
//!   Simple and debuggable, but an idle keep-alive connection pins its
//!   worker until `read_timeout` — it starves under idle fan-in
//!   (demonstrated by `tests/http_protocol.rs`).
//!
//! Endpoints (all JSON):
//!
//! | Method | Path              | Body                      | Reply |
//! |--------|-------------------|---------------------------|-------|
//! | POST   | `/v1/query`       | [`QueryRequest`]          | [`crate::api::QueryResponse`] |
//! | POST   | `/v1/query_batch` | `{"queries": [...]}`      | `{"replies": [...]}` |
//! | GET    | `/v1/metrics`     | —                         | metrics + cache state |
//! | POST   | `/v1/admin`       | [`AdminRequest`]          | [`crate::api::AdminResponse`] |
//! | GET    | `/v1/health`      | —                         | `{"status": "ok"}` |
//!
//! Malformed input is answered with 4xx JSON errors (`{"error": ...}`),
//! never a panic or dropped connection: bad JSON and bad fields are 400,
//! unknown paths 404, wrong methods 405, oversized bodies 413, oversized
//! request/header lines 431. Pipelined requests on one connection are
//! served in order in both modes. A panic escaping a handler is caught
//! so the worker pool never shrinks.
//!
//! By default (`HttpConfig::batching`) `POST /v1/query` routes through
//! the cross-request micro-batching engine ([`super::batcher`]):
//! concurrent in-flight queries from different connections are coalesced
//! into single `serve_batch` calls, identical in-flight queries are
//! answered once, and a full submit queue is answered `503 Service
//! Unavailable` with an `Outcome::Rejected` body (backpressure). In
//! event-loop mode the batcher's response comes back as a reactor wakeup
//! ([`super::batcher::Batcher::submit_with`]), so a request waiting on a
//! dispatch occupies no thread at all.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::{AdminRequest, Outcome, QueryRequest, QueryResponse, REASON_UPSTREAM_UNAVAILABLE};
use crate::error::{anyhow, bail, Context, Result};
use crate::json::{self, obj, Value};

use super::batcher::Batcher;
use super::Server;

/// Front-end configuration.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`HttpHandle::local_addr`]).
    pub addr: String,
    /// Request-handler threads. In event-loop mode these receive only
    /// complete parsed requests; in threaded-accept mode each owns one
    /// connection at a time.
    pub workers: usize,
    /// Request bodies beyond this answer 413.
    pub max_body_bytes: usize,
    /// Idle-connection timeout: a keep-alive connection with no complete
    /// request for this long is closed (mid-request stalls answer 408).
    pub read_timeout: Duration,
    /// Route `POST /v1/query` through the cross-request micro-batching
    /// engine ([`super::batcher`], window policy from
    /// [`super::ServerConfig::batch`]). When the batcher's bounded
    /// queue is full the request is answered `503` with an
    /// `Outcome::Rejected` body instead of waiting. `false` serves every
    /// request as an isolated `serve()` call (the pre-batching path).
    pub batching: bool,
    /// Serve with the epoll/poll readiness loop (default). `false`
    /// selects the legacy blocking thread-per-connection design
    /// (`semcached serve --threaded-accept`). On non-unix targets the
    /// threaded path is always used.
    pub event_loop: bool,
    /// Event-loop mode only: connections beyond this are answered `503`
    /// and closed at accept time instead of growing the fd table
    /// without bound. Auto-clamped at startup against what
    /// `RLIMIT_NOFILE` can actually be raised to (with headroom for the
    /// listener, wake pipes, workers, and data files), so the budget is
    /// never an fd-exhaustion trap.
    pub max_conns: usize,
    /// Event-loop mode only: reactor threads. Each owns its own poller,
    /// connection table, and completion queue; the first holds the
    /// listener and deals admitted connections round-robin to the
    /// fleet. Default: one per core, capped at 8
    /// ([`crate::util::auto_reactors`]); `0` = the pre-sharding
    /// single-reactor behavior (same as `1`).
    pub reactors: usize,
    /// Batcher dispatcher shards, hash-routed on the coalescing key
    /// (identical in-flight requests always share a dispatcher, so
    /// coalescing is unaffected). Default: half the cores, capped at 4
    /// ([`crate::util::auto_dispatchers`]); `0` = the pre-sharding
    /// single-dispatcher behavior (same as `1`). Ignored when
    /// `batching` is off.
    pub dispatchers: usize,
    /// Event-loop mode only: force the portable `poll(2)` backend even
    /// where epoll is available (the macOS/CI code path; also lets Linux
    /// CI exercise the fallback).
    pub poll_fallback: bool,
}

impl Default for HttpConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            max_body_bytes: 1 << 20,
            read_timeout: Duration::from_secs(10),
            batching: true,
            event_loop: true,
            max_conns: 1024,
            reactors: crate::util::auto_reactors(),
            dispatchers: crate::util::auto_dispatchers(),
            poll_fallback: false,
        }
    }
}

/// Start the HTTP front-end over a running [`Server`]. Returns once the
/// listener is bound; serving happens on background threads until the
/// returned handle is shut down or dropped.
pub fn serve_http(server: Arc<Server>, cfg: HttpConfig) -> Result<HttpHandle> {
    let listener =
        TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr().context("reading bound address")?;
    // The batcher (when enabled) is shared by every request worker and
    // hash-sharded over `dispatchers` dispatcher threads; it is shut
    // down by the handle after the workers have drained. `0` keeps the
    // pre-sharding single-dispatcher wire path.
    let dispatchers = cfg.dispatchers.max(1);
    let batcher =
        if cfg.batching { Some(server.start_batcher_sharded(dispatchers)?) } else { None };

    #[cfg(unix)]
    {
        if cfg.event_loop {
            // `0` = the pre-sharding single-reactor behavior.
            let reactors = cfg.reactors.max(1);
            // Auto-scale the connection budget against RLIMIT_NOFILE:
            // raise the soft limit toward what max_conns needs (plus
            // headroom for the listener, per-reactor wake pipes, the
            // data dir, and stdio), and clamp max_conns down — loudly —
            // when the hard limit cannot cover it. Without this a
            // too-generous budget turns into silent accept failures at
            // fd exhaustion instead of typed 503s.
            let headroom = 64 + 2 * reactors;
            let want = cfg.max_conns.max(1);
            let soft = crate::util::poll::raise_nofile_limit((want + headroom) as u64);
            let max_conns = if soft == 0 {
                want // could not read the limit; trust the caller
            } else {
                let budget = (soft as usize).saturating_sub(headroom).max(1);
                if budget < want {
                    eprintln!(
                        "[semcached] max_conns {want} exceeds the RLIMIT_NOFILE budget; \
                         clamping to {budget} (soft limit {soft}, headroom {headroom})"
                    );
                }
                want.min(budget)
            };
            let handle = super::reactor::serve_event_loop(
                server,
                batcher.clone(),
                listener,
                super::reactor::ReactorConfig {
                    workers: cfg.workers.max(1),
                    reactors,
                    max_body: cfg.max_body_bytes,
                    max_conns,
                    read_timeout: cfg.read_timeout,
                    poll_fallback: cfg.poll_fallback,
                },
            )?;
            return Ok(HttpHandle { addr, batcher, inner: HandleInner::Event(Some(handle)) });
        }
    }

    serve_threaded(server, cfg, listener, addr, batcher)
}

/// The legacy blocking accept-thread + connection-worker-pool front-end
/// (and the only mode on non-unix targets).
fn serve_threaded(
    server: Arc<Server>,
    cfg: HttpConfig,
    listener: TcpListener,
    addr: SocketAddr,
    batcher: Option<Arc<Batcher>>,
) -> Result<HttpHandle> {
    let stop = Arc::new(AtomicBool::new(false));
    // Bounded hand-off queue: when every worker is busy and the queue is
    // full, the accept thread blocks (backpressure) instead of buffering
    // connections without limit.
    let (tx, rx) = mpsc::sync_channel::<TcpStream>(128);
    let rx = Arc::new(Mutex::new(rx));

    let mut workers = Vec::with_capacity(cfg.workers.max(1));
    for w in 0..cfg.workers.max(1) {
        let rx = rx.clone();
        let server = server.clone();
        let batcher = batcher.clone();
        let max_body = cfg.max_body_bytes;
        let read_timeout = cfg.read_timeout;
        let stop = stop.clone();
        let handle = std::thread::Builder::new()
            .name(format!("http-worker-{w}"))
            .spawn(move || loop {
                // Hold the receiver lock only while waiting for the next
                // connection; channel disconnect (accept thread gone)
                // ends the worker.
                let conn = rx.lock().unwrap().recv();
                let stream = match conn {
                    Ok(s) => s,
                    Err(_) => break,
                };
                let _ = stream.set_read_timeout(Some(read_timeout));
                let _ = stream.set_nodelay(true);
                let metrics = server.metrics();
                metrics.record_conn_open();
                // A panicking handler must not shrink the fixed pool:
                // catch, drop the connection, keep serving.
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    handle_connection(&server, batcher.as_deref(), stream, max_body, &stop);
                }));
                metrics.record_conn_closed();
                if outcome.is_err() {
                    eprintln!("[semcached] connection handler panicked; worker recovered");
                }
            })
            .expect("spawn http worker");
        workers.push(handle);
    }

    let accept_stop = stop.clone();
    let accept = std::thread::Builder::new()
        .name("http-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        if tx.send(stream).is_err() {
                            break;
                        }
                    }
                    Err(_) => {
                        if accept_stop.load(Ordering::SeqCst) {
                            break;
                        }
                        // Transient accept failure (e.g. fd exhaustion):
                        // back off instead of spinning a core.
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
            }
            // Dropping `tx` here disconnects the channel; idle workers
            // wake from recv and exit.
        })
        .expect("spawn http accept");

    Ok(HttpHandle {
        addr,
        batcher,
        inner: HandleInner::Threaded { stop, accept: Some(accept), workers },
    })
}

/// Owns the front-end's threads; shuts them down on `shutdown` or drop.
pub struct HttpHandle {
    addr: SocketAddr,
    batcher: Option<Arc<Batcher>>,
    inner: HandleInner,
}

enum HandleInner {
    Threaded {
        stop: Arc<AtomicBool>,
        accept: Option<JoinHandle<()>>,
        workers: Vec<JoinHandle<()>>,
    },
    #[cfg(unix)]
    Event(Option<super::reactor::EventLoopHandle>),
}

impl HttpHandle {
    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain the workers, and join every thread.
    pub fn shutdown(self) {
        // Drop runs the real teardown; taking `self` by value makes the
        // intent explicit at call sites.
    }

    fn stop_threads(&mut self) {
        let addr = self.addr;
        match &mut self.inner {
            HandleInner::Threaded { stop, accept, workers } => {
                if !stop.swap(true, Ordering::SeqCst) {
                    // Wake the accept loop with a throwaway connection.
                    // Workers observe the stop flag after their in-flight
                    // request, so the join below waits at most one
                    // request + read_timeout per still-open keep-alive
                    // connection.
                    let _ = TcpStream::connect(addr);
                    if let Some(h) = accept.take() {
                        let _ = h.join();
                    }
                    for h in workers.drain(..) {
                        let _ = h.join();
                    }
                }
            }
            #[cfg(unix)]
            HandleInner::Event(handle) => {
                if let Some(mut h) = handle.take() {
                    h.shutdown();
                }
            }
        }
        // Only after every request worker has drained (no more
        // submitters) is it safe to stop the dispatcher.
        if let Some(b) = self.batcher.take() {
            b.shutdown();
        }
    }
}

impl Drop for HttpHandle {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

/// One parsed request.
pub(super) struct HttpRequest {
    pub(super) method: String,
    pub(super) path: String,
    pub(super) body: Vec<u8>,
    pub(super) keep_alive: bool,
}

/// One response about to be written.
#[derive(Debug, Clone, PartialEq)]
pub struct HttpResponse {
    pub status: u16,
    /// JSON body.
    pub body: String,
}

impl HttpResponse {
    pub(super) fn json(status: u16, v: &Value) -> Self {
        Self { status, body: v.to_string() }
    }

    pub(super) fn error(status: u16, msg: &str) -> Self {
        Self::json(status, &obj([("error", msg.into())]))
    }
}

/// Seconds advertised in the `Retry-After` header on every 503.
pub(super) const RETRY_AFTER_SECS: u64 = 1;

/// HTTP status for a typed query reply: upstream-unavailable rejections
/// (breaker open / deadline exhausted / load shed, with no degraded
/// candidate in cache) are 503 backpressure like a full batcher queue.
/// Everything else — hits, misses, degraded hits, and rejections the
/// caller's own options produced — stays 200 with the outcome in the
/// body.
pub(super) fn query_response_status(resp: &QueryResponse) -> u16 {
    match &resp.outcome {
        Outcome::Rejected { reason } if reason.starts_with(REASON_UPSTREAM_UNAVAILABLE) => 503,
        _ => 200,
    }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

// ---------------------------------------------------------------------
// Incremental request parsing (shared by both serving modes).
// ---------------------------------------------------------------------

/// Longest accepted request/header line, bytes (8 KB, nginx's default).
const MAX_LINE_BYTES: u64 = 8 * 1024;
/// Cap on the total size of one request's header section.
const MAX_HEADER_BYTES: usize = 16 * 1024;

/// What [`RequestParser::next_step`] produced.
pub(super) enum ParseStep {
    /// The buffered bytes don't complete a request yet; feed more via
    /// [`RequestParser::push`].
    NeedMore,
    /// One complete request (leftover pipelined bytes stay buffered).
    Request(HttpRequest),
    /// Protocol violation: write this 4xx/5xx and close the connection.
    Error(HttpResponse),
    /// Clean close (EOF or a bare newline at a request boundary).
    Close,
}

/// Where the parser currently is, for driver-side timeout/EOF mapping.
pub(super) enum ParsePhase {
    /// At a request boundary with nothing buffered (an idle keep-alive
    /// connection).
    Idle,
    /// A partial request line is buffered.
    RequestLine,
    Headers,
    Body,
}

enum ParseState {
    RequestLine,
    Headers,
    Body,
    /// The declared body exceeds `max_body`: consume (a bounded amount
    /// of) it so the client can finish writing and read the 413 instead
    /// of a reset connection, then fail.
    Drain { remaining: usize },
}

enum LineResult {
    Line(String, usize),
    NeedMore,
    TooLong,
}

/// Incremental HTTP/1.1 request parser: a per-connection state machine
/// fed arbitrary byte chunks. Both the event loop (nonblocking reads)
/// and the threaded path (blocking chunked reads) drive the same
/// machine, so framing/limit semantics cannot diverge between modes.
pub(super) struct RequestParser {
    max_body: usize,
    buf: Vec<u8>,
    /// Consumed offset into `buf` (compacted opportunistically).
    pos: usize,
    state: ParseState,
    method: String,
    path: String,
    keep_alive: bool,
    content_length: usize,
    header_bytes: usize,
}

impl RequestParser {
    pub(super) fn new(max_body: usize) -> Self {
        Self {
            max_body,
            buf: Vec::new(),
            pos: 0,
            state: ParseState::RequestLine,
            method: String::new(),
            path: String::new(),
            keep_alive: true,
            content_length: 0,
            header_bytes: 0,
        }
    }

    /// Feed bytes read off the socket.
    pub(super) fn push(&mut self, bytes: &[u8]) {
        self.compact();
        self.buf.extend_from_slice(bytes);
    }

    fn compact(&mut self) {
        if self.pos == 0 {
            return;
        }
        if self.pos >= self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos > 4096 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }

    pub(super) fn phase(&self) -> ParsePhase {
        match self.state {
            ParseState::RequestLine => {
                if self.pos >= self.buf.len() {
                    ParsePhase::Idle
                } else {
                    ParsePhase::RequestLine
                }
            }
            ParseState::Headers => ParsePhase::Headers,
            ParseState::Body | ParseState::Drain { .. } => ParsePhase::Body,
        }
    }

    /// True when un-consumed bytes are buffered (pipelined input the
    /// driver should parse without waiting for another read).
    pub(super) fn has_buffered(&self) -> bool {
        self.pos < self.buf.len()
    }

    /// The response owed to a peer that stalled mid-request past the
    /// driver's timeout. `None` = at (or before) a request boundary:
    /// close silently, like an idle keep-alive connection. Shared by
    /// the blocking driver's read-timeout path and the reactor's idle
    /// sweep so the two modes cannot diverge. A stall while draining an
    /// over-limit body still reports 413 — the request's real problem —
    /// not a truncation it never had.
    pub(super) fn stall_response(&self) -> Option<HttpResponse> {
        match self.state {
            ParseState::RequestLine => None,
            ParseState::Headers => Some(HttpResponse::error(408, "timed out reading headers")),
            ParseState::Body => Some(HttpResponse::error(400, "truncated request body")),
            ParseState::Drain { .. } => Some(self.oversized()),
        }
    }

    fn take_line(&mut self) -> LineResult {
        let avail = &self.buf[self.pos..];
        match avail.iter().position(|&b| b == b'\n') {
            Some(i) if (i as u64) < MAX_LINE_BYTES => {
                let line = String::from_utf8_lossy(&avail[..i]).trim_end().to_string();
                self.pos += i + 1;
                LineResult::Line(line, i + 1)
            }
            Some(_) => LineResult::TooLong,
            None if avail.len() as u64 >= MAX_LINE_BYTES => LineResult::TooLong,
            None => LineResult::NeedMore,
        }
    }

    fn oversized(&self) -> HttpResponse {
        HttpResponse::error(
            413,
            &format!(
                "body of {} bytes exceeds the {}-byte limit",
                self.content_length, self.max_body
            ),
        )
    }

    /// Parse one request line. `Ok(false)` = empty line at a request
    /// boundary (clean close, mirroring the blocking reader).
    fn begin_request(&mut self, line: &str) -> std::result::Result<bool, HttpResponse> {
        if line.is_empty() {
            return Ok(false);
        }
        let mut parts = line.split_whitespace();
        let method = parts.next().unwrap_or("").to_string();
        let path = parts.next().unwrap_or("").to_string();
        let version = parts.next().unwrap_or("");
        if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
            return Err(HttpResponse::error(400, "malformed request line"));
        }
        self.keep_alive = version != "HTTP/1.0";
        self.method = method;
        self.path = path;
        self.content_length = 0;
        self.header_bytes = 0;
        self.state = ParseState::Headers;
        Ok(true)
    }

    fn header_line(&mut self, line: &str) -> std::result::Result<(), HttpResponse> {
        if let Some((k, v)) = line.split_once(':') {
            let v = v.trim();
            match k.trim().to_ascii_lowercase().as_str() {
                "content-length" => {
                    self.content_length = v
                        .parse()
                        .map_err(|_| HttpResponse::error(400, "bad content-length"))?;
                }
                "connection" => {
                    if v.eq_ignore_ascii_case("close") {
                        self.keep_alive = false;
                    } else if v.eq_ignore_ascii_case("keep-alive") {
                        self.keep_alive = true;
                    }
                }
                "transfer-encoding" => {
                    return Err(HttpResponse::error(501, "chunked bodies not supported"));
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Advance the state machine as far as the buffered bytes allow.
    pub(super) fn next_step(&mut self) -> ParseStep {
        loop {
            match self.state {
                ParseState::RequestLine => {
                    let line = match self.take_line() {
                        LineResult::NeedMore => return ParseStep::NeedMore,
                        LineResult::TooLong => {
                            return ParseStep::Error(HttpResponse::error(
                                431,
                                "request line too long",
                            ));
                        }
                        LineResult::Line(l, _) => l,
                    };
                    match self.begin_request(&line) {
                        Ok(true) => {}
                        Ok(false) => return ParseStep::Close,
                        Err(resp) => return ParseStep::Error(resp),
                    }
                }
                ParseState::Headers => {
                    let (line, n) = match self.take_line() {
                        LineResult::NeedMore => return ParseStep::NeedMore,
                        LineResult::TooLong => {
                            return ParseStep::Error(HttpResponse::error(
                                431,
                                "header line too long",
                            ));
                        }
                        LineResult::Line(l, n) => (l, n),
                    };
                    self.header_bytes += n;
                    if self.header_bytes > MAX_HEADER_BYTES {
                        return ParseStep::Error(HttpResponse::error(431, "headers too large"));
                    }
                    if line.is_empty() {
                        if self.content_length > self.max_body {
                            self.state = ParseState::Drain {
                                remaining: self.content_length.min(4 * self.max_body.max(1)),
                            };
                        } else {
                            self.state = ParseState::Body;
                        }
                        continue;
                    }
                    if let Err(resp) = self.header_line(&line) {
                        return ParseStep::Error(resp);
                    }
                }
                ParseState::Body => {
                    if self.buf.len() - self.pos < self.content_length {
                        return ParseStep::NeedMore;
                    }
                    let body = self.buf[self.pos..self.pos + self.content_length].to_vec();
                    self.pos += self.content_length;
                    let req = HttpRequest {
                        method: std::mem::take(&mut self.method),
                        path: std::mem::take(&mut self.path),
                        body,
                        keep_alive: self.keep_alive,
                    };
                    self.state = ParseState::RequestLine;
                    self.compact();
                    return ParseStep::Request(req);
                }
                ParseState::Drain { remaining } => {
                    let take = (self.buf.len() - self.pos).min(remaining);
                    self.pos += take;
                    self.compact();
                    if remaining - take == 0 {
                        return ParseStep::Error(self.oversized());
                    }
                    self.state = ParseState::Drain { remaining: remaining - take };
                    return ParseStep::NeedMore;
                }
            }
        }
    }

    /// The peer closed its write side: resolve whatever is buffered.
    /// Mirrors the blocking reader's EOF handling (partial request line
    /// parsed as-is, mid-headers/mid-body answered 400, an oversized
    /// body cut short still answered 413).
    pub(super) fn finish_eof(&mut self) -> ParseStep {
        match self.state {
            ParseState::RequestLine => {
                if self.pos >= self.buf.len() {
                    return ParseStep::Close;
                }
                let line =
                    String::from_utf8_lossy(&self.buf[self.pos..]).trim_end().to_string();
                self.pos = self.buf.len();
                match self.begin_request(&line) {
                    Ok(false) => ParseStep::Close,
                    Ok(true) => {
                        ParseStep::Error(HttpResponse::error(400, "connection closed mid-headers"))
                    }
                    Err(resp) => ParseStep::Error(resp),
                }
            }
            ParseState::Headers => {
                ParseStep::Error(HttpResponse::error(400, "connection closed mid-headers"))
            }
            ParseState::Body => {
                ParseStep::Error(HttpResponse::error(400, "truncated request body"))
            }
            ParseState::Drain { .. } => ParseStep::Error(self.oversized()),
        }
    }
}

// ---------------------------------------------------------------------
// Blocking (threaded-accept) connection driver.
// ---------------------------------------------------------------------

/// Serve one connection: parse → route → respond, looping while the
/// client keeps the connection alive (and the front-end is not
/// shutting down).
fn handle_connection(
    server: &Arc<Server>,
    batcher: Option<&Batcher>,
    stream: TcpStream,
    max_body: usize,
    stop: &AtomicBool,
) {
    let mut stream = stream;
    let mut parser = RequestParser::new(max_body);
    loop {
        match next_request(&mut stream, &mut parser) {
            Ok(Some(req)) => {
                let keep_alive = req.keep_alive;
                let resp = route(server, batcher, &req);
                if write_response(&mut stream, &resp, keep_alive).is_err()
                    || !keep_alive
                    || stop.load(Ordering::SeqCst)
                {
                    return;
                }
            }
            Ok(None) => return, // clean close between requests
            Err(resp) => {
                // A malformed request still counts as one request, so
                // http_errors never exceeds http_requests.
                let metrics = server.metrics();
                metrics.record_http_request();
                metrics.record_http_error();
                let _ = write_response(&mut stream, &resp, false);
                return;
            }
        }
    }
}

/// Read one request with blocking chunked reads through the shared
/// incremental parser. `Ok(None)` = the client closed (or went idle past
/// the read timeout) between requests; `Err` carries the 4xx to send
/// before closing.
fn next_request(
    stream: &mut TcpStream,
    parser: &mut RequestParser,
) -> std::result::Result<Option<HttpRequest>, HttpResponse> {
    loop {
        match parser.next_step() {
            ParseStep::Request(r) => return Ok(Some(r)),
            ParseStep::Close => return Ok(None),
            ParseStep::Error(resp) => return Err(resp),
            ParseStep::NeedMore => {}
        }
        let mut chunk = [0u8; 8192];
        match stream.read(&mut chunk) {
            Ok(0) => {
                return match parser.finish_eof() {
                    ParseStep::Request(r) => Ok(Some(r)),
                    ParseStep::Error(resp) => Err(resp),
                    ParseStep::Close | ParseStep::NeedMore => Ok(None),
                };
            }
            Ok(n) => parser.push(&chunk[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Read timeout: an idle keep-alive connection (or one
                // that never finished its request line) closes quietly;
                // a stall mid-request is answered.
                return match parser.stall_response() {
                    None => Ok(None),
                    Some(resp) => Err(resp),
                };
            }
            Err(_) => return Ok(None), // reset mid-request
        }
    }
}

// ---------------------------------------------------------------------
// Response writing.
// ---------------------------------------------------------------------

/// Serialize head + body into one buffer (a single write syscall in the
/// common case; the event loop resumes from any offset on partial
/// writes).
pub(super) fn serialize_response(resp: &HttpResponse, keep_alive: bool) -> Vec<u8> {
    // Every 503 is backpressure (full batcher queue, over-max_conns, or
    // upstream unavailable) — advertise when to come back so well-behaved
    // clients don't hammer an open breaker. One emission point covers
    // every 503 path by construction.
    let retry_after = if resp.status == 503 {
        format!("Retry-After: {RETRY_AFTER_SECS}\r\n")
    } else {
        String::new()
    };
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n{}Connection: {}\r\n\r\n",
        resp.status,
        status_text(resp.status),
        resp.body.len(),
        retry_after,
        if keep_alive { "keep-alive" } else { "close" },
    );
    let mut out = Vec::with_capacity(head.len() + resp.body.len());
    out.extend_from_slice(head.as_bytes());
    out.extend_from_slice(resp.body.as_bytes());
    out
}

/// Write a whole response, resuming across short writes, `EINTR`, and
/// `EWOULDBLOCK` (a socket with a tiny send buffer, a write timeout, or
/// nonblocking mode must not lose the response tail — regression-tested
/// with a tiny-`SO_SNDBUF` socket in `tests/http_protocol.rs`).
pub fn write_response(
    w: &mut TcpStream,
    resp: &HttpResponse,
    keep_alive: bool,
) -> std::io::Result<()> {
    let bytes = serialize_response(resp, keep_alive);
    write_all_resumable(w, &bytes)?;
    w.flush()
}

fn write_all_resumable(w: &mut TcpStream, buf: &[u8]) -> std::io::Result<()> {
    // Bound the total time spent retrying a never-draining socket so a
    // dead peer cannot pin a connection worker forever.
    write_all_deadline(w, buf, Duration::from_secs(20))
}

/// Write all of `buf`, resuming across short writes and `EINTR`, and
/// retrying `EWOULDBLOCK`/`TimedOut` stalls for at most `limit` of
/// *cumulative* stall time (progress resets the clock). The reactor's
/// accept-path refusals use a short limit — a 503 is tens of bytes, so
/// any live peer drains it immediately, while a dead one must not pin
/// the reactor thread.
pub(super) fn write_all_deadline(
    w: &mut TcpStream,
    mut buf: &[u8],
    limit: Duration,
) -> std::io::Result<()> {
    let limit_ms = limit.as_millis() as u64;
    let mut stalled_ms = 0u64;
    while !buf.is_empty() {
        match w.write(buf) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "socket accepted 0 bytes",
                ));
            }
            Ok(n) => {
                buf = &buf[n..];
                stalled_ms = 0;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                stalled_ms += 1;
                if stalled_ms > limit_ms {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "peer stopped draining the response",
                    ));
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Routing (shared by both serving modes).
// ---------------------------------------------------------------------

/// First routing stage: everything except a batched `/v1/query` resolves
/// to a ready response on the calling thread; a batched query is handed
/// back so the driver chooses blocking submit (threaded mode) or a
/// completion callback (event loop).
pub(super) enum Routed {
    Ready(HttpResponse),
    BatchedQuery(QueryRequest),
}

/// Dispatch one parsed request to the typed API. Records
/// `http_requests` (and `http_errors` for every ready response ≥ 400).
pub(super) fn route_begin(server: &Arc<Server>, batched: bool, req: &HttpRequest) -> Routed {
    server.metrics().record_http_request();
    let resp = match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/query") => match parse_query_request(&req.body) {
            Ok(q) if batched => return Routed::BatchedQuery(q),
            Ok(q) => {
                let r = server.serve(&q);
                HttpResponse::json(query_response_status(&r), &r.to_json())
            }
            Err(resp) => resp,
        },
        ("POST", "/v1/query_batch") => post_query_batch(server, &req.body),
        ("POST", "/v1/admin") => post_admin(server, &req.body),
        ("GET", "/v1/metrics") => HttpResponse::json(200, &server.stats_json()),
        ("GET", "/v1/health") => HttpResponse::json(200, &obj([("status", "ok".into())])),
        (_, "/v1/query" | "/v1/query_batch" | "/v1/admin" | "/v1/metrics" | "/v1/health") => {
            HttpResponse::error(405, "method not allowed for this endpoint")
        }
        _ => HttpResponse::error(404, "unknown endpoint"),
    };
    if resp.status >= 400 {
        server.metrics().record_http_error();
    }
    Routed::Ready(resp)
}

/// A rejected batcher submit (full queue / shutdown): backpressure, not
/// an error in the request — answer 503 with a typed `Rejected` body so
/// clients can tell "overloaded, retry" from a 4xx.
pub(super) fn rejected_submit_response(
    server: &Arc<Server>,
    q: &QueryRequest,
    err: &super::batcher::SubmitError,
) -> HttpResponse {
    server.metrics().record_http_error();
    HttpResponse::json(503, &QueryResponse::rejected(q, err.to_string()).to_json())
}

/// Threaded-mode completion of a batched query: block on the dispatch.
fn route(server: &Arc<Server>, batcher: Option<&Batcher>, req: &HttpRequest) -> HttpResponse {
    match route_begin(server, batcher.is_some(), req) {
        Routed::Ready(resp) => resp,
        Routed::BatchedQuery(q) => {
            let b = batcher.expect("batched route without a batcher");
            match b.submit(&q) {
                Ok(resp) => {
                    let status = query_response_status(&resp);
                    if status >= 400 {
                        server.metrics().record_http_error();
                    }
                    HttpResponse::json(status, &resp.to_json())
                }
                Err(e) => rejected_submit_response(server, &q, &e),
            }
        }
    }
}

fn parse_body(body: &[u8]) -> std::result::Result<Value, HttpResponse> {
    let text = std::str::from_utf8(body)
        .map_err(|_| HttpResponse::error(400, "body is not valid UTF-8"))?;
    json::parse(text).map_err(|e| HttpResponse::error(400, &format!("invalid JSON: {e}")))
}

fn parse_query_request(body: &[u8]) -> std::result::Result<QueryRequest, HttpResponse> {
    let v = parse_body(body)?;
    QueryRequest::from_json(&v).map_err(|e| HttpResponse::error(400, &format!("{e:#}")))
}

fn post_query_batch(server: &Arc<Server>, body: &[u8]) -> HttpResponse {
    let v = match parse_body(body) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let arr = match v.get("queries").as_array() {
        Some(a) => a,
        None => return HttpResponse::error(400, "missing array field 'queries'"),
    };
    let mut reqs = Vec::with_capacity(arr.len());
    for (i, item) in arr.iter().enumerate() {
        match QueryRequest::from_json(item) {
            Ok(r) => reqs.push(r),
            Err(e) => return HttpResponse::error(400, &format!("queries[{i}]: {e:#}")),
        }
    }
    let replies: Vec<Value> = server.serve_batch(&reqs).iter().map(|r| r.to_json()).collect();
    HttpResponse::json(200, &obj([("replies", Value::Array(replies))]))
}

fn post_admin(server: &Arc<Server>, body: &[u8]) -> HttpResponse {
    let v = match parse_body(body) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    match AdminRequest::from_json(&v) {
        Ok(req) => HttpResponse::json(200, &server.admin(&req).to_json()),
        Err(e) => HttpResponse::error(400, &format!("{e:#}")),
    }
}

/// Minimal blocking HTTP/1.1 client (`Connection: close`), used by the
/// `semcached` client subcommands, the loopback smoke test in
/// `verify.sh`, and the integration tests. Returns the status code and
/// the parsed JSON body (`Value::Null` for an empty body).
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<(u16, Value)> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let body = body.unwrap_or("");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len(),
    );
    let mut writer = stream.try_clone().context("cloning stream")?;
    writer.write_all(head.as_bytes()).context("writing request head")?;
    writer.write_all(body.as_bytes()).context("writing request body")?;
    writer.flush().context("flushing request")?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).context("reading status line")?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("malformed status line {status_line:?}"))?;
    let mut content_length: Option<usize> = None;
    loop {
        let mut h = String::new();
        let n = reader.read_line(&mut h).context("reading response headers")?;
        if n == 0 {
            bail!("connection closed mid-headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().ok();
            }
        }
    }
    let mut bytes = Vec::new();
    match content_length {
        Some(n) => {
            bytes = vec![0u8; n];
            reader.read_exact(&mut bytes).context("reading response body")?;
        }
        None => {
            reader.read_to_end(&mut bytes).context("reading response body")?;
        }
    }
    let text =
        String::from_utf8(bytes).map_err(|_| anyhow!("response body is not valid UTF-8"))?;
    let value = if text.trim().is_empty() { Value::Null } else { json::parse(&text)? };
    Ok((status, value))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_texts_cover_served_codes() {
        for code in [200, 400, 404, 405, 408, 413, 431, 500, 501, 503] {
            assert_ne!(status_text(code), "Unknown", "code {code}");
        }
        assert_eq!(status_text(999), "Unknown");
    }

    #[test]
    fn error_responses_are_json() {
        let r = HttpResponse::error(400, "nope");
        assert_eq!(r.status, 400);
        let v = json::parse(&r.body).unwrap();
        assert_eq!(v.get("error").as_str(), Some("nope"));
    }

    // ---------- incremental parser ----------

    fn step_err(p: &mut RequestParser) -> HttpResponse {
        match p.next_step() {
            ParseStep::Error(resp) => resp,
            _ => panic!("expected a parse error"),
        }
    }

    #[test]
    fn parser_handles_byte_at_a_time_delivery() {
        let raw = b"POST /v1/query HTTP/1.1\r\nContent-Length: 4\r\n\r\nbody";
        let mut p = RequestParser::new(1024);
        for (i, b) in raw.iter().enumerate() {
            match p.next_step() {
                ParseStep::NeedMore => {}
                _ => panic!("complete result before byte {i}"),
            }
            p.push(&[*b]);
        }
        match p.next_step() {
            ParseStep::Request(req) => {
                assert_eq!(req.method, "POST");
                assert_eq!(req.path, "/v1/query");
                assert_eq!(req.body, b"body");
                assert!(req.keep_alive);
            }
            _ => panic!("expected a complete request"),
        }
        assert!(!p.has_buffered());
        assert!(matches!(p.phase(), ParsePhase::Idle));
    }

    #[test]
    fn parser_yields_pipelined_requests_in_order() {
        let raw = b"POST /a HTTP/1.1\r\nContent-Length: 1\r\n\r\nXPOST /b HTTP/1.1\r\nContent-Length: 1\r\n\r\nY";
        let mut p = RequestParser::new(1024);
        p.push(raw);
        let first = match p.next_step() {
            ParseStep::Request(r) => r,
            _ => panic!("first request"),
        };
        assert_eq!((first.path.as_str(), first.body.as_slice()), ("/a", b"X".as_slice()));
        assert!(p.has_buffered(), "second request stays buffered");
        let second = match p.next_step() {
            ParseStep::Request(r) => r,
            _ => panic!("second request"),
        };
        assert_eq!((second.path.as_str(), second.body.as_slice()), ("/b", b"Y".as_slice()));
        assert!(matches!(p.next_step(), ParseStep::NeedMore));
    }

    #[test]
    fn parser_keep_alive_semantics_by_version_and_header() {
        let cases: [(&[u8], bool); 4] = [
            (b"GET /v1/health HTTP/1.1\r\n\r\n".as_slice(), true),
            (b"GET /v1/health HTTP/1.0\r\n\r\n".as_slice(), false),
            (b"GET /v1/health HTTP/1.0\r\nConnection: keep-alive\r\n\r\n".as_slice(), true),
            (b"GET /v1/health HTTP/1.1\r\nConnection: close\r\n\r\n".as_slice(), false),
        ];
        for (raw, expect) in cases {
            let mut p = RequestParser::new(64);
            p.push(raw);
            match p.next_step() {
                ParseStep::Request(r) => {
                    assert_eq!(r.keep_alive, expect, "{:?}", String::from_utf8_lossy(raw))
                }
                _ => panic!("expected request for {:?}", String::from_utf8_lossy(raw)),
            }
        }
    }

    #[test]
    fn parser_rejects_garbage_and_oversize() {
        // Garbage prefix: not an HTTP/1.x request line.
        let mut p = RequestParser::new(64);
        p.push(b"!!garbage frame??\r\n");
        assert_eq!(step_err(&mut p).status, 400);

        // Newline-less flood beyond the line limit.
        let mut p = RequestParser::new(64);
        p.push(&vec![b'a'; (MAX_LINE_BYTES as usize) + 1]);
        assert_eq!(step_err(&mut p).status, 431);

        // One huge header line.
        let mut p = RequestParser::new(64);
        p.push(b"GET /v1/health HTTP/1.1\r\n");
        p.push(b"X-Big: ");
        p.push(&vec![b'b'; MAX_LINE_BYTES as usize]);
        assert_eq!(step_err(&mut p).status, 431);

        // Headers legal individually but too large in total.
        let mut p = RequestParser::new(64);
        p.push(b"GET /v1/health HTTP/1.1\r\n");
        for i in 0..20 {
            let mut line = format!("X-Pad-{i}: ").into_bytes();
            line.extend(std::iter::repeat(b'p').take(1000));
            line.extend_from_slice(b"\r\n");
            p.push(&line);
        }
        assert_eq!(step_err(&mut p).status, 431);

        // Declared body beyond the limit: drains (bounded), then 413.
        let mut p = RequestParser::new(16);
        p.push(b"POST /v1/query HTTP/1.1\r\nContent-Length: 100\r\n\r\n");
        assert!(matches!(p.next_step(), ParseStep::NeedMore));
        p.push(&[b'x'; 100]);
        assert_eq!(step_err(&mut p).status, 413);

        // Chunked transfer encoding is explicitly unimplemented.
        let mut p = RequestParser::new(64);
        p.push(b"POST /v1/query HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n");
        assert_eq!(step_err(&mut p).status, 501);
    }

    #[test]
    fn stall_responses_match_parse_state() {
        // At (or before) a request boundary: close silently.
        let mut p = RequestParser::new(64);
        assert!(p.stall_response().is_none(), "idle boundary closes silently");
        p.push(b"GET /half");
        assert!(matches!(p.next_step(), ParseStep::NeedMore));
        assert!(p.stall_response().is_none(), "partial request line closes silently");

        // Mid-headers: 408.
        let mut p = RequestParser::new(64);
        p.push(b"GET /v1/health HTTP/1.1\r\nHost: x\r\n");
        assert!(matches!(p.next_step(), ParseStep::NeedMore));
        assert_eq!(p.stall_response().expect("mid-header stall").status, 408);

        // Mid-body: 400.
        let mut p = RequestParser::new(64);
        p.push(b"POST /v1/query HTTP/1.1\r\nContent-Length: 10\r\n\r\nha");
        assert!(matches!(p.next_step(), ParseStep::NeedMore));
        assert_eq!(p.stall_response().expect("mid-body stall").status, 400);

        // Stalling while draining an over-limit body is still 413 (the
        // request's real problem), not a bogus truncation diagnosis.
        let mut p = RequestParser::new(16);
        p.push(b"POST /v1/query HTTP/1.1\r\nContent-Length: 100000\r\n\r\npartial");
        assert!(matches!(p.next_step(), ParseStep::NeedMore));
        assert_eq!(p.stall_response().expect("drain stall").status, 413);
    }

    #[test]
    fn parser_eof_resolution() {
        // Clean EOF at a boundary.
        let mut p = RequestParser::new(64);
        assert!(matches!(p.finish_eof(), ParseStep::Close));

        // EOF mid-headers.
        let mut p = RequestParser::new(64);
        p.push(b"GET /v1/health HTTP/1.1\r\nHost: x\r\n");
        assert!(matches!(p.next_step(), ParseStep::NeedMore));
        match p.finish_eof() {
            ParseStep::Error(resp) => assert_eq!(resp.status, 400),
            _ => panic!("mid-header EOF must error"),
        }

        // EOF mid-body.
        let mut p = RequestParser::new(64);
        p.push(b"POST /v1/query HTTP/1.1\r\nContent-Length: 10\r\n\r\nhal");
        assert!(matches!(p.next_step(), ParseStep::NeedMore));
        match p.finish_eof() {
            ParseStep::Error(resp) => assert_eq!(resp.status, 400),
            _ => panic!("mid-body EOF must error"),
        }

        // EOF with a partial request line: parsed as-is (malformed).
        let mut p = RequestParser::new(64);
        p.push(b"GET /half");
        assert!(matches!(p.next_step(), ParseStep::NeedMore));
        match p.finish_eof() {
            ParseStep::Error(resp) => assert_eq!(resp.status, 400),
            _ => panic!("partial request line at EOF must error"),
        }
    }
}
