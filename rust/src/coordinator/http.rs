//! Zero-dependency HTTP/1.1 front-end for the typed serving API.
//!
//! A small `std::net::TcpListener` daemon in the spirit of the paper's
//! "semantic cache as a web service in front of the LLM API": one accept
//! thread feeds a fixed pool of connection workers (the same worker-pool
//! pattern as the batch serving pipeline), each speaking just enough
//! HTTP/1.1 for JSON request/response bodies with keep-alive. The wire
//! format is the [`crate::api`] types via the in-tree [`crate::json`]
//! codec — no external crates anywhere.
//!
//! Endpoints (all JSON):
//!
//! | Method | Path              | Body                      | Reply |
//! |--------|-------------------|---------------------------|-------|
//! | POST   | `/v1/query`       | [`QueryRequest`]          | [`crate::api::QueryResponse`] |
//! | POST   | `/v1/query_batch` | `{"queries": [...]}`      | `{"replies": [...]}` |
//! | GET    | `/v1/metrics`     | —                         | metrics + cache state |
//! | POST   | `/v1/admin`       | [`AdminRequest`]          | [`crate::api::AdminResponse`] |
//! | GET    | `/v1/health`      | —                         | `{"status": "ok"}` |
//!
//! Malformed input is answered with 4xx JSON errors (`{"error": ...}`),
//! never a panic or dropped connection: bad JSON and bad fields are 400,
//! unknown paths 404, wrong methods 405, oversized bodies 413. A panic
//! escaping a handler is caught so the worker pool never shrinks.
//!
//! By default (`HttpConfig::batching`) `POST /v1/query` routes through
//! the cross-request micro-batching engine ([`super::batcher`]):
//! concurrent in-flight queries from different connections are coalesced
//! into single `serve_batch` calls, identical in-flight queries are
//! answered once, and a full submit queue is answered `503 Service
//! Unavailable` with an `Outcome::Rejected` body (backpressure).
//! `/v1/query_batch` already carries a batch and keeps calling
//! `serve_batch` directly.
//!
//! Scale limitation (tracked in ROADMAP): this is blocking
//! thread-per-connection serving — an idle keep-alive connection pins
//! its worker until `read_timeout`, and accepted connections beyond the
//! pool wait in a bounded queue (accepting blocks when it fills). An
//! async/epoll accept path is the planned next step for heavy fan-in.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::{AdminRequest, QueryRequest, QueryResponse};
use crate::error::{anyhow, bail, Context, Result};
use crate::json::{self, obj, Value};

use super::batcher::Batcher;
use super::Server;

/// Front-end configuration.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`HttpHandle::local_addr`]).
    pub addr: String,
    /// Connection-handler threads.
    pub workers: usize,
    /// Request bodies beyond this answer 413.
    pub max_body_bytes: usize,
    /// Per-read socket timeout; an idle keep-alive connection is closed
    /// after this long.
    pub read_timeout: Duration,
    /// Route `POST /v1/query` through the cross-request micro-batching
    /// engine ([`super::batcher`], window policy from
    /// [`super::ServerConfig::batch`]). When the batcher's bounded
    /// queue is full the request is answered `503` with an
    /// `Outcome::Rejected` body instead of waiting. `false` serves every
    /// request as an isolated `serve()` call (the pre-batching path).
    pub batching: bool,
}

impl Default for HttpConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            max_body_bytes: 1 << 20,
            read_timeout: Duration::from_secs(10),
            batching: true,
        }
    }
}

/// Start the HTTP front-end over a running [`Server`]. Returns once the
/// listener is bound; serving happens on background threads until the
/// returned handle is shut down or dropped.
pub fn serve_http(server: Arc<Server>, cfg: HttpConfig) -> Result<HttpHandle> {
    let listener =
        TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr().context("reading bound address")?;
    let stop = Arc::new(AtomicBool::new(false));
    // Bounded hand-off queue: when every worker is busy and the queue is
    // full, the accept thread blocks (backpressure) instead of buffering
    // connections without limit.
    let (tx, rx) = mpsc::sync_channel::<TcpStream>(128);
    let rx = Arc::new(Mutex::new(rx));
    // The batcher (when enabled) is shared by every connection worker;
    // it is shut down by the handle after the workers have drained.
    let batcher = if cfg.batching { Some(server.start_batcher()?) } else { None };

    let mut workers = Vec::with_capacity(cfg.workers.max(1));
    for w in 0..cfg.workers.max(1) {
        let rx = rx.clone();
        let server = server.clone();
        let batcher = batcher.clone();
        let max_body = cfg.max_body_bytes;
        let read_timeout = cfg.read_timeout;
        let stop = stop.clone();
        let handle = std::thread::Builder::new()
            .name(format!("http-worker-{w}"))
            .spawn(move || loop {
                // Hold the receiver lock only while waiting for the next
                // connection; channel disconnect (accept thread gone)
                // ends the worker.
                let conn = rx.lock().unwrap().recv();
                let stream = match conn {
                    Ok(s) => s,
                    Err(_) => break,
                };
                let _ = stream.set_read_timeout(Some(read_timeout));
                let _ = stream.set_nodelay(true);
                // A panicking handler must not shrink the fixed pool:
                // catch, drop the connection, keep serving.
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    handle_connection(&server, batcher.as_deref(), stream, max_body, &stop);
                }));
                if outcome.is_err() {
                    eprintln!("[semcached] connection handler panicked; worker recovered");
                }
            })
            .expect("spawn http worker");
        workers.push(handle);
    }

    let accept_stop = stop.clone();
    let accept = std::thread::Builder::new()
        .name("http-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        if tx.send(stream).is_err() {
                            break;
                        }
                    }
                    Err(_) => {
                        if accept_stop.load(Ordering::SeqCst) {
                            break;
                        }
                        // Transient accept failure (e.g. fd exhaustion):
                        // back off instead of spinning a core.
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
            }
            // Dropping `tx` here disconnects the channel; idle workers
            // wake from recv and exit.
        })
        .expect("spawn http accept");

    Ok(HttpHandle { addr, stop, accept: Some(accept), workers, batcher })
}

/// Owns the front-end's threads; shuts them down on `shutdown` or drop.
pub struct HttpHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    batcher: Option<Arc<Batcher>>,
}

impl HttpHandle {
    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain the workers, and join every thread.
    pub fn shutdown(self) {
        // Drop runs the real teardown; taking `self` by value makes the
        // intent explicit at call sites.
    }

    fn stop_threads(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // Wake the accept loop with a throwaway connection. Workers
        // observe the stop flag after their in-flight request, so the
        // join below waits at most one request + read_timeout per
        // still-open keep-alive connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // Only after every connection worker has drained (no more
        // submitters) is it safe to stop the dispatcher.
        if let Some(b) = self.batcher.take() {
            b.shutdown();
        }
    }
}

impl Drop for HttpHandle {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

/// One parsed request.
struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
    keep_alive: bool,
}

/// One response about to be written.
#[derive(Debug, Clone, PartialEq)]
pub struct HttpResponse {
    pub status: u16,
    /// JSON body.
    pub body: String,
}

impl HttpResponse {
    fn json(status: u16, v: &Value) -> Self {
        Self { status, body: v.to_string() }
    }

    fn error(status: u16, msg: &str) -> Self {
        Self::json(status, &obj([("error", msg.into())]))
    }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Serve one connection: parse → route → respond, looping while the
/// client keeps the connection alive (and the front-end is not
/// shutting down).
fn handle_connection(
    server: &Arc<Server>,
    batcher: Option<&Batcher>,
    stream: TcpStream,
    max_body: usize,
    stop: &AtomicBool,
) {
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = stream;
    loop {
        match read_request(&mut reader, max_body) {
            Ok(Some(req)) => {
                let keep_alive = req.keep_alive;
                let resp = route(server, batcher, &req);
                if write_response(&mut writer, &resp, keep_alive).is_err()
                    || !keep_alive
                    || stop.load(Ordering::SeqCst)
                {
                    return;
                }
            }
            Ok(None) => return, // clean close between requests
            Err(resp) => {
                // A malformed request still counts as one request, so
                // http_errors never exceeds http_requests.
                let metrics = server.metrics();
                metrics.record_http_request();
                metrics.record_http_error();
                let _ = write_response(&mut writer, &resp, false);
                return;
            }
        }
    }
}

/// Longest accepted request/header line, bytes (8 KB, nginx's default).
const MAX_LINE_BYTES: u64 = 8 * 1024;

/// Read one `\n`-terminated line without letting a newline-less client
/// grow the buffer past [`MAX_LINE_BYTES`]. Returns the byte count read
/// (0 = EOF); an over-long line is `ErrorKind::InvalidData`.
fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
) -> std::io::Result<usize> {
    let n = reader.by_ref().take(MAX_LINE_BYTES).read_line(line)?;
    if n as u64 == MAX_LINE_BYTES && !line.ends_with('\n') {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "line too long"));
    }
    Ok(n)
}

/// Read one request. `Ok(None)` = the client closed (or went idle past
/// the read timeout) between requests; `Err` carries the 4xx to send
/// before closing.
fn read_request(
    reader: &mut BufReader<TcpStream>,
    max_body: usize,
) -> std::result::Result<Option<HttpRequest>, HttpResponse> {
    let mut line = String::new();
    match read_line_bounded(reader, &mut line) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
            return Err(HttpResponse::error(431, "request line too long"));
        }
        Err(_) => return Ok(None), // timeout/reset before a request started
    }
    let line = line.trim_end();
    if line.is_empty() {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(HttpResponse::error(400, "malformed request line"));
    }
    let mut keep_alive = version != "HTTP/1.0";
    let mut content_length: usize = 0;
    let mut header_bytes = 0usize;
    loop {
        let mut h = String::new();
        match read_line_bounded(reader, &mut h) {
            Ok(0) => return Err(HttpResponse::error(400, "connection closed mid-headers")),
            Ok(n) => header_bytes += n,
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                return Err(HttpResponse::error(431, "header line too long"));
            }
            Err(_) => return Err(HttpResponse::error(408, "timed out reading headers")),
        }
        if header_bytes > 16 * 1024 {
            return Err(HttpResponse::error(431, "headers too large"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let v = v.trim();
            match k.trim().to_ascii_lowercase().as_str() {
                "content-length" => {
                    content_length = v
                        .parse()
                        .map_err(|_| HttpResponse::error(400, "bad content-length"))?;
                }
                "connection" => {
                    if v.eq_ignore_ascii_case("close") {
                        keep_alive = false;
                    } else if v.eq_ignore_ascii_case("keep-alive") {
                        keep_alive = true;
                    }
                }
                "transfer-encoding" => {
                    return Err(HttpResponse::error(501, "chunked bodies not supported"));
                }
                _ => {}
            }
        }
    }
    if content_length > max_body {
        // Drain a bounded amount of the body so the client can finish
        // writing and read the 413 instead of seeing a reset connection.
        let mut remaining = content_length.min(4 * max_body.max(1));
        let mut sink = [0u8; 8192];
        while remaining > 0 {
            let n = sink.len().min(remaining);
            if reader.read_exact(&mut sink[..n]).is_err() {
                break;
            }
            remaining -= n;
        }
        return Err(HttpResponse::error(
            413,
            &format!("body of {content_length} bytes exceeds the {max_body}-byte limit"),
        ));
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader
            .read_exact(&mut body)
            .map_err(|_| HttpResponse::error(400, "truncated request body"))?;
    }
    Ok(Some(HttpRequest { method, path, body, keep_alive }))
}

fn write_response(w: &mut TcpStream, resp: &HttpResponse, keep_alive: bool) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        resp.status,
        status_text(resp.status),
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    w.write_all(head.as_bytes())?;
    w.write_all(resp.body.as_bytes())?;
    w.flush()
}

/// Dispatch one parsed request to the typed API.
fn route(server: &Arc<Server>, batcher: Option<&Batcher>, req: &HttpRequest) -> HttpResponse {
    server.metrics().record_http_request();
    let resp = match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/query") => post_query(server, batcher, &req.body),
        ("POST", "/v1/query_batch") => post_query_batch(server, &req.body),
        ("POST", "/v1/admin") => post_admin(server, &req.body),
        ("GET", "/v1/metrics") => HttpResponse::json(200, &server.stats_json()),
        ("GET", "/v1/health") => HttpResponse::json(200, &obj([("status", "ok".into())])),
        (_, "/v1/query" | "/v1/query_batch" | "/v1/admin" | "/v1/metrics" | "/v1/health") => {
            HttpResponse::error(405, "method not allowed for this endpoint")
        }
        _ => HttpResponse::error(404, "unknown endpoint"),
    };
    if resp.status >= 400 {
        server.metrics().record_http_error();
    }
    resp
}

fn parse_body(body: &[u8]) -> std::result::Result<Value, HttpResponse> {
    let text = std::str::from_utf8(body)
        .map_err(|_| HttpResponse::error(400, "body is not valid UTF-8"))?;
    json::parse(text).map_err(|e| HttpResponse::error(400, &format!("invalid JSON: {e}")))
}

fn post_query(server: &Arc<Server>, batcher: Option<&Batcher>, body: &[u8]) -> HttpResponse {
    let v = match parse_body(body) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let req = match QueryRequest::from_json(&v) {
        Ok(r) => r,
        Err(e) => return HttpResponse::error(400, &format!("{e:#}")),
    };
    match batcher {
        // The batched hot path: coalesce with whatever else is in
        // flight. A full queue is backpressure, not an error in the
        // request — answer 503 with a typed `Rejected` body so clients
        // can tell "overloaded, retry" from a 4xx.
        Some(b) => match b.submit(&req) {
            Ok(resp) => HttpResponse::json(200, &resp.to_json()),
            Err(e) => {
                HttpResponse::json(503, &QueryResponse::rejected(&req, e.to_string()).to_json())
            }
        },
        None => HttpResponse::json(200, &server.serve(&req).to_json()),
    }
}

fn post_query_batch(server: &Arc<Server>, body: &[u8]) -> HttpResponse {
    let v = match parse_body(body) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let arr = match v.get("queries").as_array() {
        Some(a) => a,
        None => return HttpResponse::error(400, "missing array field 'queries'"),
    };
    let mut reqs = Vec::with_capacity(arr.len());
    for (i, item) in arr.iter().enumerate() {
        match QueryRequest::from_json(item) {
            Ok(r) => reqs.push(r),
            Err(e) => return HttpResponse::error(400, &format!("queries[{i}]: {e:#}")),
        }
    }
    let replies: Vec<Value> = server.serve_batch(&reqs).iter().map(|r| r.to_json()).collect();
    HttpResponse::json(200, &obj([("replies", Value::Array(replies))]))
}

fn post_admin(server: &Arc<Server>, body: &[u8]) -> HttpResponse {
    let v = match parse_body(body) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    match AdminRequest::from_json(&v) {
        Ok(req) => HttpResponse::json(200, &server.admin(&req).to_json()),
        Err(e) => HttpResponse::error(400, &format!("{e:#}")),
    }
}

/// Minimal blocking HTTP/1.1 client (`Connection: close`), used by the
/// `semcached` client subcommands, the loopback smoke test in
/// `verify.sh`, and the integration tests. Returns the status code and
/// the parsed JSON body (`Value::Null` for an empty body).
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<(u16, Value)> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let body = body.unwrap_or("");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len(),
    );
    let mut writer = stream.try_clone().context("cloning stream")?;
    writer.write_all(head.as_bytes()).context("writing request head")?;
    writer.write_all(body.as_bytes()).context("writing request body")?;
    writer.flush().context("flushing request")?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).context("reading status line")?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("malformed status line {status_line:?}"))?;
    let mut content_length: Option<usize> = None;
    loop {
        let mut h = String::new();
        let n = reader.read_line(&mut h).context("reading response headers")?;
        if n == 0 {
            bail!("connection closed mid-headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().ok();
            }
        }
    }
    let mut bytes = Vec::new();
    match content_length {
        Some(n) => {
            bytes = vec![0u8; n];
            reader.read_exact(&mut bytes).context("reading response body")?;
        }
        None => {
            reader.read_to_end(&mut bytes).context("reading response body")?;
        }
    }
    let text =
        String::from_utf8(bytes).map_err(|_| anyhow!("response body is not valid UTF-8"))?;
    let value = if text.trim().is_empty() { Value::Null } else { json::parse(&text)? };
    Ok((status, value))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_texts_cover_served_codes() {
        for code in [200, 400, 404, 405, 408, 413, 431, 500, 501, 503] {
            assert_ne!(status_text(code), "Unknown", "code {code}");
        }
        assert_eq!(status_text(999), "Unknown");
    }

    #[test]
    fn error_responses_are_json() {
        let r = HttpResponse::error(400, "nope");
        assert_eq!(r.status, 400);
        let v = json::parse(&r.body).unwrap();
        assert_eq!(v.get("error").as_str(), Some("nope"));
    }
}
