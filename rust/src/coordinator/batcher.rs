//! Cross-request micro-batching: coalescing concurrent in-flight
//! queries into single `serve_batch` calls.
//!
//! The PR 1 batch pipeline ([`super::Server::serve_batch`]) only pays
//! off when callers *have* a batch in hand; the HTTP front-end serves
//! each connection's request as an isolated `serve()` call, so the
//! pipeline sat unused on the wire path. The [`Batcher`] closes that
//! gap:
//!
//! ```text
//!   conn worker ──submit──► hash(CoalesceKey) ─┬─► shard 0: bounded MPSC ──► dispatcher 0
//!   conn worker ──submit──►   % dispatchers    ├─► shard 1: bounded MPSC ──► dispatcher 1
//!   conn worker ──submit──►                    └─► shard M: bounded MPSC ──► dispatcher M
//!                                   │                             │ drain up to
//!                            (503 when full)                      │ max_batch_size
//!                                                                 │ within max_wait_us
//!                                                                 ▼
//!                                                      dedup identical in-flight
//!                                                                 │
//!                                                          serve_batch(uniques)
//!                                                                 │
//!                                        one-shot reply channel per submitter
//! ```
//!
//! **Window policy.** A dispatch starts with the oldest queued request;
//! the dispatcher first drains everything already queued, then waits for
//! stragglers until either the batch holds `max_batch_size` requests or
//! `max_wait_us` has passed since the *first* request of the window was
//! enqueued (so a request never waits more than one window on top of
//! its queue time). While a dispatch is being served the queue refills,
//! which is what makes batches form under load without any extra delay.
//!
//! **Coalescing.** Identical in-flight requests — same text, same
//! outcome-affecting options (threshold, ttl_ms, top_k, cluster,
//! deadline_ms), *and* same `client_tag` — are served once per dispatch; every duplicate is
//! answered from the representative's result via
//! [`BatchExecutor::coalesce`] without its own embedding, lookup, or
//! LLM call. This also *fixes* the documented `serve_batch` caveat:
//! racing duplicate novel queries no longer each call the upstream LLM.
//! `client_tag` is part of the identity because it selects the tenant
//! namespace ([`crate::tenancy`]): equal texts from different tenants
//! resolve against different caches and must not share a result.
//!
//! **Sharding.** The engine runs [`BatchConfig::dispatchers`] dispatcher
//! threads, each owning its own bounded queue; submissions are routed by
//! `hash(CoalesceKey) % dispatchers`. Because the route is a pure
//! function of the coalescing identity, identical in-flight requests
//! always land on the *same* dispatcher and still dedup within its
//! windows — the shard count changes throughput, never coalescing
//! semantics — while a hot key (one tenant flooding one text) can only
//! ever saturate its own shard, not serialize the others.
//!
//! **Backpressure.** The submit queue is bounded; when it is full,
//! [`Batcher::submit`] fails fast with [`SubmitError::QueueFull`]
//! (mapped to HTTP 503 + `Outcome::Rejected` by the front-end) instead
//! of buffering without limit.
//!
//! **Shutdown.** [`Batcher::shutdown`] closes the queue, lets the
//! dispatcher drain every already-accepted request (each submitter still
//! gets its reply), and joins the dispatcher thread. Submitting after
//! shutdown fails with [`SubmitError::Shutdown`].
//!
//! **Fan-back.** Each accepted submission carries a completion callback
//! the dispatcher invokes exactly once with the response. Blocking
//! callers use [`Batcher::submit`] (a one-shot channel over the
//! callback); the event-driven HTTP front-end uses
//! [`Batcher::submit_with`] directly, so its request workers hand the
//! response back to the reactor as a wakeup instead of pinning a thread
//! on a blocking `recv` for the whole dispatch.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::{QueryRequest, QueryResponse};
use crate::error::{bail, Result};
use crate::metrics::Metrics;

/// Hard cap on [`BatchConfig::max_batch_size`].
pub const MAX_BATCH_SIZE_LIMIT: usize = 4096;
/// Hard cap on [`BatchConfig::max_wait_us`] (1 s — a coalescing window,
/// not a request timeout).
pub const MAX_WAIT_US_LIMIT: u64 = 1_000_000;
/// Hard cap on [`BatchConfig::dispatchers`].
pub const MAX_DISPATCHERS_LIMIT: usize = 64;

/// Micro-batching window policy and queue bound.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchConfig {
    /// Most requests coalesced into one dispatch (`1..=`
    /// [`MAX_BATCH_SIZE_LIMIT`]; 1 disables coalescing but keeps the
    /// queue/backpressure semantics).
    pub max_batch_size: usize,
    /// Longest a dispatch window stays open after its first request was
    /// enqueued, microseconds (`0..=`[`MAX_WAIT_US_LIMIT`]; 0 = dispatch
    /// whatever is already queued without waiting for stragglers).
    pub max_wait_us: u64,
    /// Bound on queued-but-undispatched requests *per dispatcher shard*;
    /// a full shard queue answers [`SubmitError::QueueFull`] (HTTP 503).
    pub queue_capacity: usize,
    /// Dispatcher threads, hash-sharded on the coalescing key (`1..=`
    /// [`MAX_DISPATCHERS_LIMIT`]). Identical in-flight requests always
    /// route to the same dispatcher regardless of this count, so raising
    /// it never weakens coalescing; 1 is the pre-sharding behavior.
    pub dispatchers: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self { max_batch_size: 32, max_wait_us: 1_000, queue_capacity: 1024, dispatchers: 1 }
    }
}

impl BatchConfig {
    pub fn validate(&self) -> Result<()> {
        if self.max_batch_size == 0 {
            bail!("batch max_batch_size must be >= 1");
        }
        if self.max_batch_size > MAX_BATCH_SIZE_LIMIT {
            bail!(
                "batch max_batch_size must be <= {MAX_BATCH_SIZE_LIMIT}, got {}",
                self.max_batch_size
            );
        }
        if self.max_wait_us > MAX_WAIT_US_LIMIT {
            bail!(
                "batch max_wait_us must be <= {MAX_WAIT_US_LIMIT} (1s), got {}",
                self.max_wait_us
            );
        }
        if self.queue_capacity == 0 {
            bail!("batch queue_capacity must be >= 1");
        }
        if self.dispatchers == 0 {
            bail!("batch dispatchers must be >= 1");
        }
        if self.dispatchers > MAX_DISPATCHERS_LIMIT {
            bail!(
                "batch dispatchers must be <= {MAX_DISPATCHERS_LIMIT}, got {}",
                self.dispatchers
            );
        }
        Ok(())
    }
}

/// Why a [`Batcher::submit`] was not served.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded submit queue is full (backpressure; retry later).
    QueueFull,
    /// The batcher has shut down (or its dispatcher died).
    Shutdown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "batch queue full (server overloaded)"),
            SubmitError::Shutdown => write!(f, "batcher is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// What the batcher dispatches to. [`super::Server`] is the production
/// executor (`serve_batch`); tests plug in recording/misbehaving mocks.
pub trait BatchExecutor: Send + Sync + 'static {
    /// Serve one dispatched micro-batch; must return exactly one
    /// response per request, in input order.
    fn execute(&self, reqs: &[QueryRequest]) -> Vec<QueryResponse>;

    /// [`BatchExecutor::execute`], but advancing `recorded` to the
    /// number of requests whose per-query serving metrics (`request` +
    /// outcome counter) the executor has *fully* recorded so far (a
    /// count, not a prefix — batch workers may finish out of order). The dispatcher reads it only when the executor dies
    /// mid-batch, so `reject_all` can record `request` + `rejected` for
    /// exactly the submissions the executor never accounted — keeping
    /// `cache_hits + cache_misses + degraded_hits + rejected == requests`
    /// exact across executor panics. The default forwards to `execute` and records
    /// nothing, which is correct for executors that keep no per-query
    /// metrics (everything they dispatched gets rejected-and-recorded on
    /// failure). [`super::Server`] overrides this with real progress
    /// tracking.
    fn execute_tracked(
        &self,
        reqs: &[QueryRequest],
        recorded: &std::sync::atomic::AtomicUsize,
    ) -> Vec<QueryResponse> {
        let _ = recorded;
        self.execute(reqs)
    }

    /// [`BatchExecutor::execute_tracked`] plus each request's original
    /// enqueue instant (`accepted[i]` for `reqs[i]`), so executors that
    /// enforce per-request deadlines can measure them from the HTTP edge
    /// — time spent in the batcher's queue and coalescing window counts
    /// against the budget. The default ignores the instants.
    fn execute_tracked_since(
        &self,
        reqs: &[QueryRequest],
        accepted: &[Instant],
        recorded: &std::sync::atomic::AtomicUsize,
    ) -> Vec<QueryResponse> {
        let _ = accepted;
        self.execute_tracked(reqs, recorded)
    }

    /// Answer `dup` — an identical in-flight twin of `rep` within one
    /// dispatch — from the representative's response. The default keeps
    /// the result and re-tags it with the duplicate's `client_tag`;
    /// [`super::Server`] overrides this to record metrics and resolve
    /// the duplicate as a cache hit on the representative's entry.
    fn coalesce(
        &self,
        dup: &QueryRequest,
        rep: &QueryRequest,
        rep_resp: &QueryResponse,
    ) -> QueryResponse {
        let _ = rep;
        let mut resp = rep_resp.clone();
        resp.client_tag = dup.client_tag.clone();
        resp
    }
}

/// How a submission's response travels back to its submitter: invoked
/// exactly once per accepted submission (blocking `submit` wraps a
/// one-shot channel in one; the event-loop front-end passes a reactor
/// wakeup).
type ReplyFn = Box<dyn FnOnce(QueryResponse) + Send>;

/// One queued request with its completion callback.
struct Submission {
    req: QueryRequest,
    enqueued: Instant,
    reply: ReplyFn,
}

/// In-flight identity for coalescing: the text plus every option that
/// can change the outcome. `client_tag` is included because it selects
/// the tenant namespace — equal texts from different tenants hit
/// different caches (and differently-tagged blank/None tags normalize
/// to the same default tenant, so they still coalesce).
#[derive(Hash, PartialEq, Eq)]
struct CoalesceKey {
    text: String,
    client_tag: String,
    threshold_bits: Option<u32>,
    ttl_ms: Option<u64>,
    top_k: Option<usize>,
    cluster: Option<u64>,
    /// Requests with different deadline budgets must not share a fate:
    /// a tight-deadline twin of a loose-deadline representative could
    /// otherwise be answered past its own budget (or vice versa see a
    /// degraded answer it didn't need to accept).
    deadline_ms: Option<u64>,
}

impl CoalesceKey {
    fn of(req: &QueryRequest) -> Self {
        Self {
            text: req.text.clone(),
            client_tag: crate::tenancy::normalize_tag(req.client_tag.as_deref()).to_string(),
            threshold_bits: req.options.threshold.map(f32::to_bits),
            ttl_ms: req.options.ttl_ms,
            top_k: req.options.top_k,
            cluster: req.cluster,
            deadline_ms: req.options.deadline_ms,
        }
    }
}

/// One dispatcher shard: its bounded queue and its dispatcher thread.
struct Shard {
    /// `None` once shut down; dropping the sender disconnects the queue.
    tx: RwLock<Option<SyncSender<Submission>>>,
    dispatcher: Mutex<Option<JoinHandle<()>>>,
}

/// The shard a request routes to: a pure function of the coalescing
/// identity, so identical in-flight requests always share a dispatcher
/// (and therefore still coalesce) at any shard count.
fn shard_of(req: &QueryRequest, shards: usize) -> usize {
    if shards <= 1 {
        return 0;
    }
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    CoalesceKey::of(req).hash(&mut h);
    (h.finish() % shards as u64) as usize
}

/// The cross-request micro-batching engine. Cheap to share via `Arc`;
/// every HTTP connection worker calls [`Batcher::submit`] concurrently.
pub struct Batcher {
    shards: Vec<Shard>,
    metrics: Arc<Metrics>,
    /// Queued-but-not-yet-dequeued submissions across all shards (a
    /// gauge: incremented after a successful enqueue, decremented as a
    /// dispatcher pops; signed because a pop can transiently beat its
    /// enqueuer's increment).
    depth: Arc<AtomicI64>,
}

impl Batcher {
    /// Validate `cfg`, then spawn `cfg.dispatchers` dispatcher threads
    /// over `executor`, each owning one hash shard of the key space.
    pub fn start(
        executor: Arc<dyn BatchExecutor>,
        metrics: Arc<Metrics>,
        cfg: BatchConfig,
    ) -> Result<Arc<Batcher>> {
        cfg.validate()?;
        let depth = Arc::new(AtomicI64::new(0));
        let mut shards = Vec::with_capacity(cfg.dispatchers);
        for i in 0..cfg.dispatchers {
            let (tx, rx) = mpsc::sync_channel::<Submission>(cfg.queue_capacity);
            let executor = executor.clone();
            let dispatcher_metrics = metrics.clone();
            let dispatcher_depth = depth.clone();
            let dispatcher_cfg = cfg.clone();
            let handle = std::thread::Builder::new()
                .name(format!("batch-dispatcher-{i}"))
                .spawn(move || {
                    dispatch_loop(rx, executor, dispatcher_metrics, dispatcher_depth, dispatcher_cfg)
                })
                .expect("spawn batch dispatcher");
            shards.push(Shard {
                tx: RwLock::new(Some(tx)),
                dispatcher: Mutex::new(Some(handle)),
            });
        }
        Ok(Arc::new(Batcher { shards, metrics, depth }))
    }

    /// How many dispatcher shards this batcher runs.
    pub fn dispatchers(&self) -> usize {
        self.shards.len()
    }

    /// Submissions accepted but not yet pulled into a dispatch. An
    /// observability gauge (and a deterministic synchronization point
    /// for tests): a depth of `n` proves at least `n` enqueues have
    /// fully completed and not been dequeued. It can transiently
    /// under-count mid-handoff, never over-count.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::SeqCst).max(0) as usize
    }

    /// Enqueue one request and block until its response is ready.
    ///
    /// Fails fast (without blocking) when the queue is full or the
    /// batcher is shut down; both failures are recorded as a rejected
    /// request so
    /// `cache_hits + cache_misses + degraded_hits + rejected == requests`
    /// stays an invariant of the metrics under backpressure.
    pub fn submit(&self, req: &QueryRequest) -> std::result::Result<QueryResponse, SubmitError> {
        let (reply_tx, reply_rx) = mpsc::sync_channel::<QueryResponse>(1);
        self.submit_with(req, move |resp| {
            let _ = reply_tx.send(resp);
        })?;
        // Accepted requests are always answered: the dispatcher drains
        // the queue before exiting, and if it ever dies the queue (and
        // with it this reply callback) is dropped, waking us here.
        reply_rx.recv().map_err(|_| SubmitError::Shutdown)
    }

    /// Enqueue one request without blocking for the response: `complete`
    /// is invoked with the response exactly once, on the dispatcher
    /// thread, when the dispatch that served (or coalesced) this request
    /// finishes. On `Err` the callback is dropped un-invoked and the
    /// rejection has already been recorded (as in [`Batcher::submit`]);
    /// the caller answers the client itself.
    pub fn submit_with<F>(
        &self,
        req: &QueryRequest,
        complete: F,
    ) -> std::result::Result<(), SubmitError>
    where
        F: FnOnce(QueryResponse) + Send + 'static,
    {
        let shard = &self.shards[shard_of(req, self.shards.len())];
        let guard = shard.tx.read().unwrap();
        let tx = match guard.as_ref() {
            Some(tx) => tx,
            None => return Err(self.reject(SubmitError::Shutdown)),
        };
        let sub = Submission {
            req: req.clone(),
            enqueued: Instant::now(),
            reply: Box::new(complete),
        };
        match tx.try_send(sub) {
            // Gauge up only after the slot is truly occupied, so an
            // observed depth of n proves n completed enqueues (the
            // dispatcher's decrement may transiently beat this
            // increment; the signed gauge absorbs that).
            Ok(()) => {
                let d = self.depth.fetch_add(1, Ordering::SeqCst) + 1;
                self.metrics.set_batch_queue_depth(d.max(0) as u64);
                Ok(())
            }
            Err(TrySendError::Full(_)) => Err(self.reject(SubmitError::QueueFull)),
            Err(TrySendError::Disconnected(_)) => Err(self.reject(SubmitError::Shutdown)),
        }
    }

    fn reject(&self, e: SubmitError) -> SubmitError {
        self.metrics.record_request();
        self.metrics.record_rejected();
        e
    }

    /// Stop accepting, serve everything already queued, join every
    /// dispatcher. Idempotent; also runs on drop. All senders are
    /// dropped before any join, so shards drain concurrently.
    pub fn shutdown(&self) {
        for shard in &self.shards {
            let tx = shard.tx.write().unwrap().take();
            drop(tx); // disconnects the shard's queue once it drains
        }
        for shard in &self.shards {
            if let Some(h) = shard.dispatcher.lock().unwrap().take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn dispatch_loop(
    rx: Receiver<Submission>,
    executor: Arc<dyn BatchExecutor>,
    metrics: Arc<Metrics>,
    depth: Arc<AtomicI64>,
    cfg: BatchConfig,
) {
    let window = Duration::from_micros(cfg.max_wait_us);
    // Decrement the authoritative gauge and mirror it into the metrics
    // registry so `/v1/metrics` exposes queue pressure live.
    let dequeued = |depth: &AtomicI64| {
        let d = depth.fetch_sub(1, Ordering::SeqCst) - 1;
        metrics.set_batch_queue_depth(d.max(0) as u64);
    };
    loop {
        // Block for the window's first request; a disconnected, empty
        // queue means shutdown.
        let first = match rx.recv() {
            Ok(s) => s,
            Err(_) => break,
        };
        dequeued(&depth);
        let deadline = first.enqueued + window;
        let mut batch = vec![first];
        loop {
            if batch.len() >= cfg.max_batch_size {
                break;
            }
            // Drain whatever is already queued without waiting...
            match rx.try_recv() {
                Ok(s) => {
                    dequeued(&depth);
                    batch.push(s);
                    continue;
                }
                Err(TryRecvError::Disconnected) => break,
                Err(TryRecvError::Empty) => {}
            }
            // ...then wait for stragglers until the window closes.
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline.saturating_duration_since(now)) {
                Ok(s) => {
                    dequeued(&depth);
                    batch.push(s);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        dispatch(executor.as_ref(), &metrics, batch);
    }
}

/// Serve one dispatched micro-batch: dedup identical in-flight requests,
/// run the executor over the unique ones, fan every reply out to its
/// submitter (exactly one reply per submission, even if the executor
/// misbehaves).
fn dispatch(executor: &dyn BatchExecutor, metrics: &Metrics, batch: Vec<Submission>) {
    let t0 = Instant::now();
    metrics.record_batcher_dispatch(batch.len() as u64);
    for s in &batch {
        metrics.observe_queue_wait_ms(s.enqueued.elapsed().as_secs_f64() * 1e3);
    }

    // Group by in-flight identity: `rep_slot[i]` is the unique-slot of
    // submission i, `reps[slot]` the submission index of that slot's
    // representative (its first occurrence, preserving arrival order).
    let mut rep_slot: Vec<usize> = Vec::with_capacity(batch.len());
    let mut reps: Vec<usize> = Vec::new();
    let mut seen: HashMap<CoalesceKey, usize> = HashMap::new();
    for (i, s) in batch.iter().enumerate() {
        match seen.entry(CoalesceKey::of(&s.req)) {
            Entry::Occupied(e) => rep_slot.push(*e.get()),
            Entry::Vacant(v) => {
                v.insert(reps.len());
                rep_slot.push(reps.len());
                reps.push(i);
            }
        }
    }
    let unique: Vec<QueryRequest> = reps.iter().map(|&i| batch[i].req.clone()).collect();
    // A representative's deadline is measured from its own enqueue
    // instant; coalesced twins (same `deadline_ms`, enqueued within one
    // window of it) share the representative's budget.
    let accepted: Vec<Instant> = reps.iter().map(|&i| batch[i].enqueued).collect();

    // A panicking executor must not leave submitters blocked forever or
    // kill the dispatcher: catch, reject the whole dispatch, keep going.
    // `recorded` survives the unwind with the executor's per-query
    // accounting progress, so rejection accounting stays exact.
    let recorded = std::sync::atomic::AtomicUsize::new(0);
    let served = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        executor.execute_tracked_since(&unique, &accepted, &recorded)
    }));
    let responses = match served {
        Ok(r) if r.len() == unique.len() => r,
        Ok(r) => {
            eprintln!(
                "[batcher] executor returned {} responses for {} requests; rejecting dispatch",
                r.len(),
                unique.len()
            );
            reject_all(metrics, batch, recorded.load(Ordering::SeqCst));
            return;
        }
        Err(_) => {
            eprintln!("[batcher] executor panicked; rejecting dispatch, dispatcher recovered");
            reject_all(metrics, batch, recorded.load(Ordering::SeqCst));
            return;
        }
    };

    for (i, s) in batch.into_iter().enumerate() {
        let slot = rep_slot[i];
        let resp = if reps[slot] == i {
            responses[slot].clone()
        } else {
            metrics.record_coalesced();
            // `unique[slot]` is the clone of this slot's representative
            // request, so coalescing sees the same identity it grouped by.
            executor.coalesce(&s.req, &unique[slot], &responses[slot])
        };
        // A panicking completion callback must not kill the dispatcher
        // (and with it every later submitter).
        let reply = s.reply;
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || reply(resp)));
    }
    metrics.observe_dispatch_ms(t0.elapsed().as_secs_f64() * 1e3);
}

/// Answer a failed dispatch: every submission still gets exactly one
/// reply, and the accounting stays exact. Like any other serving-time
/// rejection, the reply rides a normal 200 on the wire with a typed
/// `Rejected` outcome.
///
/// `already_recorded` is how many queries the executor fully recorded
/// (`request` + a hit/miss outcome each) before dying mid-batch. The
/// counters are pure tallies, so skipping `request` + `rejected` for
/// that many submissions — whichever ones — keeps the totals exact:
/// `already_recorded` requests carry executor-recorded outcomes, the
/// remaining `batch.len() - already_recorded` are recorded as rejected
/// here, and
/// `cache_hits + cache_misses + degraded_hits + rejected == requests`
/// holds.
/// (Coalesced duplicates are never executor-recorded — only unique
/// representatives reach `execute` — so `already_recorded` can never
/// exceed the number of submissions.)
fn reject_all(metrics: &Metrics, batch: Vec<Submission>, already_recorded: usize) {
    debug_assert!(already_recorded <= batch.len());
    for (i, s) in batch.into_iter().enumerate() {
        if i >= already_recorded {
            metrics.record_request();
            metrics.record_rejected();
        }
        let resp = QueryResponse::rejected(&s.req, "internal error: batch executor failed");
        let reply = s.reply;
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || reply(resp)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{LatencyBreakdown, Outcome};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Condvar;

    /// Echo executor: answers `Miss` with the request text as response;
    /// optionally blocks inside `execute` until released (to pin the
    /// dispatcher while the test fills the queue deterministically).
    struct EchoExec {
        calls: Mutex<Vec<Vec<String>>>,
        entered: AtomicUsize,
        gate: Mutex<bool>,
        gate_cv: Condvar,
    }

    impl EchoExec {
        fn new(gated: bool) -> Arc<Self> {
            Arc::new(Self {
                calls: Mutex::new(Vec::new()),
                entered: AtomicUsize::new(0),
                gate: Mutex::new(!gated),
                gate_cv: Condvar::new(),
            })
        }

        fn open_gate(&self) {
            *self.gate.lock().unwrap() = true;
            self.gate_cv.notify_all();
        }
    }

    /// Deterministic wait-with-deadline (no fixed sleeps in assertions).
    fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
        for _ in 0..5_000 {
            if cond() {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        panic!("timed out waiting for {what}");
    }

    impl BatchExecutor for EchoExec {
        fn execute(&self, reqs: &[QueryRequest]) -> Vec<QueryResponse> {
            self.entered.fetch_add(1, Ordering::SeqCst);
            let mut open = self.gate.lock().unwrap();
            while !*open {
                open = self.gate_cv.wait(open).unwrap();
            }
            drop(open);
            self.calls
                .lock()
                .unwrap()
                .push(reqs.iter().map(|r| r.text.clone()).collect());
            reqs.iter()
                .map(|r| QueryResponse {
                    response: r.text.clone(),
                    outcome: Outcome::Miss { inserted_id: 1 },
                    latency: LatencyBreakdown::default(),
                    judged_positive: None,
                    matched_cluster: None,
                    client_tag: r.client_tag.clone(),
                })
                .collect()
        }
    }

    #[test]
    fn config_validation_rejects_bad_knobs() {
        assert!(BatchConfig::default().validate().is_ok());
        let zero = BatchConfig { max_batch_size: 0, ..Default::default() };
        assert!(zero.validate().is_err(), "max_batch_size == 0");
        let huge = BatchConfig { max_batch_size: MAX_BATCH_SIZE_LIMIT + 1, ..Default::default() };
        assert!(huge.validate().is_err(), "max_batch_size beyond cap");
        let wait = BatchConfig { max_wait_us: MAX_WAIT_US_LIMIT + 1, ..Default::default() };
        assert!(wait.validate().is_err(), "max_wait_us out of range");
        let q = BatchConfig { queue_capacity: 0, ..Default::default() };
        assert!(q.validate().is_err(), "queue_capacity == 0");
        let d0 = BatchConfig { dispatchers: 0, ..Default::default() };
        assert!(d0.validate().is_err(), "dispatchers == 0");
        let dmany = BatchConfig { dispatchers: MAX_DISPATCHERS_LIMIT + 1, ..Default::default() };
        assert!(dmany.validate().is_err(), "dispatchers beyond cap");
        assert!(Batcher::start(
            EchoExec::new(false),
            Arc::new(Metrics::new()),
            BatchConfig { max_batch_size: 0, ..Default::default() },
        )
        .is_err());
    }

    #[test]
    fn submit_roundtrips_and_shutdown_rejects_later_submits() {
        let exec = EchoExec::new(false);
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::start(exec.clone(), metrics.clone(), BatchConfig::default()).unwrap();
        let resp = b.submit(&QueryRequest::new("hello batcher")).unwrap();
        assert_eq!(resp.response, "hello batcher");
        b.shutdown();
        let err = b.submit(&QueryRequest::new("too late")).unwrap_err();
        assert_eq!(err, SubmitError::Shutdown);
        let m = metrics.snapshot();
        assert_eq!(m.batcher_dispatches, 1);
        assert_eq!(m.batcher_queries, 1);
        assert_eq!(m.rejected, 1, "post-shutdown submit recorded as rejected");
    }

    #[test]
    fn submit_with_invokes_callback_and_never_after_shutdown() {
        let exec = EchoExec::new(false);
        let b = Batcher::start(exec, Arc::new(Metrics::new()), BatchConfig::default()).unwrap();
        let (tx, rx) = mpsc::channel::<String>();
        b.submit_with(&QueryRequest::new("callback probe"), move |resp| {
            let _ = tx.send(resp.response);
        })
        .unwrap();
        // submit_with returns before the response exists; the callback
        // delivers it from the dispatcher thread.
        let got = rx.recv_timeout(Duration::from_secs(5)).expect("callback fired");
        assert_eq!(got, "callback probe");
        b.shutdown();
        let err = b
            .submit_with(&QueryRequest::new("too late"), |_| {
                panic!("callback must not run for a rejected submit")
            })
            .unwrap_err();
        assert_eq!(err, SubmitError::Shutdown);
    }

    #[test]
    fn full_queue_fails_fast_with_backpressure() {
        // Gate the executor so the dispatcher is pinned serving the
        // first submission while the queue (capacity 1) fills.
        let exec = EchoExec::new(true);
        let metrics = Arc::new(Metrics::new());
        let cfg =
            BatchConfig { max_batch_size: 1, max_wait_us: 0, queue_capacity: 1, dispatchers: 1 };
        let b = Batcher::start(exec.clone(), metrics.clone(), cfg).unwrap();

        std::thread::scope(|scope| {
            let b1 = b.clone();
            let t1 = scope.spawn(move || b1.submit(&QueryRequest::new("first")).unwrap());
            // Wait until the dispatcher is inside execute() on "first"
            // (so "first" is out of the queue and pinned behind the gate).
            wait_until("dispatcher entered execute", || {
                exec.entered.load(Ordering::SeqCst) == 1
            });
            let b2 = b.clone();
            let t2 = scope.spawn(move || b2.submit(&QueryRequest::new("second")).unwrap());
            // Wait until "second" occupies the one queue slot.
            wait_until("second submission queued", || b.queue_depth() == 1);
            // Queue full (capacity 1 holds "second"): fail fast, no block.
            let err = b.submit(&QueryRequest::new("third")).unwrap_err();
            assert_eq!(err, SubmitError::QueueFull);
            exec.open_gate();
            assert_eq!(t1.join().unwrap().response, "first");
            assert_eq!(t2.join().unwrap().response, "second");
        });
        let m = metrics.snapshot();
        assert_eq!(m.rejected, 1);
        assert_eq!(m.batcher_queries, 2, "accepted submissions both dispatched");
    }

    #[test]
    fn identical_inflight_requests_coalesce_within_a_dispatch() {
        // Pin the dispatcher on a warm-up request, queue 4 identical
        // same-tenant requests plus one distinct, then release: the next
        // dispatch must dedup the four into one executed request.
        let exec = EchoExec::new(true);
        let metrics = Arc::new(Metrics::new());
        let cfg = BatchConfig { max_batch_size: 8, max_wait_us: 0, queue_capacity: 16, dispatchers: 1 };
        let b = Batcher::start(exec.clone(), metrics.clone(), cfg).unwrap();
        std::thread::scope(|scope| {
            let warm = b.clone();
            scope.spawn(move || warm.submit(&QueryRequest::new("warm up")).unwrap());
            wait_until("dispatcher entered execute", || {
                exec.entered.load(Ordering::SeqCst) == 1
            });
            for i in 0..5 {
                let b = b.clone();
                let text = if i < 4 { "dup question" } else { "distinct question" };
                scope.spawn(move || {
                    let resp = b
                        .submit(&QueryRequest::new(text).with_client_tag("tenant-a"))
                        .unwrap();
                    assert_eq!(resp.response, text, "coalesced reply carries rep's answer");
                    assert_eq!(resp.client_tag.as_deref(), Some("tenant-a"), "own tag echoed");
                });
            }
            // All 5 must be in the queue before the gate opens, so they
            // land in one dispatch.
            wait_until("all 5 submissions queued", || b.queue_depth() == 5);
            assert_eq!(
                metrics.snapshot().batch_queue_depth,
                5,
                "queue depth mirrored into the metrics gauge"
            );
            exec.open_gate();
        });
        b.shutdown();
        let calls = exec.calls.lock().unwrap();
        assert_eq!(calls.len(), 2, "warm-up dispatch + coalesced dispatch");
        let second: &Vec<String> = &calls[1];
        assert_eq!(second.len(), 2, "4 dups + 1 distinct dedup to 2 uniques: {second:?}");
        assert_eq!(metrics.snapshot().coalesced, 3);
        assert_eq!(metrics.snapshot().batch_queue_depth, 0, "gauge drains with the queue");
    }

    #[test]
    fn equal_texts_from_different_tenants_never_coalesce() {
        // Same text, four distinct client_tags: each tenant resolves
        // against its own cache namespace, so all four must be executed
        // (no cross-tenant answer sharing). Untagged and blank-tagged
        // requests normalize to the same default tenant and still
        // coalesce with each other.
        let exec = EchoExec::new(true);
        let metrics = Arc::new(Metrics::new());
        let cfg = BatchConfig { max_batch_size: 8, max_wait_us: 0, queue_capacity: 16, dispatchers: 1 };
        let b = Batcher::start(exec.clone(), metrics.clone(), cfg).unwrap();
        std::thread::scope(|scope| {
            let warm = b.clone();
            scope.spawn(move || warm.submit(&QueryRequest::new("warm up")).unwrap());
            wait_until("dispatcher entered execute", || {
                exec.entered.load(Ordering::SeqCst) == 1
            });
            for i in 0..4 {
                let b = b.clone();
                scope.spawn(move || {
                    let tag = format!("tenant-{i}");
                    b.submit(&QueryRequest::new("same question").with_client_tag(tag)).unwrap();
                });
            }
            // One untagged and one blank-tagged twin: same default tenant.
            for tag in [None, Some("   ")] {
                let b = b.clone();
                scope.spawn(move || {
                    let mut req = QueryRequest::new("same question");
                    if let Some(t) = tag {
                        req = req.with_client_tag(t);
                    }
                    b.submit(&req).unwrap();
                });
            }
            wait_until("all 6 submissions queued", || b.queue_depth() == 6);
            exec.open_gate();
        });
        b.shutdown();
        let calls = exec.calls.lock().unwrap();
        assert_eq!(calls.len(), 2, "warm-up dispatch + tagged dispatch");
        assert_eq!(
            calls[1].len(),
            5,
            "4 tenants + 1 default-tenant pair -> 5 uniques: {:?}",
            calls[1]
        );
        assert_eq!(metrics.snapshot().coalesced, 1, "only the default-tenant twin coalesced");
    }

    #[test]
    fn shutdown_drains_accepted_requests() {
        let exec = EchoExec::new(true);
        let b = Batcher::start(exec.clone(), Arc::new(Metrics::new()), BatchConfig::default())
            .unwrap();
        std::thread::scope(|scope| {
            // First submission alone, so the gated dispatch holds
            // exactly it; the other two then demonstrably queue behind.
            let b0 = b.clone();
            scope.spawn(move || {
                let resp = b0.submit(&QueryRequest::new("drain 0")).unwrap();
                assert!(resp.response.starts_with("drain"));
            });
            wait_until("dispatcher entered execute", || {
                exec.entered.load(Ordering::SeqCst) >= 1
            });
            for i in 1..3 {
                let b = b.clone();
                scope.spawn(move || {
                    let resp = b.submit(&QueryRequest::new(format!("drain {i}"))).unwrap();
                    assert!(resp.response.starts_with("drain"));
                });
            }
            wait_until("remaining submissions queued", || b.queue_depth() == 2);
            // Shut down from another thread while requests are queued
            // behind the gated dispatch; all must still be answered.
            let closer = b.clone();
            scope.spawn(move || closer.shutdown());
            // Pin the intended interleaving: only open the gate once
            // shutdown has demonstrably closed the queue (tests live in
            // the batcher module, so the private `tx` is observable).
            wait_until("shutdown closed the queue", || {
                b.shards.iter().all(|s| s.tx.read().unwrap().is_none())
            });
            exec.open_gate();
        });
    }

    /// Server-like executor that records per-query metrics as it goes
    /// (request + miss, then bumps `recorded`), echoes on its first
    /// dispatch, and panics partway through its second — emulating
    /// `Server::serve_batch` dying mid-batch.
    struct PanicExec {
        metrics: Arc<Metrics>,
        entered: AtomicUsize,
        gate: Mutex<bool>,
        gate_cv: Condvar,
        record_before_panic: usize,
    }

    impl BatchExecutor for PanicExec {
        fn execute(&self, _reqs: &[QueryRequest]) -> Vec<QueryResponse> {
            unreachable!("execute_tracked is overridden");
        }

        fn execute_tracked(
            &self,
            reqs: &[QueryRequest],
            recorded: &AtomicUsize,
        ) -> Vec<QueryResponse> {
            let call = self.entered.fetch_add(1, Ordering::SeqCst) + 1;
            let mut open = self.gate.lock().unwrap();
            while !*open {
                open = self.gate_cv.wait(open).unwrap();
            }
            drop(open);
            let mut out = Vec::new();
            for (i, r) in reqs.iter().enumerate() {
                if call > 1 && i >= self.record_before_panic {
                    panic!("injected mid-batch executor failure");
                }
                self.metrics.record_request();
                self.metrics.record_miss();
                recorded.fetch_add(1, Ordering::SeqCst);
                out.push(QueryResponse {
                    response: r.text.clone(),
                    outcome: Outcome::Miss { inserted_id: 1 },
                    latency: LatencyBreakdown::default(),
                    judged_positive: None,
                    matched_cluster: None,
                    client_tag: r.client_tag.clone(),
                });
            }
            out
        }
    }

    #[test]
    fn executor_panic_keeps_metrics_invariant_exact() {
        // Pin a warm-up dispatch behind the gate, queue 4 dups + 2
        // distinct requests so they land in one dispatch, then let the
        // executor record exactly one query before panicking. The old
        // reject_all recorded request+rejected for *every* submission,
        // double-counting the query the executor had already recorded.
        let metrics = Arc::new(Metrics::new());
        let exec = Arc::new(PanicExec {
            metrics: metrics.clone(),
            entered: AtomicUsize::new(0),
            gate: Mutex::new(false),
            gate_cv: Condvar::new(),
            record_before_panic: 1,
        });
        let cfg = BatchConfig { max_batch_size: 8, max_wait_us: 0, queue_capacity: 16, dispatchers: 1 };
        let b = Batcher::start(exec.clone(), metrics.clone(), cfg).unwrap();
        std::thread::scope(|scope| {
            let warm = b.clone();
            scope.spawn(move || {
                let resp = warm.submit(&QueryRequest::new("warm up")).unwrap();
                assert!(
                    matches!(resp.outcome, Outcome::Miss { .. }),
                    "warm-up dispatch succeeds"
                );
            });
            wait_until("dispatcher entered execute", || {
                exec.entered.load(Ordering::SeqCst) == 1
            });
            for i in 0..6 {
                let b = b.clone();
                let text = if i < 4 { "doomed dup".to_string() } else { format!("doomed {i}") };
                scope.spawn(move || {
                    let resp = b.submit(&QueryRequest::new(text)).unwrap();
                    // Every submitter still gets exactly one reply, a
                    // typed rejection.
                    assert!(
                        matches!(resp.outcome, Outcome::Rejected { .. }),
                        "panicked dispatch answers Rejected, got {:?}",
                        resp.outcome
                    );
                });
            }
            wait_until("all 6 submissions queued", || b.queue_depth() == 6);
            *exec.gate.lock().unwrap() = true;
            exec.gate_cv.notify_all();
        });
        b.shutdown();
        let m = metrics.snapshot();
        // warm-up (1 recorded miss) + panicked dispatch (1 recorded
        // miss, 5 rejections) = 7 requests, no double counts.
        assert_eq!(m.requests, 7, "each submission recorded exactly once");
        assert_eq!(m.cache_misses, 2, "warm-up + the one query recorded pre-panic");
        assert_eq!(m.rejected, 5, "remaining submissions rejected exactly once each");
        assert_eq!(
            m.cache_hits + m.cache_misses + m.rejected,
            m.requests,
            "metrics invariant holds across an executor-panic dispatch"
        );
    }

    /// Server-like executor that serves every query as a *degraded* hit
    /// (upstream down, relaxed-gate cache answer), recording request +
    /// degraded as it goes, then panics mid-batch on its second
    /// dispatch — the degraded analogue of [`PanicExec`].
    struct DegradedPanicExec {
        metrics: Arc<Metrics>,
        entered: AtomicUsize,
        gate: Mutex<bool>,
        gate_cv: Condvar,
        record_before_panic: usize,
    }

    impl BatchExecutor for DegradedPanicExec {
        fn execute(&self, _reqs: &[QueryRequest]) -> Vec<QueryResponse> {
            unreachable!("execute_tracked is overridden");
        }

        fn execute_tracked(
            &self,
            reqs: &[QueryRequest],
            recorded: &AtomicUsize,
        ) -> Vec<QueryResponse> {
            let call = self.entered.fetch_add(1, Ordering::SeqCst) + 1;
            let mut open = self.gate.lock().unwrap();
            while !*open {
                open = self.gate_cv.wait(open).unwrap();
            }
            drop(open);
            let mut out = Vec::new();
            for (i, r) in reqs.iter().enumerate() {
                if call > 1 && i >= self.record_before_panic {
                    panic!("injected mid-batch executor failure");
                }
                self.metrics.record_request();
                self.metrics.record_degraded_hit();
                recorded.fetch_add(1, Ordering::SeqCst);
                out.push(QueryResponse {
                    response: r.text.clone(),
                    outcome: Outcome::Degraded { score: 0.7, entry_id: 1 },
                    latency: LatencyBreakdown { degraded: true, ..Default::default() },
                    judged_positive: None,
                    matched_cluster: None,
                    client_tag: r.client_tag.clone(),
                });
            }
            out
        }
    }

    #[test]
    fn executor_panic_keeps_extended_balance_with_degraded_outcomes() {
        // Same shape as `executor_panic_keeps_metrics_invariant_exact`,
        // but the executor answers degraded hits: the batcher's failed-
        // dispatch rejection accounting must keep the *extended* balance
        // `hits + misses + degraded + rejected == requests` exact.
        let metrics = Arc::new(Metrics::new());
        let exec = Arc::new(DegradedPanicExec {
            metrics: metrics.clone(),
            entered: AtomicUsize::new(0),
            gate: Mutex::new(false),
            gate_cv: Condvar::new(),
            record_before_panic: 2,
        });
        let cfg = BatchConfig { max_batch_size: 8, max_wait_us: 0, queue_capacity: 16, dispatchers: 1 };
        let b = Batcher::start(exec.clone(), metrics.clone(), cfg).unwrap();
        std::thread::scope(|scope| {
            let warm = b.clone();
            scope.spawn(move || {
                let resp = warm.submit(&QueryRequest::new("warm up")).unwrap();
                assert!(
                    matches!(resp.outcome, Outcome::Degraded { .. }),
                    "warm-up dispatch answers degraded"
                );
            });
            wait_until("dispatcher entered execute", || {
                exec.entered.load(Ordering::SeqCst) == 1
            });
            for i in 0..5 {
                let b = b.clone();
                scope.spawn(move || {
                    let _ = b.submit(&QueryRequest::new(format!("doomed {i}"))).unwrap();
                });
            }
            wait_until("all 5 submissions queued", || b.queue_depth() == 5);
            *exec.gate.lock().unwrap() = true;
            exec.gate_cv.notify_all();
        });
        b.shutdown();
        let m = metrics.snapshot();
        assert_eq!(m.requests, 6, "warm-up + 5 doomed, each exactly once");
        assert_eq!(m.degraded_hits, 3, "warm-up + the two recorded pre-panic");
        assert_eq!(m.rejected, 3, "unaccounted remainder rejected exactly once each");
        assert_eq!(
            m.cache_hits + m.cache_misses + m.degraded_hits + m.rejected,
            m.requests,
            "extended balance holds across an executor-panic dispatch"
        );
    }

    #[test]
    fn identical_requests_coalesce_across_sharded_batcher() {
        // dispatchers = 4: the route is a pure function of the
        // coalescing key, so 5 identical in-flight requests all land on
        // one shard and still coalesce — the PR 3 guarantee survives
        // sharding.
        let exec = EchoExec::new(true);
        let metrics = Arc::new(Metrics::new());
        let cfg = BatchConfig { max_batch_size: 8, max_wait_us: 0, queue_capacity: 16, dispatchers: 4 };
        let b = Batcher::start(exec.clone(), metrics.clone(), cfg).unwrap();
        assert_eq!(b.dispatchers(), 4);
        std::thread::scope(|scope| {
            let first = b.clone();
            scope.spawn(move || {
                let resp = first.submit(&QueryRequest::new("dup question")).unwrap();
                assert_eq!(resp.response, "dup question");
            });
            // The first identical request pins its shard's dispatcher
            // inside execute (gate closed).
            wait_until("shard dispatcher entered execute", || {
                exec.entered.load(Ordering::SeqCst) == 1
            });
            for _ in 0..4 {
                let b = b.clone();
                scope.spawn(move || {
                    let resp = b.submit(&QueryRequest::new("dup question")).unwrap();
                    assert_eq!(resp.response, "dup question");
                });
            }
            wait_until("4 identical requests queued", || b.queue_depth() == 4);
            // Same key => same shard: were any routed elsewhere, that
            // shard's (idle) dispatcher would have entered execute and
            // blocked on the shared gate too.
            assert_eq!(
                exec.entered.load(Ordering::SeqCst),
                1,
                "identical requests all queued behind the same shard"
            );
            exec.open_gate();
        });
        b.shutdown();
        let calls = exec.calls.lock().unwrap();
        assert_eq!(calls.len(), 2, "pinned dispatch + coalesced dispatch: {calls:?}");
        assert_eq!(calls[1], vec!["dup question"], "4 queued dups dedup to one execution");
        assert_eq!(metrics.snapshot().coalesced, 3);
    }

    #[test]
    fn hot_shard_does_not_serialize_other_shards() {
        // Two requests that hash to different shards must be in
        // execute concurrently: a hot key pinning one dispatcher can
        // never serialize traffic on the others.
        let shards = 4;
        let hot = QueryRequest::new("hot shard probe");
        let hot_shard = shard_of(&hot, shards);
        let cold = (0..256)
            .map(|i| QueryRequest::new(format!("cold probe {i}")))
            .find(|r| shard_of(r, shards) != hot_shard)
            .expect("some probe hashes to a different shard");
        let exec = EchoExec::new(true);
        let cfg = BatchConfig { max_batch_size: 8, max_wait_us: 0, queue_capacity: 16, dispatchers: shards };
        let b = Batcher::start(exec.clone(), Arc::new(Metrics::new()), cfg).unwrap();
        std::thread::scope(|scope| {
            let (b1, hot) = (b.clone(), hot.clone());
            scope.spawn(move || b1.submit(&hot).unwrap());
            wait_until("hot dispatcher entered execute", || {
                exec.entered.load(Ordering::SeqCst) == 1
            });
            let (b2, cold) = (b.clone(), cold.clone());
            scope.spawn(move || b2.submit(&cold).unwrap());
            // With the hot dispatcher still gated, the cold request's
            // dispatcher enters execute on its own — proof the shards
            // run independently. (Pre-sharding this deadlocked: one
            // dispatcher, gate never reached twice.)
            wait_until("cold dispatcher entered execute concurrently", || {
                exec.entered.load(Ordering::SeqCst) == 2
            });
            exec.open_gate();
        });
        b.shutdown();
        assert_eq!(exec.calls.lock().unwrap().len(), 2);
    }
}
