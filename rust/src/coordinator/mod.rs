//! The serving coordinator (L3): request routing over cache + LLM.
//!
//! Owns the full paper workflow (§2.8) behind a thread-safe [`Server`]:
//!
//! ```text
//!   query ──► embedding batcher ──► ANN lookup ──► hit? ──► cached reply
//!                                         │
//!                                        miss ──► SimLlm ──► insert ──► reply
//! ```
//!
//! The workflow is exposed through the typed v1 API
//! ([`crate::api::QueryRequest`] → [`crate::api::QueryResponse`]):
//! [`Server::serve`] answers one request on the calling thread, and
//! [`Server::serve_batch`] pipelines a whole batch — chunked
//! `encode_batch` embedding, a scoped-thread worker pool fanning ANN
//! lookups out over the cache's read-mostly `RwLock` shards, and a
//! deterministic in-input-order merge, with per-stage latency recorded
//! in [`crate::metrics::Metrics`]. The pre-v1 `handle`/`handle_batch`
//! surface survives as thin shims over the same core, and the [`http`]
//! module puts the API on the wire (the `semcached` daemon).
//!
//! On the wire path the [`batcher`] module sits between the two:
//! concurrent in-flight `POST /v1/query` requests from many connections
//! are coalesced by a [`Batcher`] into single [`Server::serve_batch`]
//! calls under a (max_batch_size, max_wait_us) window, with identical
//! in-flight queries deduplicated so repetitive traffic pays for one
//! embed/lookup/LLM call instead of N.
//!
//! The wire itself is event-driven by default: a fleet of epoll/poll
//! readiness loops (the `reactor` module, via [`crate::util::poll`]) —
//! [`HttpConfig::reactors`] threads, each owning its own poller,
//! connection table, and completion queue, with accepted connections
//! dealt round-robin from the listener-owning reactor — holds every
//! connection without a pinned thread and hands only complete parsed
//! requests to a small worker pool, so idle keep-alive connections cost
//! a file descriptor instead of a thread. The batcher is likewise
//! sharded over [`BatchConfig::dispatchers`] threads, hash-partitioned
//! on the coalescing key so identical in-flight requests always meet on
//! the same dispatcher (and still coalesce) while a hot key can never
//! serialize cold ones. The pre-reactor blocking design survives behind
//! [`HttpConfig::event_loop`]` = false` (`semcached serve
//! --threaded-accept`).
//!
//! Latency accounting mixes *measured* wall-clock for everything the
//! Rust process does (tokenize, encode, search, insert) with the
//! *simulated* upstream latency for LLM calls, so Figure 3's
//! with/without-cache comparison is apples-to-apples (DESIGN.md §3).
//!
//! A housekeeping thread periodically sweeps TTLs and rebuilds
//! garbage-heavy index partitions (§2.4 "rebalancing", §2.7 TTL).

pub mod batcher;
pub mod http;
#[cfg(unix)]
mod reactor;
pub mod resilience;
mod server;
mod trace;

pub use batcher::{BatchConfig, BatchExecutor, Batcher, SubmitError, MAX_DISPATCHERS_LIMIT};
pub use http::{http_request, serve_http, HttpConfig, HttpHandle};
pub use resilience::{Resilience, ResilienceConfig, UpstreamOutcome, UpstreamUnavailable};
pub use server::{
    HousekeepingGuard, Reply, ReplySource, Server, ServerConfig, ServerConfigBuilder,
    SnapshotGuard,
};
pub use trace::{TraceConfig, TraceReport, TraceRunner};

/// The serving coordinator — alias for [`Server`], matching the
/// coordinator-centric naming used in the architecture docs.
pub type Coordinator = Server;
