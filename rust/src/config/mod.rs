//! Typed configuration for the whole system.
//!
//! Sources, in increasing precedence: built-in defaults → a TOML-subset
//! config file (`--config path`) → `key=value` CLI overrides. The TOML
//! subset supports `[section]` headers, `key = value` with strings,
//! numbers, booleans — everything the shipped configs use (see
//! `configs/*.toml`).

mod toml;

pub use toml::{parse_toml, TomlError};

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{bail, Context, Result};
use crate::tenancy::TenantOverrides;

/// Full system configuration. Field groups mirror DESIGN.md §4 modules.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    // Cache (paper §2.5, §2.6, §2.7)
    /// Cosine similarity threshold gating cache hits (paper: 0.8).
    pub similarity_threshold: f32,
    /// TTL for cached entries, seconds (0 = immortal).
    pub ttl_secs: u64,
    /// Legacy count-based cap. Deprecated by the byte-accurate
    /// `max_bytes` budget: the key is still *accepted* (a config that
    /// started a pre-tenancy daemon must keep starting one) but its
    /// value is clamped to unbounded — size caps are byte-denominated
    /// now. See DESIGN.md "Migration: cache_capacity".
    pub cache_capacity: usize,
    /// Global cache memory budget in bytes (0 = unbounded). Every entry
    /// charges its byte-accurate footprint against this; crossing it
    /// evicts entries of the inserting tenant per `eviction_policy`.
    pub max_bytes: u64,
    /// Eviction policy when a byte budget is exceeded: "lru", "lfu", or
    /// "cost" (simulated-LLM-latency-saved per byte).
    pub eviction_policy: String,
    /// Default per-tenant byte quota (0 = unlimited); individual tenants
    /// override via `[tenant.<name>] quota_bytes`.
    pub tenant_quota_bytes: u64,
    /// Per-tenant overrides, keyed by tenant name (`[tenant.<name>]`
    /// tables / `--tenant.<name>.<field>` flags).
    pub tenants: BTreeMap<String, TenantOverrides>,
    /// Top-k neighbors fetched per lookup.
    pub top_k: usize,

    // Index (paper §2.4)
    /// "hnsw" or "flat".
    pub index_kind: String,
    pub hnsw_m: usize,
    pub hnsw_ef_construction: usize,
    pub hnsw_ef_search: usize,
    /// Rebuild when tombstone ratio exceeds this (paper's rebalancing).
    pub rebuild_garbage_ratio: f64,
    /// Score ANN candidates through the int8-quantized code matrix
    /// (per-vector scale, exact-f32 rerank of the survivors) instead of
    /// full f32 dots — 4× more vectors per cache line. `false` keeps
    /// the exact-only scan; `SEMCACHE_SCALAR_KERNELS=1` overrides at
    /// runtime. See DESIGN.md §Perf.
    pub quantized_scan: bool,

    // Embedding (paper §2.2)
    /// "pjrt" (AOT artifacts) or "native" (pure-Rust twin).
    pub encoder_kind: String,
    /// Micro-batching window for the embedding batcher, microseconds.
    pub batch_window_us: u64,
    /// Max batch size (must be one of the AOT-compiled sizes for pjrt).
    pub max_batch: usize,
    /// Exact-match embedding memo tier capacity, entries (0 disables
    /// the tier).
    pub embed_memo_capacity: usize,
    /// Lock shards of the embedding memo tier.
    pub embed_memo_shards: usize,
    /// Worker-pool width for native `encode_batch` (0 = one per core).
    pub embed_workers: usize,

    // Store
    pub store_shards: usize,

    // Simulated upstream (DESIGN.md §3 substitution)
    /// Mean network round-trip to the simulated LLM API, ms.
    pub llm_rtt_ms: f64,
    /// Per-output-token decode time of the simulated LLM, ms.
    pub llm_ms_per_token: f64,
    /// Mean response length in tokens.
    pub llm_mean_output_tokens: f64,
    /// Wall-clock pacing: if false the latency model is virtual-time only
    /// (experiments run fast); if true the server actually sleeps.
    pub llm_real_sleep: bool,
    /// Log-normal jitter sigma of the simulated LLM latency model.
    pub llm_jitter_sigma: f64,
    /// Seed for the simulated LLM's answer-synthesis RNG (fault
    /// schedules seed separately, via the fault plan).
    pub llm_seed: u64,

    // Upstream resilience (coordinator::resilience)
    /// Default end-to-end serving deadline per request, ms (requests may
    /// tighten it via `deadline_ms`). 0 disables deadlines.
    pub upstream_deadline_ms: u64,
    /// Upstream retry budget per miss (attempts = 1 + retries).
    pub upstream_max_retries: u32,
    /// First retry backoff, ms (doubles per retry, jittered).
    pub upstream_backoff_base_ms: u64,
    /// Backoff ceiling, ms.
    pub upstream_backoff_max_ms: u64,
    /// Consecutive upstream failures that trip the breaker open.
    pub upstream_breaker_failures: u32,
    /// How long an open breaker blocks upstream traffic before allowing
    /// half-open probes, ms.
    pub upstream_breaker_open_ms: u64,
    /// Successful half-open probes required to close the breaker.
    pub upstream_breaker_halfopen_probes: u32,
    /// In-flight upstream call cap; misses beyond it are shed into
    /// degraded serving instead of queueing (0 = uncapped).
    pub upstream_max_inflight: usize,
    /// Relaxed similarity gate for degraded-mode serving when the
    /// upstream is unavailable (must be <= 1; lower than the production
    /// threshold by design).
    pub degraded_threshold: f32,

    // Workload
    pub workload_seed: u64,
    /// Queries per second for the trace generator (Poisson).
    pub trace_qps: f64,

    // Coordinator
    pub workers: usize,
    /// Housekeeping cadence (TTL sweep + rebuild check), ms.
    pub housekeeping_ms: u64,

    // HTTP front-end (semcached)
    /// Serve with the epoll/poll readiness loop (default); false selects
    /// the legacy blocking thread-per-connection path
    /// (`--threaded-accept`).
    pub http_event_loop: bool,
    /// Event-loop connection cap; connections beyond it are answered
    /// 503 at accept time. Auto-clamped at startup against the
    /// process's file-descriptor limit (after `raise_nofile_limit`).
    pub http_max_conns: usize,
    /// Reactor (event-loop) threads on the wire path. The default is
    /// sized from the core count; `0` is a legacy alias for `1` (the
    /// pre-sharding single-threaded reactor).
    pub http_reactors: usize,
    /// Batcher dispatcher threads, hash-sharded on the coalescing key.
    /// The default is sized from the core count; `0` is a legacy alias
    /// for `1` (the pre-sharding single dispatcher).
    pub http_dispatchers: usize,

    // Durability (crate::persist)
    /// Directory for WAL segments + snapshots; empty disables
    /// persistence (pure in-memory serving, the pre-durability default).
    pub data_dir: String,
    /// Seconds between automatic snapshots (WAL truncation points).
    pub snapshot_interval_secs: u64,
    /// WAL fsync policy: "os" (write only; survives SIGKILL) or
    /// "always" (fsync per record; survives power loss).
    pub wal_sync: String,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            similarity_threshold: 0.8,
            ttl_secs: 0,
            cache_capacity: 0,
            max_bytes: 0,
            eviction_policy: "lru".into(),
            tenant_quota_bytes: 0,
            tenants: BTreeMap::new(),
            top_k: 5,
            index_kind: "hnsw".into(),
            hnsw_m: 16,
            hnsw_ef_construction: 200,
            hnsw_ef_search: 64,
            rebuild_garbage_ratio: 0.3,
            quantized_scan: true,
            encoder_kind: "native".into(),
            batch_window_us: 200,
            max_batch: 8,
            embed_memo_capacity: 4096,
            embed_memo_shards: 8,
            embed_workers: 0,
            store_shards: 16,
            llm_rtt_ms: 150.0,
            llm_ms_per_token: 12.0,
            llm_mean_output_tokens: 120.0,
            llm_real_sleep: false,
            llm_jitter_sigma: 0.25,
            llm_seed: 0x11AA,
            upstream_deadline_ms: 10_000,
            upstream_max_retries: 2,
            upstream_backoff_base_ms: 50,
            upstream_backoff_max_ms: 2_000,
            upstream_breaker_failures: 5,
            upstream_breaker_open_ms: 1_000,
            upstream_breaker_halfopen_probes: 2,
            upstream_max_inflight: 256,
            degraded_threshold: 0.6,
            workload_seed: 0xC0FFEE,
            trace_qps: 200.0,
            workers: 4,
            housekeeping_ms: 1000,
            http_event_loop: true,
            http_max_conns: 1024,
            http_reactors: crate::util::auto_reactors(),
            http_dispatchers: crate::util::auto_dispatchers(),
            data_dir: String::new(),
            snapshot_interval_secs: 60,
            wal_sync: "os".into(),
        }
    }
}

impl Config {
    /// Load from a TOML-subset file, applying it over defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let mut cfg = Self::default();
        cfg.apply_table(&parse_toml(&text)?)?;
        Ok(cfg)
    }

    /// Assemble the config a binary serves with: `--config <file>` over
    /// defaults, then any `--<config-key> <value>` override. Flags named
    /// in `reserved` (the binary's own, e.g. `--port`) are skipped, as
    /// are the conventions handled here: `--encoder` (falling back to
    /// pjrt-if-ready, else native) and `--seed`. Validates the result.
    /// Shared by the `semcache` and `semcached` binaries.
    pub fn from_args(args: &crate::cli::Args, reserved: &[&str]) -> Result<Self> {
        let mut cfg = match args.opt("config") {
            Some(path) => Config::from_file(Path::new(path))?,
            None => Config::default(),
        };
        for (k, v) in args.options() {
            if matches!(k.as_str(), "config" | "encoder" | "seed")
                || reserved.contains(&k.as_str())
            {
                continue;
            }
            cfg.set(k, v).with_context(|| format!("CLI override --{k}"))?;
        }
        if let Some(e) = args.opt("encoder") {
            cfg.encoder_kind = e.to_string();
        } else if crate::runtime::pjrt_ready() {
            cfg.encoder_kind = "pjrt".into();
        } else {
            cfg.encoder_kind = "native".into();
        }
        if let Some(seed) = args.opt("seed") {
            cfg.workload_seed = seed.parse().context("--seed")?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply flat `section.key -> raw string` pairs.
    pub fn apply_table(&mut self, table: &BTreeMap<String, String>) -> Result<()> {
        for (k, v) in table {
            self.set(k, v)?;
        }
        Ok(())
    }

    /// Set one key (section-qualified or bare) from its string form.
    pub fn set(&mut self, key: &str, raw: &str) -> Result<()> {
        // `tenant.<name>.<field>` is the one key family where the middle
        // component is data (the tenant name), so it must be routed
        // before the bare-suffix dispatch below ever strips it.
        if let Some(rest) = key.strip_prefix("tenant.") {
            let (name, field) = match rest.rsplit_once('.') {
                Some((n, f)) if !n.is_empty() => (n, f),
                _ => bail!(
                    "per-tenant config key must be tenant.<name>.<field>, got '{key}'"
                ),
            };
            let o = self.tenants.entry(name.to_string()).or_default();
            match field {
                "quota_bytes" => {
                    o.quota_bytes =
                        Some(raw.parse().with_context(|| format!("config {key}={raw}"))?)
                }
                "similarity_threshold" => {
                    o.similarity_threshold =
                        Some(raw.parse().with_context(|| format!("config {key}={raw}"))?)
                }
                other => bail!(
                    "unknown per-tenant key '{other}' (expected quota_bytes|similarity_threshold)"
                ),
            }
            return Ok(());
        }
        // Accept both "cache.similarity_threshold" and "similarity_threshold".
        let bare = key.rsplit('.').next().unwrap_or(key);
        macro_rules! num {
            () => {
                raw.parse().with_context(|| format!("config {key}={raw}"))?
            };
        }
        match bare {
            "similarity_threshold" => self.similarity_threshold = num!(),
            "ttl_secs" => self.ttl_secs = num!(),
            "cache_capacity" => self.cache_capacity = num!(),
            "max_bytes" => self.max_bytes = num!(),
            "eviction_policy" => self.eviction_policy = raw.to_string(),
            "tenant_quota_bytes" => self.tenant_quota_bytes = num!(),
            "top_k" => self.top_k = num!(),
            "index_kind" => self.index_kind = raw.to_string(),
            "hnsw_m" => self.hnsw_m = num!(),
            "hnsw_ef_construction" => self.hnsw_ef_construction = num!(),
            "hnsw_ef_search" => self.hnsw_ef_search = num!(),
            "rebuild_garbage_ratio" => self.rebuild_garbage_ratio = num!(),
            "quantized_scan" => self.quantized_scan = num!(),
            "encoder_kind" => self.encoder_kind = raw.to_string(),
            "batch_window_us" => self.batch_window_us = num!(),
            "max_batch" => self.max_batch = num!(),
            "embed_memo_capacity" => self.embed_memo_capacity = num!(),
            "embed_memo_shards" => self.embed_memo_shards = num!(),
            "embed_workers" => self.embed_workers = num!(),
            "store_shards" => self.store_shards = num!(),
            "llm_rtt_ms" => self.llm_rtt_ms = num!(),
            "llm_ms_per_token" => self.llm_ms_per_token = num!(),
            "llm_mean_output_tokens" => self.llm_mean_output_tokens = num!(),
            "llm_real_sleep" => self.llm_real_sleep = num!(),
            "llm_jitter_sigma" => self.llm_jitter_sigma = num!(),
            "llm_seed" => self.llm_seed = num!(),
            "upstream_deadline_ms" => self.upstream_deadline_ms = num!(),
            "upstream_max_retries" => self.upstream_max_retries = num!(),
            "upstream_backoff_base_ms" => self.upstream_backoff_base_ms = num!(),
            "upstream_backoff_max_ms" => self.upstream_backoff_max_ms = num!(),
            "upstream_breaker_failures" => self.upstream_breaker_failures = num!(),
            "upstream_breaker_open_ms" => self.upstream_breaker_open_ms = num!(),
            "upstream_breaker_halfopen_probes" => self.upstream_breaker_halfopen_probes = num!(),
            "upstream_max_inflight" => self.upstream_max_inflight = num!(),
            "degraded_threshold" => self.degraded_threshold = num!(),
            "workload_seed" => self.workload_seed = num!(),
            "trace_qps" => self.trace_qps = num!(),
            "workers" => self.workers = num!(),
            "housekeeping_ms" => self.housekeeping_ms = num!(),
            "http_event_loop" => self.http_event_loop = num!(),
            "http_max_conns" => self.http_max_conns = num!(),
            "http_reactors" => self.http_reactors = num!(),
            "http_dispatchers" => self.http_dispatchers = num!(),
            "data_dir" => self.data_dir = raw.to_string(),
            "snapshot_interval_secs" => self.snapshot_interval_secs = num!(),
            "wal_sync" => self.wal_sync = raw.to_string(),
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.similarity_threshold) {
            bail!("similarity_threshold must be in [0,1]");
        }
        if self.top_k == 0 {
            bail!("top_k must be >= 1");
        }
        // Resolvable policy name (lru|lfu|cost).
        crate::eviction::policy_from_name(&self.eviction_policy)?;
        for (name, o) in &self.tenants {
            if name.trim().is_empty() {
                bail!("tenant name must not be blank");
            }
            if let Some(t) = o.similarity_threshold {
                if !(0.0..=1.0).contains(&t) {
                    bail!("tenant.{name}.similarity_threshold must be in [0,1], got {t}");
                }
            }
        }
        match self.index_kind.as_str() {
            "hnsw" | "flat" => {}
            other => bail!("index_kind must be hnsw|flat, got '{other}'"),
        }
        match self.encoder_kind.as_str() {
            "pjrt" | "native" => {}
            other => bail!("encoder_kind must be pjrt|native, got '{other}'"),
        }
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        if self.embed_memo_capacity > 0 && self.embed_memo_shards == 0 {
            bail!("embed_memo_shards must be >= 1 when the memo tier is enabled");
        }
        if self.http_max_conns == 0 {
            bail!("http_max_conns must be >= 1");
        }
        // 0 is accepted as the legacy "old single-threaded behavior"
        // alias for both knobs; only absurd widths are rejected.
        if self.http_reactors > 256 {
            bail!("http_reactors must be <= 256, got {}", self.http_reactors);
        }
        if self.http_dispatchers > crate::coordinator::MAX_DISPATCHERS_LIMIT {
            bail!(
                "http_dispatchers must be <= {}, got {}",
                crate::coordinator::MAX_DISPATCHERS_LIMIT,
                self.http_dispatchers
            );
        }
        if !self.llm_jitter_sigma.is_finite() || self.llm_jitter_sigma < 0.0 {
            bail!("llm_jitter_sigma must be finite and >= 0, got {}", self.llm_jitter_sigma);
        }
        if !(-1.0..=1.0).contains(&self.degraded_threshold) {
            bail!("degraded_threshold must be in [-1,1], got {}", self.degraded_threshold);
        }
        if self.upstream_breaker_failures == 0 {
            bail!("upstream_breaker_failures must be >= 1");
        }
        if self.upstream_breaker_halfopen_probes == 0 {
            bail!("upstream_breaker_halfopen_probes must be >= 1");
        }
        if self.upstream_backoff_max_ms < self.upstream_backoff_base_ms {
            bail!(
                "upstream_backoff_max_ms ({}) must be >= upstream_backoff_base_ms ({})",
                self.upstream_backoff_max_ms,
                self.upstream_backoff_base_ms
            );
        }
        match self.wal_sync.as_str() {
            "os" | "always" => {}
            other => bail!("wal_sync must be os|always, got '{other}'"),
        }
        if !self.data_dir.is_empty() && self.snapshot_interval_secs == 0 {
            bail!("snapshot_interval_secs must be >= 1 when persistence is enabled");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = Config::default();
        assert_eq!(c.similarity_threshold, 0.8);
        assert_eq!(c.index_kind, "hnsw");
        c.validate().unwrap();
    }

    #[test]
    fn set_and_validate() {
        let mut c = Config::default();
        c.set("cache.similarity_threshold", "0.75").unwrap();
        c.set("hnsw_m", "8").unwrap();
        c.set("index.index_kind", "flat").unwrap();
        assert_eq!(c.similarity_threshold, 0.75);
        assert_eq!(c.hnsw_m, 8);
        assert_eq!(c.index_kind, "flat");
        c.validate().unwrap();
    }

    #[test]
    fn embed_hotpath_keys_roundtrip_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.embed_memo_capacity, 4096);
        c.set("embedding.embed_memo_capacity", "128").unwrap();
        c.set("embed_memo_shards", "2").unwrap();
        c.set("embed_workers", "4").unwrap();
        assert_eq!((c.embed_memo_capacity, c.embed_memo_shards, c.embed_workers), (128, 2, 4));
        c.validate().unwrap();
        c.embed_memo_shards = 0;
        assert!(c.validate().is_err(), "enabled tier needs >= 1 shard");
        c.embed_memo_capacity = 0; // disabled tier: shards irrelevant
        c.validate().unwrap();
    }

    #[test]
    fn quantized_scan_key_roundtrips_and_defaults_on() {
        let mut c = Config::default();
        assert!(c.quantized_scan, "quantized scan is the default");
        c.set("index.quantized_scan", "false").unwrap();
        assert!(!c.quantized_scan);
        c.set("quantized_scan", "true").unwrap();
        assert!(c.quantized_scan);
        c.validate().unwrap();
        assert!(c.set("quantized_scan", "maybe").is_err(), "non-bool must be rejected");
    }

    #[test]
    fn http_front_end_keys_roundtrip_and_validate() {
        let mut c = Config::default();
        assert!(c.http_event_loop, "event loop is the default");
        assert_eq!(c.http_max_conns, 1024);
        assert!(c.http_reactors >= 1, "auto-sized reactor fleet is at least 1");
        assert!(c.http_dispatchers >= 1, "auto-sized dispatcher pool is at least 1");
        c.set("http.http_event_loop", "false").unwrap();
        c.set("http_max_conns", "64").unwrap();
        c.set("http.http_reactors", "4").unwrap();
        c.set("http_dispatchers", "2").unwrap();
        assert!(!c.http_event_loop);
        assert_eq!(c.http_max_conns, 64);
        assert_eq!((c.http_reactors, c.http_dispatchers), (4, 2));
        c.validate().unwrap();
        // 0 = legacy single-threaded alias: valid, not an error.
        c.set("http_reactors", "0").unwrap();
        c.set("http_dispatchers", "0").unwrap();
        c.validate().unwrap();
        c.http_reactors = 257;
        assert!(c.validate().is_err(), "absurd reactor width must be rejected");
        c.http_reactors = 4;
        c.http_dispatchers = crate::coordinator::MAX_DISPATCHERS_LIMIT + 1;
        assert!(c.validate().is_err(), "dispatcher width above the shard cap");
        c.http_dispatchers = 2;
        c.http_max_conns = 0;
        assert!(c.validate().is_err(), "a zero connection budget serves nothing");
    }

    #[test]
    fn persistence_keys_roundtrip_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.data_dir, "", "persistence is off by default");
        assert_eq!(c.snapshot_interval_secs, 60);
        assert_eq!(c.wal_sync, "os");
        c.set("persist.data_dir", "/tmp/semcache-data").unwrap();
        c.set("snapshot_interval_secs", "5").unwrap();
        c.set("wal_sync", "always").unwrap();
        assert_eq!(c.data_dir, "/tmp/semcache-data");
        assert_eq!(c.snapshot_interval_secs, 5);
        assert_eq!(c.wal_sync, "always");
        c.validate().unwrap();
        c.wal_sync = "maybe".into();
        assert!(c.validate().is_err(), "unknown fsync policy must be rejected");
        c.wal_sync = "os".into();
        c.snapshot_interval_secs = 0;
        assert!(c.validate().is_err(), "zero interval with a data dir is a footgun");
        c.data_dir.clear(); // persistence off: interval irrelevant
        c.validate().unwrap();
    }

    #[test]
    fn eviction_and_tenancy_keys_roundtrip_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.max_bytes, 0, "unbounded by default");
        assert_eq!(c.eviction_policy, "lru");
        assert_eq!(c.tenant_quota_bytes, 0);
        assert!(c.tenants.is_empty());
        c.set("cache.max_bytes", "1048576").unwrap();
        c.set("eviction_policy", "cost").unwrap();
        c.set("tenant_quota_bytes", "65536").unwrap();
        c.set("tenant.alice.quota_bytes", "4096").unwrap();
        c.set("tenant.alice.similarity_threshold", "0.9").unwrap();
        c.set("tenant.bot-7.quota_bytes", "0").unwrap();
        assert_eq!(c.max_bytes, 1_048_576);
        assert_eq!(c.eviction_policy, "cost");
        assert_eq!(c.tenant_quota_bytes, 65_536);
        assert_eq!(
            c.tenants["alice"],
            TenantOverrides { quota_bytes: Some(4096), similarity_threshold: Some(0.9) }
        );
        assert_eq!(c.tenants["bot-7"].quota_bytes, Some(0));
        c.validate().unwrap();
        // Legacy count-based cap still *parses* (migration: clamped, not
        // rejected — see Config::cache_capacity).
        c.set("cache_capacity", "500").unwrap();
        c.validate().unwrap();
        c.eviction_policy = "random".into();
        assert!(c.validate().is_err(), "unknown policy must be rejected");
        c.eviction_policy = "lfu".into();
        c.tenants.get_mut("alice").unwrap().similarity_threshold = Some(1.5);
        assert!(c.validate().is_err(), "tenant threshold outside [0,1]");
        // Malformed per-tenant keys are routed errors, not silent drops.
        let mut c = Config::default();
        assert!(c.set("tenant.alice", "7").is_err(), "missing field");
        assert!(c.set("tenant.alice.nope", "7").is_err(), "unknown field");
        assert!(c.set("tenant.alice.quota_bytes", "lots").is_err(), "non-numeric");
    }

    #[test]
    fn tenant_tables_parse_from_toml() {
        let dir = std::env::temp_dir().join("semcache_cfg_tenant_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        std::fs::write(
            &p,
            "[cache]\nmax_bytes = 2097152\neviction_policy = \"cost\"\n\n\
             [tenant.hot]\nquota_bytes = 131072\n\n\
             [tenant.cold]\nquota_bytes = 65536\nsimilarity_threshold = 0.85\n",
        )
        .unwrap();
        let c = Config::from_file(&p).unwrap();
        assert_eq!(c.max_bytes, 2_097_152);
        assert_eq!(c.eviction_policy, "cost");
        assert_eq!(c.tenants["hot"].quota_bytes, Some(131_072));
        assert_eq!(c.tenants["cold"].similarity_threshold, Some(0.85));
        c.validate().unwrap();
    }

    #[test]
    fn upstream_resilience_keys_roundtrip_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.llm_jitter_sigma, 0.25);
        assert!(c.degraded_threshold < c.similarity_threshold, "degraded gate is laxer");
        c.set("llm.llm_jitter_sigma", "0.5").unwrap();
        c.set("llm_seed", "42").unwrap();
        c.set("upstream.upstream_deadline_ms", "1500").unwrap();
        c.set("upstream_max_retries", "4").unwrap();
        c.set("upstream_backoff_base_ms", "25").unwrap();
        c.set("upstream_backoff_max_ms", "500").unwrap();
        c.set("upstream_breaker_failures", "3").unwrap();
        c.set("upstream_breaker_open_ms", "200").unwrap();
        c.set("upstream_breaker_halfopen_probes", "1").unwrap();
        c.set("upstream_max_inflight", "8").unwrap();
        c.set("degraded_threshold", "0.5").unwrap();
        assert_eq!(c.llm_jitter_sigma, 0.5);
        assert_eq!(c.llm_seed, 42);
        assert_eq!(c.upstream_deadline_ms, 1500);
        assert_eq!(c.upstream_max_retries, 4);
        assert_eq!((c.upstream_backoff_base_ms, c.upstream_backoff_max_ms), (25, 500));
        assert_eq!(c.upstream_breaker_failures, 3);
        assert_eq!(c.upstream_breaker_open_ms, 200);
        assert_eq!(c.upstream_breaker_halfopen_probes, 1);
        assert_eq!(c.upstream_max_inflight, 8);
        assert_eq!(c.degraded_threshold, 0.5);
        c.validate().unwrap();
        c.degraded_threshold = 1.5;
        assert!(c.validate().is_err(), "degraded gate outside cosine range");
        c.degraded_threshold = 0.5;
        c.upstream_breaker_failures = 0;
        assert!(c.validate().is_err(), "a 0-failure breaker would never close");
        c.upstream_breaker_failures = 3;
        c.upstream_backoff_max_ms = 1;
        assert!(c.validate().is_err(), "backoff ceiling below its base");
        c.upstream_backoff_max_ms = 500;
        c.llm_jitter_sigma = -1.0;
        assert!(c.validate().is_err(), "negative jitter sigma");
    }

    #[test]
    fn bad_values_rejected() {
        let mut c = Config::default();
        assert!(c.set("similarity_threshold", "abc").is_err());
        assert!(c.set("nonexistent_key", "1").is_err());
        c.similarity_threshold = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.index_kind = "annoy".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("semcache_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.toml");
        std::fs::write(
            &p,
            "# comment\n[cache]\nsimilarity_threshold = 0.7\nttl_secs = 60\n\n[llm]\nllm_real_sleep = true\n",
        )
        .unwrap();
        let c = Config::from_file(&p).unwrap();
        assert_eq!(c.similarity_threshold, 0.7);
        assert_eq!(c.ttl_secs, 60);
        assert!(c.llm_real_sleep);
    }
}
