//! TOML-subset parser: `[section]` headers, `key = value` lines, `#`
//! comments. Values keep their raw string form (quotes stripped); typed
//! parsing happens in [`super::Config::set`]. Flattens to
//! `section.key -> value`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse the subset; returns flattened `section.key -> raw value`.
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, String>, TomlError> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (i, raw_line) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| TomlError { line: line_no, msg: "unterminated section".into() })?
                .trim();
            if name.is_empty() {
                return Err(TomlError { line: line_no, msg: "empty section name".into() });
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| TomlError { line: line_no, msg: "expected key = value".into() })?;
        let key = line[..eq].trim();
        let mut value = line[eq + 1..].trim().to_string();
        if key.is_empty() {
            return Err(TomlError { line: line_no, msg: "empty key".into() });
        }
        // Strip matching quotes.
        if (value.starts_with('"') && value.ends_with('"') && value.len() >= 2)
            || (value.starts_with('\'') && value.ends_with('\'') && value.len() >= 2)
        {
            value = value[1..value.len() - 1].to_string();
        }
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        out.insert(full, value);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside quotes.
    let mut in_str: Option<char> = None;
    for (i, c) in line.char_indices() {
        match (c, in_str) {
            ('"' | '\'', None) => in_str = Some(c),
            (c, Some(q)) if c == q => in_str = None,
            ('#', None) => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_flatten() {
        let t = parse_toml("a = 1\n[s]\nb = 2\n[t]\nc = \"x y\"\n").unwrap();
        assert_eq!(t["a"], "1");
        assert_eq!(t["s.b"], "2");
        assert_eq!(t["t.c"], "x y");
    }

    #[test]
    fn comments_and_blank_lines() {
        let t = parse_toml("# full comment\n\nk = 5 # trailing\nq = \"has # inside\"\n").unwrap();
        assert_eq!(t["k"], "5");
        assert_eq!(t["q"], "has # inside");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse_toml("good = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse_toml("[unclosed\n").unwrap_err();
        assert_eq!(e.line, 1);
    }
}
