//! Minimal error type for the offline build (no `anyhow`).
//!
//! Mirrors the small slice of the `anyhow` API this crate uses: a
//! string-chained [`Error`], the [`Result`] alias, the [`bail!`] /
//! [`anyhow!`] macros, and the [`Context`] extension trait for `Result`
//! and `Option`. Contexts stack outermost-first; `{e}` prints the
//! outermost message and `{e:#}` prints the full chain separated by
//! `": "` (the same convention `anyhow` uses).
//!
//! ```
//! use semcache::error::{Context, Result};
//!
//! fn parse(raw: &str) -> Result<u32> {
//!     raw.parse::<u32>().with_context(|| format!("parsing '{raw}'"))
//! }
//! let err = parse("abc").unwrap_err();
//! assert!(format!("{err:#}").starts_with("parsing 'abc': "));
//! ```

use std::fmt;

/// A chain of error messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a single message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { chain: vec![msg.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, msg: impl fmt::Display) -> Self {
        self.chain.insert(0, msg.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        for cause in self.chain.iter().skip(1) {
            write!(f, "\n  caused by: {cause}")?;
        }
        Ok(())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::msg(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<crate::json::ParseError> for Error {
    fn from(e: crate::json::ParseError) -> Self {
        Error::msg(e)
    }
}

impl From<crate::config::TomlError> for Error {
    fn from(e: crate::config::TomlError) -> Self {
        Error::msg(e)
    }
}

/// Attach context to fallible values (`Result` or `Option`).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

/// Convert any error into [`Error`], preserving the chain when it
/// already *is* an [`Error`] (detected by `Any` downcast — the blanket
/// impl below cannot specialize on the error type).
fn into_error<E: fmt::Display + std::any::Any>(e: E) -> Error {
    let mut holder = Some(e);
    {
        let any: &mut dyn std::any::Any = &mut holder;
        if let Some(opt) = any.downcast_mut::<Option<Error>>() {
            if let Some(err) = opt.take() {
                return err;
            }
        }
    }
    Error::msg(holder.take().expect("error still present"))
}

impl<T, E: fmt::Display + std::any::Any> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| into_error(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| into_error(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted [`Error`] (the `anyhow::bail!` analogue).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::Error::msg(format!($($arg)*)))
    };
}

/// Build a formatted [`Error`] value (the `anyhow::anyhow!` analogue).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

// Make `use crate::error::{bail, anyhow}` work like the anyhow imports
// the call sites were written against (`#[macro_export]` exports at the
// crate root; these aliases put them back under `error::`).
pub use crate::anyhow;
pub use crate::bail;

#[cfg(test)]
mod tests {
    use super::*;

    fn fail() -> Result<()> {
        bail!("root problem {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fail().unwrap_err();
        assert_eq!(format!("{e}"), "root problem 42");
        assert_eq!(format!("{e:#}"), "root problem 42");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = fail().context("outer step").unwrap_err();
        assert_eq!(format!("{e}"), "outer step");
        assert_eq!(format!("{e:#}"), "outer step: root problem 42");
        assert_eq!(e.root_cause(), "root problem 42");
    }

    #[test]
    fn context_on_error_preserves_the_chain() {
        // Stacking contexts on a Result<_, Error> must extend the chain,
        // not flatten it into one string.
        let e = fail().context("mid step").context("outer step").unwrap_err();
        assert_eq!(e.chain().len(), 3);
        assert_eq!(e.chain().join(" | "), "outer step | mid step | root problem 42");
        assert_eq!(e.root_cause(), "root problem 42");
        assert_eq!(format!("{e}"), "outer step");
        assert_eq!(format!("{e:#}"), "outer step: mid step: root problem 42");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing value");
        let some = Some(7u32).context("unused").unwrap();
        assert_eq!(some, 7);
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, String> = Ok(1);
        let v = ok
            .with_context(|| -> String { panic!("must not be called on Ok") })
            .unwrap();
        assert_eq!(v, 1);
    }

    #[test]
    fn from_io_and_parse_errors() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(format!("{e}").contains("nope"));
        let p = crate::json::parse("{").unwrap_err();
        let e: Error = p.into();
        assert!(format!("{e}").contains("json parse error"));
    }

    #[test]
    fn anyhow_macro_builds_error() {
        let e = anyhow!("ad hoc {}", "msg");
        assert_eq!(format!("{e}"), "ad hoc msg");
    }
}
