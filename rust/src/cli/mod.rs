//! Hand-rolled CLI argument parser (no `clap` in the offline build),
//! shared by the `semcache` experiment binary and the `semcached`
//! serving daemon.
//!
//! Grammar: `semcache <subcommand> [--key value]... [--flag]...`
//! Unknown keys are an error; `--help` short-circuits.

use std::collections::BTreeMap;

use crate::error::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from raw argv (excluding argv[0]).
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' not supported");
                }
                // --key=value or --key value or boolean flag.
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(key.to_string(), it.next().unwrap().clone());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.options.get(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse()
                .map_err(|_| anyhow!("invalid value for --{key}: '{raw}'")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// All `--key value` options (for config overrides).
    pub fn options(&self) -> &BTreeMap<String, String> {
        &self.options
    }
}

pub const USAGE: &str = "\
GPT Semantic Cache — reproduction of Regmi & Pun (2024)

USAGE:
    semcache <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
    info         Show build/artifact/runtime information
    dataset      Generate the evaluation dataset (writes JSON)
    experiment   Run the paper evaluation (table1 | fig2 | fig3 | fig4 | all)
    sweep        §5.3 similarity-threshold sweep (0.60..0.90)
    scaling      §2.4 HNSW vs exhaustive-search scaling study
    serve        Run the live serving demo over a generated trace
    help         Show this message

COMMON OPTIONS:
    --config <path>          TOML config file (configs/*.toml)
    --encoder <pjrt|native>  Embedding backend (default: pjrt if artifacts exist)
    --scale <paper|small|tiny>  Dataset scale (default: paper)
    --seed <u64>             Workload seed
    --out <dir>              Output directory for reports (default: results)
    --<config-key> <value>   Any config key (e.g. --similarity_threshold 0.75)

EXAMPLES:
    semcache experiment all --scale small --encoder native
    semcache sweep --out results
    semcache serve --qps 200 --workers 8

SEE ALSO:
    semcached — the cache as a network service (HTTP/1.1 JSON API)
";

pub const SEMCACHED_USAGE: &str = "\
semcached — GPT Semantic Cache as a network service

USAGE:
    semcached <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
    serve        Run the HTTP/1.1 front-end (POST /v1/query, /v1/query_batch,
                 /v1/admin; GET /v1/metrics, /v1/health)
    query        Send one query to a running daemon and print the JSON reply
    metrics      Fetch /v1/metrics from a running daemon
    admin        Send an admin action (flush | housekeep | snapshot | stats
                 | fault) — `fault` reconfigures upstream fault injection
                 live: no options clears all faults; --outage (bare flag)
                 is a full outage until reconfigured; --error-prob,
                 --rate-limit-prob, --retry-after-ms, --spike-prob,
                 --spike-min-ms, --spike-max-ms, --hang-prob, --hang-ms,
                 --outage-from-call, --outage-until-call, --fault-seed
                 set individual knobs (absent knobs keep defaults)
    stress-idle  Hold idle keep-alive connections open against a daemon
                 (--conns N, --hold-ms MS; probes idle-fan-in behavior)
    help         Show this message

SERVE OPTIONS:
    --port <u16>             Listen port (default 8080; 0 = ephemeral)
    --bind <addr>            Bind address (default 127.0.0.1)
    --http-workers <n>       Request-handler threads (default 4)
    --workers <n>            Batch-pipeline worker threads (default 4)
    --threaded-accept        Legacy blocking thread-per-connection serving
                             (idle keep-alive connections pin workers)
    --event-loop             Force the default epoll/poll readiness loop
                             (e.g. over a config with http_event_loop=false)
    --max-conns <n>          Event-loop connection cap; beyond it new
                             connections get 503 at accept (default 1024;
                             auto-clamped to the fd limit at startup)
    --reactors <n>           Event-loop reactor threads (default: sized
                             from cores; 0 = legacy single-threaded loop)
    --dispatchers <n>        Batcher dispatcher shards, hash-partitioned
                             on the coalescing key (default: sized from
                             cores; 0 = legacy single dispatcher)
    --no-batch               Serve each query in isolation instead of
                             coalescing concurrent in-flight queries
    --batch-max-size <n>     Micro-batch size cap (default 8; >= 1)
    --batch-wait-us <us>     Dispatch window: max extra wait for
                             stragglers, microseconds (default 200; <= 1s)
    --batch-queue <n>        Bounded submit queue; a full queue answers
                             503 Service Unavailable (default 1024)
    --populate <scale>       Pre-populate from the synthetic workload
                             (paper | small | tiny)
    --port-file <path>       Write the bound host:port to a file once ready
    --data-dir <path>        Durability: recover cache state from this
                             directory at startup, journal every mutation
                             (WAL) and snapshot periodically; omit for
                             pure in-memory serving
    --config <path>          TOML config file (configs/*.toml)
    --<config-key> <value>   Any config key (e.g. --similarity_threshold 0.75,
                             --embed_memo_capacity 4096 [0 = no memo tier],
                             --snapshot_interval_secs 60,
                             --wal_sync os|always [os survives SIGKILL,
                             always also survives power loss],
                             --max_bytes 67108864 [global cache byte
                             budget; 0 = unbounded],
                             --eviction_policy lru|lfu|cost [budget
                             eviction order; cost = latency saved/byte],
                             --tenant_quota_bytes 1048576 [default
                             per-tenant byte quota; 0 = unlimited],
                             --tenant.<name>.quota_bytes N and
                             --tenant.<name>.similarity_threshold F
                             [per-tenant overrides; also `[tenant.<name>]`
                             tables in the config file],
                             --upstream_deadline_ms 10000 [per-request
                             LLM budget; 0 = unbounded],
                             --upstream_max_retries 2,
                             --upstream_breaker_failures 5 [consecutive
                             failures that open the circuit breaker],
                             --upstream_max_inflight 256 [upstream
                             concurrency cap; excess misses shed],
                             --degraded_threshold 0.6 [relaxed gate for
                             cache-only serving while upstream is down])

CLIENT OPTIONS (query | metrics | admin):
    --addr <host:port>       Daemon address (default 127.0.0.1:8080)
    --threshold <f32>        Per-request similarity gate      (query)
    --top-k <n>              Per-request candidate-set width  (query)
    --ttl-ms <ms>            Per-request insert TTL           (query)
    --deadline-ms <ms>       Per-request upstream deadline override
                             (>= 1; 0 is rejected)            (query)
    --tag <string>           client_tag: selects the tenant
                             namespace, echoed on the reply   (query)
    --embed-bypass           Skip the embedding memo read; bare flag,
                             place it AFTER the query text    (query)

EXAMPLES:
    semcached serve --port 8080 --populate small
    semcached query \"how do i reset my password\"
    curl -s localhost:8080/v1/query -d '{\"text\": \"how do i reset my password\"}'
    semcached admin flush
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse(&["experiment", "table1", "--seed", "42", "--fast", "--out=res"]);
        assert_eq!(a.subcommand, "experiment");
        assert_eq!(a.positional(), &["table1".to_string()]);
        assert_eq!(a.opt("seed"), Some("42"));
        assert_eq!(a.opt("out"), Some("res"));
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
        assert_eq!(a.opt_parse::<u64>("seed", 0).unwrap(), 42);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse(&[]);
        assert_eq!(a.subcommand, "");
        assert_eq!(a.opt_parse::<usize>("missing", 7).unwrap(), 7);
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.opt_parse::<usize>("n", 0).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["serve", "--real-sleep", "--verbose"]);
        assert!(a.flag("real-sleep"));
        assert!(a.flag("verbose"));
        assert!(a.opt("real-sleep").is_none());
    }
}
