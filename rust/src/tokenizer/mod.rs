//! Deterministic hashing word tokenizer.
//!
//! The encoder consumes fixed-length sequences of token ids. Because the
//! compile path (Python) and the request path (Rust) must tokenize
//! identically, the tokenizer is a tiny deterministic algorithm duplicated
//! bit-for-bit in `python/compile/tokenizer.py`:
//!
//! 1. lowercase, then split on anything that is not `[a-z0-9']`;
//! 2. each word hashes to `2 + fnv1a64(word) % (vocab_size - 2)`;
//! 3. sequences are truncated / right-padded with PAD (id 0) to `seq_len`.
//!
//! Id 0 = PAD, id 1 = CLS (prepended). The synthetic vocabulary used by the
//! workload generator is *constructed* so that every surface word maps to a
//! distinct id (no collisions within the active vocabulary) — collisions
//! with arbitrary out-of-vocabulary words are acceptable: they only make
//! the embedding of an unseen query noisier, which mirrors a real
//! subword tokenizer's degradation.

mod hash;

pub use hash::fnv1a64;

/// PAD token id (also the mask sentinel for mean pooling).
pub const PAD_ID: i64 = 0;
/// CLS token id, prepended to every sequence.
pub const CLS_ID: i64 = 1;
/// First id available to real words.
pub const FIRST_WORD_ID: i64 = 2;

/// Tokenizer with a fixed vocabulary size and sequence length.
/// Mirrors `python/compile/tokenizer.py`.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub vocab_size: usize,
    pub seq_len: usize,
}

impl Tokenizer {
    pub fn new(vocab_size: usize, seq_len: usize) -> Self {
        assert!(vocab_size > 2, "vocab must hold PAD/CLS plus words");
        assert!(seq_len >= 2, "seq_len must hold CLS plus one word");
        Self { vocab_size, seq_len }
    }

    /// Map one word (already lowercased, non-empty) to its id.
    pub fn word_id(&self, word: &str) -> i64 {
        FIRST_WORD_ID + (fnv1a64(word.as_bytes()) % (self.vocab_size as u64 - 2)) as i64
    }

    /// Split text into normalized words.
    pub fn words(text: &str) -> Vec<String> {
        let mut out = Vec::new();
        let mut cur = String::new();
        for c in text.chars() {
            let c = c.to_ascii_lowercase();
            if c.is_ascii_alphanumeric() || c == '\'' {
                cur.push(c);
            } else if !cur.is_empty() {
                out.push(std::mem::take(&mut cur));
            }
        }
        if !cur.is_empty() {
            out.push(cur);
        }
        out
    }

    /// Tokenize to exactly `seq_len` ids: `[CLS, w0, w1, ..., PAD...]`.
    pub fn encode(&self, text: &str) -> Vec<i64> {
        let mut ids = Vec::with_capacity(self.seq_len);
        ids.push(CLS_ID);
        for w in Self::words(text) {
            if ids.len() == self.seq_len {
                break;
            }
            ids.push(self.word_id(&w));
        }
        while ids.len() < self.seq_len {
            ids.push(PAD_ID);
        }
        ids
    }

    /// Number of non-pad tokens in an encoded sequence.
    pub fn active_len(ids: &[i64]) -> usize {
        ids.iter().filter(|&&t| t != PAD_ID).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::new(4096, 32)
    }

    #[test]
    fn splits_and_normalizes() {
        assert_eq!(
            Tokenizer::words("How do I reset my-password?  "),
            vec!["how", "do", "i", "reset", "my", "password"]
        );
        assert_eq!(Tokenizer::words("don't stop"), vec!["don't", "stop"]);
        assert_eq!(Tokenizer::words("!!!"), Vec::<String>::new());
    }

    #[test]
    fn encode_shape_and_padding() {
        let t = tok();
        let ids = t.encode("hello world");
        assert_eq!(ids.len(), 32);
        assert_eq!(ids[0], CLS_ID);
        assert_ne!(ids[1], PAD_ID);
        assert_ne!(ids[2], PAD_ID);
        assert!(ids[3..].iter().all(|&i| i == PAD_ID));
        assert_eq!(Tokenizer::active_len(&ids), 3);
    }

    #[test]
    fn truncates_long_input() {
        let t = tok();
        let long: String = (0..100).map(|i| format!("w{i} ")).collect();
        let ids = t.encode(&long);
        assert_eq!(ids.len(), 32);
        assert!(ids.iter().all(|&i| i != PAD_ID));
    }

    #[test]
    fn deterministic_and_case_insensitive() {
        let t = tok();
        assert_eq!(t.encode("Reset My Password"), t.encode("reset my password"));
    }

    #[test]
    fn ids_in_range() {
        let t = tok();
        for w in ["a", "zebra", "0x7f", "pneumonoultramicroscopic"] {
            let id = t.word_id(w);
            assert!((FIRST_WORD_ID..4096).contains(&id), "{w} -> {id}");
        }
    }

    /// Known-answer vector shared with python/tests/test_tokenizer_parity.py.
    #[test]
    fn fnv_known_answer() {
        assert_eq!(fnv1a64(b"hello"), 0xa430d84680aabd0b);
        let t = tok();
        assert_eq!(t.word_id("hello"), 2 + (0xa430d84680aabd0bu64 % 4094) as i64);
    }
}
