//! FNV-1a 64-bit — the word-hash primitive of the tokenizer.
//! Twin: `python/compile/tokenizer.py::fnv1a64`.

/// FNV-1a, 64-bit offset basis / prime.
#[inline]
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_values() {
        // Canonical FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
