//! One lock's worth of the KV store: a hash map with TTL + weight
//! metadata and a lazy-LRU queue for count-capacity eviction (the
//! classic "stale pairs" trick: the queue may contain outdated
//! (seq, key) pairs; eviction pops until it finds a pair whose seq still
//! matches the entry).
//!
//! Access stamps (`access_seq`) are supplied by the owning [`super::KvStore`]
//! from one store-wide counter, so recency is comparable *across*
//! shards — the byte-budget victim scan relies on that.

use std::collections::{HashMap, VecDeque};

use crate::eviction::{EntryMeta, EvictionPolicy};

pub(super) struct Entry<V> {
    value: V,
    expires_at_ms: u64,
    /// Last-access sequence number, compared against queue pairs.
    access_seq: u64,
    /// Byte footprint charged for this entry (0 for unweighted inserts).
    bytes: u64,
    /// Accesses, counting the insert as the first.
    access_count: u64,
    /// Simulated upstream latency a hit on this entry saves, ms.
    cost_ms: f64,
}

pub(super) enum Lookup<'a, V> {
    Hit(&'a V),
    Expired,
    Miss,
}

/// A byte-budget eviction candidate as seen by one shard's scan.
pub(super) struct Victim {
    pub key: String,
    pub score: f64,
    pub seq: u64,
    pub bytes: u64,
}

pub(super) struct Shard<V> {
    map: HashMap<String, Entry<V>>,
    /// Lazy LRU queue of (access_seq, key); front = coldest candidate.
    lru: VecDeque<(u64, String)>,
}

impl<V> Shard<V> {
    pub fn new() -> Self {
        Self { map: HashMap::new(), lru: VecDeque::new() }
    }

    fn bump(&mut self, key: &str, seq: u64) {
        // Bound queue growth from repeated touches: compact when it is far
        // larger than the map (amortized O(1) per access). Runs *before*
        // pushing the new pair, so the fresh pair survives compaction.
        if self.lru.len() > 4 * self.map.len() + 15 {
            let map = &self.map;
            self.lru.retain(|(seq, k)| map.get(k).map(|e| e.access_seq == *seq).unwrap_or(false));
        }
        self.lru.push_back((seq, key.to_string()));
    }

    /// Insert, evicting LRU entries if `capacity > 0` would be exceeded.
    /// Returns the count-evicted keys (so the caller can reclaim
    /// secondary structures) and the bytes freed by the overwrite and/or
    /// evictions.
    pub fn insert(
        &mut self,
        key: String,
        value: V,
        expires_at_ms: u64,
        capacity: usize,
        seq: u64,
        bytes: u64,
        cost_ms: f64,
    ) -> (Vec<String>, u64) {
        self.bump(&key, seq);
        let replaced = self.map.insert(
            key,
            Entry { value, expires_at_ms, access_seq: seq, bytes, access_count: 1, cost_ms },
        );
        let is_new = replaced.is_none();
        let mut freed = replaced.map(|e| e.bytes).unwrap_or(0);
        let mut evicted = Vec::new();
        if capacity > 0 && is_new {
            while self.map.len() > capacity {
                if let Some((seq, k)) = self.lru.pop_front() {
                    let stale = self.map.get(&k).map(|e| e.access_seq != seq).unwrap_or(true);
                    if !stale {
                        if let Some(e) = self.map.remove(&k) {
                            freed += e.bytes;
                        }
                        evicted.push(k);
                    }
                } else {
                    break; // queue exhausted (shouldn't happen)
                }
            }
        }
        (evicted, freed)
    }

    /// Read-only lookup: no LRU bump, no lazy removal. Used by the
    /// unbounded-store fast path, where a hit needs only a shared lock;
    /// an `Expired` result tells the caller to upgrade to a write lock
    /// and reclaim via [`Shard::remove_expired`].
    pub fn peek(&self, key: &str, now_ms: u64) -> Lookup<'_, V> {
        match self.map.get(key) {
            None => Lookup::Miss,
            Some(e) if e.expires_at_ms <= now_ms => Lookup::Expired,
            Some(e) => Lookup::Hit(&e.value),
        }
    }

    /// Drop `key` only if it is present *and* expired (idempotent: safe
    /// under read-then-write upgrade races). Returns the freed bytes if
    /// it removed.
    pub fn remove_expired(&mut self, key: &str, now_ms: u64) -> Option<u64> {
        match self.map.get(key) {
            Some(e) if e.expires_at_ms <= now_ms => Some(self.map.remove(key).unwrap().bytes),
            _ => None,
        }
    }

    /// Lookup with recency/frequency bookkeeping; lazily removes an
    /// expired entry (its freed bytes ride the second tuple slot).
    pub fn get(&mut self, key: &str, now_ms: u64, seq: u64) -> (Lookup<'_, V>, u64) {
        let expired = match self.map.get(key) {
            None => return (Lookup::Miss, 0),
            Some(e) => e.expires_at_ms <= now_ms,
        };
        if expired {
            let freed = self.map.remove(key).map(|e| e.bytes).unwrap_or(0);
            return (Lookup::Expired, freed);
        }
        self.bump(key, seq);
        let e = self.map.get_mut(key).unwrap();
        e.access_seq = seq;
        e.access_count += 1;
        (Lookup::Hit(&self.map.get(key).unwrap().value), 0)
    }

    /// Remove a key outright. Returns (was live, bytes freed) — expired
    /// residents free their bytes too.
    pub fn remove(&mut self, key: &str, now_ms: u64) -> (bool, u64) {
        match self.map.remove(key) {
            Some(e) => (e.expires_at_ms > now_ms, e.bytes),
            None => (false, 0),
        }
    }

    /// Unconditional removal for byte-budget eviction; returns the freed
    /// bytes if the key was resident.
    pub fn evict(&mut self, key: &str) -> Option<u64> {
        self.map.remove(key).map(|e| e.bytes)
    }

    /// The lowest-scoring resident entry under `policy` (expired
    /// residents score negative infinity, so dead weight reclaims
    /// first). Ties break toward the colder access stamp.
    pub fn victim(&self, policy: &dyn EvictionPolicy, now_ms: u64) -> Option<Victim> {
        let mut best: Option<Victim> = None;
        for (k, e) in &self.map {
            let score = if e.expires_at_ms <= now_ms {
                f64::NEG_INFINITY
            } else {
                policy.score(&EntryMeta {
                    bytes: e.bytes,
                    last_access_seq: e.access_seq,
                    access_count: e.access_count,
                    latency_saved_ms: e.cost_ms,
                })
            };
            let better = match &best {
                None => true,
                Some(b) => score < b.score || (score == b.score && e.access_seq < b.seq),
            };
            if better {
                best = Some(Victim {
                    key: k.clone(),
                    score,
                    seq: e.access_seq,
                    bytes: e.bytes,
                });
            }
        }
        best
    }

    pub fn ttl_remaining(&self, key: &str, now_ms: u64) -> Option<u64> {
        let e = self.map.get(key)?;
        if e.expires_at_ms <= now_ms {
            None
        } else if e.expires_at_ms == u64::MAX {
            Some(u64::MAX)
        } else {
            Some(e.expires_at_ms - now_ms)
        }
    }

    /// Drop every expired entry; returns (count, bytes freed).
    pub fn sweep(&mut self, now_ms: u64) -> (usize, u64) {
        let before = self.map.len();
        let mut freed = 0;
        self.map.retain(|_, e| {
            let live = e.expires_at_ms > now_ms;
            if !live {
                freed += e.bytes;
            }
            live
        });
        (before - self.map.len(), freed)
    }

    /// Like [`Shard::sweep`], but collects the removed keys so the caller
    /// can propagate the expiry to secondary structures (e.g. tombstone
    /// the matching vector-index nodes). Returns the bytes freed.
    pub fn sweep_keys(&mut self, now_ms: u64, out: &mut Vec<String>) -> u64 {
        let start = out.len();
        for (k, e) in &self.map {
            if e.expires_at_ms <= now_ms {
                out.push(k.clone());
            }
        }
        let mut freed = 0;
        for k in &out[start..] {
            if let Some(e) = self.map.remove(k) {
                freed += e.bytes;
            }
        }
        freed
    }

    pub fn live_len(&self, now_ms: u64) -> usize {
        self.map.values().filter(|e| e.expires_at_ms > now_ms).count()
    }

    pub fn for_each_live<F: FnMut(&str, &V)>(&self, now_ms: u64, f: &mut F) {
        for (k, e) in &self.map {
            if e.expires_at_ms > now_ms {
                f(k, &e.value);
            }
        }
    }

    /// Live entries with their absolute expiry (u64::MAX = immortal);
    /// the snapshot writer converts this to wall-clock expiry.
    pub fn for_each_live_expiry<F: FnMut(&str, &V, u64)>(&self, now_ms: u64, f: &mut F) {
        for (k, e) in &self.map {
            if e.expires_at_ms > now_ms {
                f(k, &e.value, e.expires_at_ms);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn put(s: &mut Shard<u32>, key: &str, v: u32, exp: u64, cap: usize, seq: u64) -> (Vec<String>, u64) {
        s.insert(key.into(), v, exp, cap, seq, 0, 0.0)
    }

    #[test]
    fn lazy_queue_compaction_keeps_correctness() {
        let mut s: Shard<u32> = Shard::new();
        let mut seq = 0u64;
        // Hammer one key to bloat the queue, forcing compaction.
        put(&mut s, "a", 0, u64::MAX, 2, { seq += 1; seq });
        for i in 0..100 {
            seq += 1;
            match s.get("a", 0, seq) {
                (Lookup::Hit(_), _) => {}
                _ => panic!("a must stay live (iter {i})"),
            }
        }
        assert!(s.lru.len() <= 4 * s.map.len() + 16, "queue compacted");
        // LRU still works after compaction.
        put(&mut s, "b", 1, u64::MAX, 2, { seq += 1; seq });
        put(&mut s, "c", 2, u64::MAX, 2, { seq += 1; seq }); // evicts coldest
        assert_eq!(s.map.len(), 2);
    }

    #[test]
    fn peek_is_read_only_and_remove_expired_is_idempotent() {
        let mut s: Shard<u32> = Shard::new();
        s.insert("a".into(), 1, 10, 0, 1, 64, 0.0);
        let lru_before = s.lru.len();
        match s.peek("a", 5) {
            Lookup::Hit(v) => assert_eq!(*v, 1),
            _ => panic!("live entry must peek as hit"),
        }
        assert!(matches!(s.peek("a", 10), Lookup::Expired));
        assert!(matches!(s.peek("b", 0), Lookup::Miss));
        assert_eq!(s.lru.len(), lru_before, "peek must not touch the LRU queue");
        assert!(s.remove_expired("a", 5).is_none(), "live entry must survive");
        assert_eq!(s.remove_expired("a", 10), Some(64), "reclaim reports freed bytes");
        assert!(s.remove_expired("a", 10).is_none(), "second reclaim is a no-op");
    }

    #[test]
    fn overwrite_does_not_evict_and_frees_old_bytes() {
        let mut s: Shard<u32> = Shard::new();
        let (ev, freed) = s.insert("a".into(), 0, u64::MAX, 1, 1, 100, 0.0);
        assert!(ev.is_empty());
        assert_eq!(freed, 0);
        let (ev, freed) = s.insert("a".into(), 1, u64::MAX, 1, 2, 150, 0.0);
        assert!(ev.is_empty(), "overwrite must not trip the count cap");
        assert_eq!(freed, 100, "the replaced entry's footprint is released");
        assert_eq!(s.map.len(), 1);
    }

    #[test]
    fn count_eviction_reports_keys_and_bytes() {
        let mut s: Shard<u32> = Shard::new();
        s.insert("a".into(), 0, u64::MAX, 2, 1, 10, 0.0);
        s.insert("b".into(), 1, u64::MAX, 2, 2, 20, 0.0);
        let (ev, freed) = s.insert("c".into(), 2, u64::MAX, 2, 3, 30, 0.0);
        assert_eq!(ev, vec!["a".to_string()], "coldest key evicted and reported");
        assert_eq!(freed, 10);
    }

    #[test]
    fn victim_scan_prefers_expired_then_policy_order() {
        let mut s: Shard<u32> = Shard::new();
        s.insert("cold".into(), 0, u64::MAX, 0, 1, 10, 5.0);
        s.insert("hot".into(), 1, u64::MAX, 0, 2, 10, 5.0);
        s.insert("dead".into(), 2, 50, 0, 3, 10, 5.0);
        let v = s.victim(&crate::eviction::Lru, 100).unwrap();
        assert_eq!(v.key, "dead", "expired resident must be reclaimed first");
        s.evict("dead").unwrap();
        let v = s.victim(&crate::eviction::Lru, 100).unwrap();
        assert_eq!(v.key, "cold", "then the coldest live entry");
        assert_eq!(v.bytes, 10);
    }
}
