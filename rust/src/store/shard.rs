//! One lock's worth of the KV store: a hash map with TTL metadata and a
//! lazy-LRU queue for eviction (the classic "stale pairs" trick: the queue
//! may contain outdated (seq, key) pairs; eviction pops until it finds a
//! pair whose seq still matches the entry).

use std::collections::{HashMap, VecDeque};

pub(super) struct Entry<V> {
    value: V,
    expires_at_ms: u64,
    /// Last-access sequence number, compared against queue pairs.
    access_seq: u64,
}

pub(super) enum Lookup<'a, V> {
    Hit(&'a V),
    Expired,
    Miss,
}

pub(super) struct Shard<V> {
    map: HashMap<String, Entry<V>>,
    /// Lazy LRU queue of (access_seq, key); front = coldest candidate.
    lru: VecDeque<(u64, String)>,
    next_seq: u64,
}

impl<V> Shard<V> {
    pub fn new() -> Self {
        Self { map: HashMap::new(), lru: VecDeque::new(), next_seq: 0 }
    }

    fn bump(&mut self, key: &str) -> u64 {
        // Bound queue growth from repeated touches: compact when it is far
        // larger than the map (amortized O(1) per access). Runs *before*
        // pushing the new pair — the caller is about to stamp the entry
        // with `next_seq + 1`, so the fresh pair must survive compaction.
        if self.lru.len() > 4 * self.map.len() + 15 {
            let map = &self.map;
            self.lru.retain(|(seq, k)| map.get(k).map(|e| e.access_seq == *seq).unwrap_or(false));
        }
        self.next_seq += 1;
        self.lru.push_back((self.next_seq, key.to_string()));
        self.next_seq
    }

    /// Insert, evicting LRU entries if `capacity > 0` would be exceeded.
    /// Returns the number of evictions performed.
    pub fn insert(&mut self, key: String, value: V, expires_at_ms: u64, capacity: usize) -> u64 {
        let seq = self.bump(&key);
        let is_new = !self.map.contains_key(&key);
        self.map.insert(key, Entry { value, expires_at_ms, access_seq: seq });
        let mut evicted = 0;
        if capacity > 0 && is_new {
            while self.map.len() > capacity {
                if let Some((seq, k)) = self.lru.pop_front() {
                    let stale = self.map.get(&k).map(|e| e.access_seq != seq).unwrap_or(true);
                    if !stale {
                        self.map.remove(&k);
                        evicted += 1;
                    }
                } else {
                    break; // queue exhausted (shouldn't happen)
                }
            }
        }
        evicted
    }

    /// Read-only lookup: no LRU bump, no lazy removal. Used by the
    /// unbounded-store fast path, where a hit needs only a shared lock;
    /// an `Expired` result tells the caller to upgrade to a write lock
    /// and reclaim via [`Shard::remove_expired`].
    pub fn peek(&self, key: &str, now_ms: u64) -> Lookup<'_, V> {
        match self.map.get(key) {
            None => Lookup::Miss,
            Some(e) if e.expires_at_ms <= now_ms => Lookup::Expired,
            Some(e) => Lookup::Hit(&e.value),
        }
    }

    /// Drop `key` only if it is present *and* expired (idempotent: safe
    /// under read-then-write upgrade races). Returns whether it removed.
    pub fn remove_expired(&mut self, key: &str, now_ms: u64) -> bool {
        match self.map.get(key) {
            Some(e) if e.expires_at_ms <= now_ms => {
                self.map.remove(key);
                true
            }
            _ => false,
        }
    }

    pub fn get(&mut self, key: &str, now_ms: u64) -> Lookup<'_, V> {
        let expired = match self.map.get(key) {
            None => return Lookup::Miss,
            Some(e) => e.expires_at_ms <= now_ms,
        };
        if expired {
            self.map.remove(key);
            return Lookup::Expired;
        }
        let seq = self.bump(key);
        let e = self.map.get_mut(key).unwrap();
        e.access_seq = seq;
        Lookup::Hit(&self.map.get(key).unwrap().value)
    }

    pub fn remove(&mut self, key: &str, now_ms: u64) -> bool {
        match self.map.remove(key) {
            Some(e) => e.expires_at_ms > now_ms,
            None => false,
        }
    }

    pub fn ttl_remaining(&self, key: &str, now_ms: u64) -> Option<u64> {
        let e = self.map.get(key)?;
        if e.expires_at_ms <= now_ms {
            None
        } else if e.expires_at_ms == u64::MAX {
            Some(u64::MAX)
        } else {
            Some(e.expires_at_ms - now_ms)
        }
    }

    pub fn sweep(&mut self, now_ms: u64) -> usize {
        let before = self.map.len();
        self.map.retain(|_, e| e.expires_at_ms > now_ms);
        before - self.map.len()
    }

    /// Like [`Shard::sweep`], but collects the removed keys so the caller
    /// can propagate the expiry to secondary structures (e.g. tombstone
    /// the matching vector-index nodes).
    pub fn sweep_keys(&mut self, now_ms: u64, out: &mut Vec<String>) {
        let start = out.len();
        for (k, e) in &self.map {
            if e.expires_at_ms <= now_ms {
                out.push(k.clone());
            }
        }
        for k in &out[start..] {
            self.map.remove(k);
        }
    }

    pub fn live_len(&self, now_ms: u64) -> usize {
        self.map.values().filter(|e| e.expires_at_ms > now_ms).count()
    }

    pub fn for_each_live<F: FnMut(&str, &V)>(&self, now_ms: u64, f: &mut F) {
        for (k, e) in &self.map {
            if e.expires_at_ms > now_ms {
                f(k, &e.value);
            }
        }
    }

    /// Live entries with their absolute expiry (u64::MAX = immortal);
    /// the snapshot writer converts this to wall-clock expiry.
    pub fn for_each_live_expiry<F: FnMut(&str, &V, u64)>(&self, now_ms: u64, f: &mut F) {
        for (k, e) in &self.map {
            if e.expires_at_ms > now_ms {
                f(k, &e.value, e.expires_at_ms);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lazy_queue_compaction_keeps_correctness() {
        let mut s: Shard<u32> = Shard::new();
        // Hammer one key to bloat the queue, forcing compaction.
        s.insert("a".into(), 0, u64::MAX, 2);
        for i in 0..100 {
            match s.get("a", 0) {
                Lookup::Hit(_) => {}
                _ => panic!("a must stay live (iter {i})"),
            }
        }
        assert!(s.lru.len() <= 4 * s.map.len() + 16, "queue compacted");
        // LRU still works after compaction.
        s.insert("b".into(), 1, u64::MAX, 2);
        s.insert("c".into(), 2, u64::MAX, 2); // evicts coldest
        assert_eq!(s.map.len(), 2);
    }

    #[test]
    fn peek_is_read_only_and_remove_expired_is_idempotent() {
        let mut s: Shard<u32> = Shard::new();
        s.insert("a".into(), 1, 10, 0);
        let lru_before = s.lru.len();
        match s.peek("a", 5) {
            Lookup::Hit(v) => assert_eq!(*v, 1),
            _ => panic!("live entry must peek as hit"),
        }
        assert!(matches!(s.peek("a", 10), Lookup::Expired));
        assert!(matches!(s.peek("b", 0), Lookup::Miss));
        assert_eq!(s.lru.len(), lru_before, "peek must not touch the LRU queue");
        assert!(!s.remove_expired("a", 5), "live entry must survive");
        assert!(s.remove_expired("a", 10));
        assert!(!s.remove_expired("a", 10), "second reclaim is a no-op");
    }

    #[test]
    fn overwrite_does_not_evict() {
        let mut s: Shard<u32> = Shard::new();
        assert_eq!(s.insert("a".into(), 0, u64::MAX, 1), 0);
        assert_eq!(s.insert("a".into(), 1, u64::MAX, 1), 0);
        assert_eq!(s.map.len(), 1);
    }
}
