//! Clock abstraction: TTL logic is tested against a manual clock and runs
//! against the monotonic system clock in production.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Millisecond clock.
pub trait Clock: Send + Sync {
    fn now_ms(&self) -> u64;

    /// Wall-clock milliseconds since the Unix epoch. Unlike [`now_ms`],
    /// this survives process restarts, so it is the timebase persisted in
    /// snapshots and WAL records: on recovery, a stored absolute expiry is
    /// re-anchored onto the new process' monotonic clock. [`ManualClock`]
    /// drives both from the same atomic, which lets tests simulate
    /// downtime (construct the recovery clock at a later wall time)
    /// without sleeping.
    ///
    /// [`now_ms`]: Clock::now_ms
    fn wall_ms(&self) -> u64 {
        self.now_ms()
    }
}

/// Monotonic system clock (ms since process start).
pub struct SystemClock;

impl Clock for SystemClock {
    fn now_ms(&self) -> u64 {
        // Lazily anchored per-process epoch; monotonic so TTLs never go
        // backwards under NTP adjustments.
        static EPOCH: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
        let epoch = EPOCH.get_or_init(Instant::now);
        epoch.elapsed().as_millis() as u64
    }

    fn wall_ms(&self) -> u64 {
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0)
    }
}

/// Hand-driven clock for deterministic TTL tests and simulations.
pub struct ManualClock {
    now: AtomicU64,
}

impl ManualClock {
    pub fn new(start_ms: u64) -> Self {
        Self { now: AtomicU64::new(start_ms) }
    }

    pub fn advance(&self, ms: u64) {
        self.now.fetch_add(ms, Ordering::SeqCst);
    }

    pub fn set(&self, ms: u64) {
        self.now.store(ms, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_ms(&self) -> u64 {
        self.now.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_advances() {
        let c = ManualClock::new(5);
        assert_eq!(c.now_ms(), 5);
        c.advance(10);
        assert_eq!(c.now_ms(), 15);
        c.set(3);
        assert_eq!(c.now_ms(), 3);
    }

    #[test]
    fn system_clock_monotonic() {
        let c = SystemClock;
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(b >= a);
    }

    #[test]
    fn wall_clock_tracks_manual_clock() {
        // ManualClock shares one atomic between both timebases, so a
        // "later" clock models post-restart downtime.
        let c = ManualClock::new(1_000);
        assert_eq!(c.wall_ms(), 1_000);
        c.advance(250);
        assert_eq!(c.wall_ms(), c.now_ms());
    }

    #[test]
    fn system_wall_clock_is_epoch_scale() {
        // Sanity: wall_ms is Unix-epoch scale (> 2020-01-01), not
        // process-start scale.
        assert!(SystemClock.wall_ms() > 1_577_836_800_000);
    }
}
