//! In-memory key-value store — the Redis substitute (paper §2.3, §2.7).
//!
//! Implements the Redis semantics the paper relies on:
//!
//! * **in-memory hash storage** with O(1) get/set;
//! * **per-entry TTL** with both lazy expiry (on access) and an active
//!   sweeper (`sweep_expired`, driven by the coordinator's housekeeping
//!   thread — Redis' `activeExpireCycle` analogue);
//! * **bounded memory with LRU eviction** (Redis `allkeys-lru`);
//! * **read-mostly `RwLock` sharding** to keep lock contention off the
//!   request path: when the store is unbounded (no LRU bookkeeping, the
//!   serving default), concurrent `get`s on one shard take only the
//!   shared lock and proceed in parallel; writers and LRU-tracked reads
//!   take the exclusive lock;
//! * hit/miss/expiry/eviction **stats** (Redis `INFO` analogue).
//!
//! The store is deliberately type-parameterized (`KvStore<V>`): the
//! semantic cache stores full entries (question + response + embedding)
//! while tests exercise it with small values.

mod clock;
mod shard;

pub use clock::{Clock, ManualClock, SystemClock};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::sync::RwLock;

use shard::Shard;

/// Store-wide statistics (monotonic counters).
#[derive(Debug, Default)]
pub struct StoreStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub expired: AtomicU64,
    pub evicted: AtomicU64,
    pub inserts: AtomicU64,
}

/// Point-in-time snapshot of [`StoreStats`].
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct StatsSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub expired: u64,
    pub evicted: u64,
    pub inserts: u64,
    pub len: usize,
}

/// Configuration for a [`KvStore`].
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// Number of shards (power of two recommended).
    pub shards: usize,
    /// Maximum number of live entries across all shards; 0 = unbounded.
    pub capacity: usize,
    /// Default TTL in milliseconds applied by [`KvStore::set`]; 0 = no expiry.
    pub default_ttl_ms: u64,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self { shards: 16, capacity: 0, default_ttl_ms: 0 }
    }
}

/// Sharded TTL+LRU key-value store.
pub struct KvStore<V> {
    shards: Vec<RwLock<Shard<V>>>,
    stats: StoreStats,
    clock: Arc<dyn Clock>,
    per_shard_capacity: usize,
    default_ttl_ms: u64,
}

impl<V> KvStore<V> {
    pub fn new(cfg: StoreConfig) -> Self {
        Self::with_clock(cfg, Arc::new(SystemClock))
    }

    /// Inject a clock — tests drive TTL expiry with [`ManualClock`].
    pub fn with_clock(cfg: StoreConfig, clock: Arc<dyn Clock>) -> Self {
        let shards = cfg.shards.max(1);
        // Capacity is enforced per shard; round up so total >= requested.
        let per_shard_capacity =
            if cfg.capacity == 0 { 0 } else { cfg.capacity.div_ceil(shards) };
        Self {
            shards: (0..shards).map(|_| RwLock::new(Shard::new())).collect(),
            stats: StoreStats::default(),
            clock,
            per_shard_capacity,
            default_ttl_ms: cfg.default_ttl_ms,
        }
    }

    fn shard_for(&self, key: &str) -> &RwLock<Shard<V>> {
        let h = crate::tokenizer::fnv1a64(key.as_bytes());
        &self.shards[(h % self.shards.len() as u64) as usize]
    }

    /// Insert with the default TTL.
    pub fn set(&self, key: &str, value: V) {
        self.set_ttl(key, value, self.default_ttl_ms);
    }

    /// Insert with an explicit TTL (ms); 0 = never expires.
    pub fn set_ttl(&self, key: &str, value: V, ttl_ms: u64) {
        let now = self.clock.now_ms();
        let expires = if ttl_ms == 0 { u64::MAX } else { now + ttl_ms };
        let mut shard = self.shard_for(key).write().unwrap();
        let evicted = shard.insert(key.to_string(), value, expires, self.per_shard_capacity);
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        self.stats.evicted.fetch_add(evicted, Ordering::Relaxed);
    }
}

impl<V: Clone> KvStore<V> {
    /// Get a clone of the live value; lazily expires dead entries.
    ///
    /// Read-mostly fast path: when the store is unbounded (capacity 0)
    /// there is no LRU recency to maintain, so a hit only takes the
    /// shard's *shared* lock — concurrent readers of one shard proceed in
    /// parallel. The exclusive lock is taken only to reclaim an entry
    /// that was observed expired (idempotent under races) or, in the
    /// bounded configuration, to bump LRU recency.
    pub fn get(&self, key: &str) -> Option<V> {
        let now = self.clock.now_ms();
        let lock = self.shard_for(key);
        if self.per_shard_capacity == 0 {
            let shard = lock.read().unwrap();
            match shard.peek(key, now) {
                shard::Lookup::Hit(v) => {
                    let v = v.clone();
                    self.stats.hits.fetch_add(1, Ordering::Relaxed);
                    return Some(v);
                }
                shard::Lookup::Miss => {
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
                shard::Lookup::Expired => {}
            }
            drop(shard);
            // Upgrade to reclaim the expired entry; another thread may have
            // raced us (re-inserted or already reclaimed), so re-check.
            if lock.write().unwrap().remove_expired(key, now) {
                self.stats.expired.fetch_add(1, Ordering::Relaxed);
            }
            self.stats.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let mut shard = lock.write().unwrap();
        match shard.get(key, now) {
            shard::Lookup::Hit(v) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                Some(v.clone())
            }
            shard::Lookup::Expired => {
                self.stats.expired.fetch_add(1, Ordering::Relaxed);
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
            shard::Lookup::Miss => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }
}

impl<V> KvStore<V> {
    /// Remove a key; true if it was present and live.
    pub fn remove(&self, key: &str) -> bool {
        let now = self.clock.now_ms();
        self.shard_for(key).write().unwrap().remove(key, now)
    }

    /// Remaining TTL in ms (None = missing/expired; u64::MAX = immortal).
    pub fn ttl_ms(&self, key: &str) -> Option<u64> {
        let now = self.clock.now_ms();
        let shard = self.shard_for(key).read().unwrap();
        shard.ttl_remaining(key, now)
    }

    /// Active expiry cycle: drop every expired entry, returning the count.
    /// The coordinator's housekeeping thread calls this periodically.
    pub fn sweep_expired(&self) -> usize {
        let now = self.clock.now_ms();
        let mut total = 0;
        for shard in &self.shards {
            total += shard.write().unwrap().sweep(now);
        }
        self.stats.expired.fetch_add(total as u64, Ordering::Relaxed);
        total
    }

    /// Active expiry cycle that returns the swept keys, so callers keeping
    /// secondary structures keyed on the same entries (the cache
    /// partition's vector index + embedding map) can reclaim in lockstep.
    pub fn sweep_expired_keys(&self) -> Vec<String> {
        let now = self.clock.now_ms();
        let mut keys = Vec::new();
        for shard in &self.shards {
            shard.write().unwrap().sweep_keys(now, &mut keys);
        }
        self.stats.expired.fetch_add(keys.len() as u64, Ordering::Relaxed);
        keys
    }

    /// Live entry count (does not count not-yet-swept expired entries).
    pub fn len(&self) -> usize {
        let now = self.clock.now_ms();
        self.shards.iter().map(|s| s.read().unwrap().live_len(now)).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Visit every live entry (used by snapshot/rebuild paths).
    pub fn for_each<F: FnMut(&str, &V)>(&self, mut f: F) {
        let now = self.clock.now_ms();
        for shard in &self.shards {
            shard.read().unwrap().for_each_live(now, &mut f);
        }
    }

    /// Visit every live entry with its absolute expiry on this store's
    /// clock (u64::MAX = immortal). Snapshot dumps use this to convert
    /// monotonic expiries into wall-clock expiries that survive restarts.
    pub fn for_each_with_expiry<F: FnMut(&str, &V, u64)>(&self, mut f: F) {
        let now = self.clock.now_ms();
        for shard in &self.shards {
            shard.read().unwrap().for_each_live_expiry(now, &mut f);
        }
    }

    pub fn stats(&self) -> StatsSnapshot {
        StatsSnapshot {
            hits: self.stats.hits.load(Ordering::Relaxed),
            misses: self.stats.misses.load(Ordering::Relaxed),
            expired: self.stats.expired.load(Ordering::Relaxed),
            evicted: self.stats.evicted.load(Ordering::Relaxed),
            inserts: self.stats.inserts.load(Ordering::Relaxed),
            len: self.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manual_store(capacity: usize, ttl: u64) -> (KvStore<String>, Arc<ManualClock>) {
        let clock = Arc::new(ManualClock::new(1_000));
        let cfg = StoreConfig { shards: 4, capacity, default_ttl_ms: ttl };
        (KvStore::with_clock(cfg, clock.clone()), clock)
    }

    #[test]
    fn set_get_remove() {
        let (s, _) = manual_store(0, 0);
        assert_eq!(s.get("a"), None);
        s.set("a", "1".into());
        assert_eq!(s.get("a"), Some("1".into()));
        assert!(s.remove("a"));
        assert!(!s.remove("a"));
        assert_eq!(s.get("a"), None);
    }

    #[test]
    fn overwrite_updates_value_and_ttl() {
        let (s, clock) = manual_store(0, 0);
        s.set_ttl("k", "v1".into(), 100);
        s.set_ttl("k", "v2".into(), 10_000);
        clock.advance(5_000);
        assert_eq!(s.get("k"), Some("v2".into()));
    }

    #[test]
    fn ttl_lazy_expiry() {
        let (s, clock) = manual_store(0, 500);
        s.set("k", "v".into());
        assert_eq!(s.get("k"), Some("v".into()));
        clock.advance(499);
        assert_eq!(s.get("k"), Some("v".into()));
        clock.advance(2);
        assert_eq!(s.get("k"), None);
        let st = s.stats();
        assert_eq!(st.expired, 1);
        assert_eq!(st.hits, 2);
        assert_eq!(st.misses, 1);
    }

    #[test]
    fn ttl_zero_is_immortal() {
        let (s, clock) = manual_store(0, 0);
        s.set("k", "v".into());
        clock.advance(u64::MAX / 4);
        assert_eq!(s.get("k"), Some("v".into()));
        assert_eq!(s.ttl_ms("k"), Some(u64::MAX));
    }

    #[test]
    fn active_sweep_counts_and_removes() {
        let (s, clock) = manual_store(0, 100);
        for i in 0..50 {
            s.set(&format!("k{i}"), "v".into());
        }
        s.set_ttl("keep", "v".into(), 0);
        clock.advance(200);
        let swept = s.sweep_expired();
        assert_eq!(swept, 50);
        assert_eq!(s.len(), 1);
        assert_eq!(s.sweep_expired(), 0);
    }

    #[test]
    fn sweep_expired_keys_reports_what_it_removed() {
        let (s, clock) = manual_store(0, 100);
        s.set("gone1", "x".into());
        s.set("gone2", "x".into());
        s.set_ttl("keep", "y".into(), 0);
        clock.advance(200);
        let mut keys = s.sweep_expired_keys();
        keys.sort();
        assert_eq!(keys, vec!["gone1".to_string(), "gone2".to_string()]);
        assert_eq!(s.len(), 1);
        assert_eq!(s.stats().expired, 2);
        assert!(s.sweep_expired_keys().is_empty());
    }

    #[test]
    fn for_each_with_expiry_exposes_absolute_expiry() {
        let (s, _clock) = manual_store(0, 0);
        s.set_ttl("immortal", "a".into(), 0);
        s.set_ttl("mortal", "b".into(), 500);
        let mut seen = Vec::new();
        s.for_each_with_expiry(|k, _, exp| seen.push((k.to_string(), exp)));
        seen.sort();
        assert_eq!(seen[0], ("immortal".to_string(), u64::MAX));
        assert_eq!(seen[1], ("mortal".to_string(), 1_500)); // clock starts at 1_000
    }

    #[test]
    fn lru_eviction_prefers_cold_keys() {
        let clock = Arc::new(ManualClock::new(0));
        // Single shard so capacity semantics are exact.
        let cfg = StoreConfig { shards: 1, capacity: 3, default_ttl_ms: 0 };
        let s: KvStore<String> = KvStore::with_clock(cfg, clock);
        s.set("a", "1".into());
        s.set("b", "2".into());
        s.set("c", "3".into());
        // Touch a and c so b is coldest.
        assert!(s.get("a").is_some());
        assert!(s.get("c").is_some());
        s.set("d", "4".into());
        assert_eq!(s.get("b"), None, "cold key evicted");
        assert!(s.get("a").is_some());
        assert!(s.get("c").is_some());
        assert!(s.get("d").is_some());
        assert_eq!(s.stats().evicted, 1);
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn len_ignores_expired() {
        let (s, clock) = manual_store(0, 100);
        s.set("a", "x".into());
        s.set_ttl("b", "y".into(), 0);
        assert_eq!(s.len(), 2);
        clock.advance(150);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn for_each_visits_live_only() {
        let (s, clock) = manual_store(0, 100);
        s.set("dead", "x".into());
        s.set_ttl("live", "y".into(), 1_000);
        clock.advance(150);
        let mut seen = Vec::new();
        s.for_each(|k, _| seen.push(k.to_string()));
        assert_eq!(seen, vec!["live"]);
    }

    #[test]
    fn concurrent_readers_share_the_fast_path() {
        // Unbounded store: parallel gets take only the shared lock; all
        // of them must see consistent values and stats.
        let s: Arc<KvStore<String>> = Arc::new(KvStore::new(StoreConfig {
            shards: 2,
            capacity: 0,
            default_ttl_ms: 0,
        }));
        for i in 0..64 {
            s.set(&format!("k{i}"), format!("v{i}"));
        }
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for round in 0..200usize {
                    let i = round % 64;
                    assert_eq!(s.get(&format!("k{i}")), Some(format!("v{i}")));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.stats().hits, 4 * 200);
        assert_eq!(s.stats().misses, 0);
    }

    #[test]
    fn concurrent_smoke() {
        use std::sync::Arc as A;
        let s: A<KvStore<u64>> = A::new(KvStore::new(StoreConfig {
            shards: 8,
            capacity: 0,
            default_ttl_ms: 0,
        }));
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    let k = format!("k{}", (t * 1000 + i) % 256);
                    s.set(&k, i);
                    let _ = s.get(&k);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 256);
    }
}
